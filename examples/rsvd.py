"""Randomized SVD (Halko-Martinsson-Tropp) on the TSM2X kernel paths --
the sketching workload the QR subsystem unlocks.

Every heavy product in the algorithm is tall-and-skinny over the row
dimension of A (n_rows >> n_cols >> rank):

    Y  = A @ Omega            # (n, d) @ (d, k)    -- TSM2L (tiny contraction)
    Q  = tsqr(Y)              # CholeskyQR2: Gram=TSMT, apply=TSM2L
    Z  = A^T @ Q              # huge-m reduction    -- TSMT
    Y' = A @ Z                # power iteration     -- TSM2L
    B  = Q^T A  (= Z^T)       # small (k, d)
    U_b, s, V^T = svd(B)      # host-shaped
    U  = Q @ U_b              # (n, k) @ (k, k)     -- TSM2L

so the whole factorization runs under one ``tsmm.policy(...)`` scope and
the only dense decompositions left are (k, d)- and (r, r)-shaped.

    PYTHONPATH=src python examples/rsvd.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import linalg
from repro.core import tsmm

N, D, RANK, OVERSAMPLE, POWER_ITERS = 200_000, 256, 8, 8, 2


def make_low_rank(key, noise=1e-3):
    """A = U diag(s) V^T + noise, with a known spectrum to recover."""
    k1, k2, k3 = jax.random.split(key, 3)
    u, _ = jnp.linalg.qr(jax.random.normal(k1, (N, RANK)))
    v, _ = jnp.linalg.qr(jax.random.normal(k2, (D, RANK)))
    s = jnp.asarray(np.geomspace(100.0, 1.0, RANK), jnp.float32)
    a = (u * s) @ v.T + noise * jax.random.normal(k3, (N, D))
    return a, s


def rsvd(key, a, rank, *, oversample=OVERSAMPLE, power_iters=POWER_ITERS):
    """Rank-``rank`` randomized SVD of tall ``a``; returns (U, s, Vt)."""
    k = rank + oversample
    omega = jax.random.normal(key, (a.shape[1], k), a.dtype)
    y = tsmm.tsmm(a, omega)                       # TSM2L
    q, _ = linalg.tsqr(y)
    for _ in range(power_iters):                  # subspace iteration
        z = tsmm.tsmm_t(a, q)                     # TSMT: A^T Q, (d, k)
        y = tsmm.tsmm(a, z)                       # TSM2L: A (A^T Q)
        q, _ = linalg.tsqr(y)
    b = tsmm.tsmm_t(a, q).T                       # (k, d) = Q^T A
    u_b, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = tsmm.tsmm(q, u_b)                         # TSM2L back-projection
    return u[:, :rank], s[:rank], vt[:rank]


def main():
    key = jax.random.PRNGKey(0)
    a, s_true = make_low_rank(key)
    t0 = time.time()
    u, s, vt = jax.jit(lambda k, x: rsvd(k, x, RANK))(
        jax.random.fold_in(key, 1), a)
    jax.block_until_ready(s)
    print(f"rsvd of {a.shape} rank {RANK} in {time.time() - t0:.2f}s "
          f"on {jax.devices()[0]}")
    # Weyl: the noise term moves each singular value by at most ||E||_2
    # ~ noise * (sqrt(N) + sqrt(D)); recovery is good if we sit inside it.
    noise_floor = 1e-3 * (N ** 0.5 + D ** 0.5)
    s_err = float(jnp.max(jnp.abs(s - s_true)))
    print(f"singular values:  {np.asarray(s).round(2)}")
    print(f"max sv error: {s_err:.2e} (noise floor {noise_floor:.2e})")
    orth = float(jnp.max(jnp.abs(u.T @ u - jnp.eye(RANK))))
    rec = float(jnp.linalg.norm((u * s) @ vt - a) / jnp.linalg.norm(a))
    print(f"basis orthogonality: {orth:.2e}; reconstruction residual "
          f"(noise floor): {rec:.2e}")
    assert s_err < noise_floor and orth < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
