"""Batched serving example: prefill a batch of prompts, decode with greedy
and temperature sampling, verify the KV-cache path against the full
forward (the correctness invariant behind decode_32k / long_500k cells).

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import model
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=[a for a in registry.ARCH_NAMES
                             if a != "hubert-xlarge"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    extras = None
    if cfg.family == "vlm":
        extras = {"image_embeds": jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.vision_seq, cfg.vision_dim))}

    t0 = time.time()
    out_greedy = engine.generate(params, cfg, prompts, args.max_new,
                                 extras=extras)
    t1 = time.time()
    out_sampled = engine.generate(params, cfg, prompts, args.max_new,
                                  temperature=0.8, extras=extras,
                                  key=jax.random.PRNGKey(7))
    print(f"[serve] {args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"  greedy tokens[0]: {np.asarray(out_greedy[0])}")
    print(f"  sampled tokens[0]: {np.asarray(out_sampled[0])}")
    print(f"  prefill+decode wall: {t1 - t0:.2f}s "
          f"({args.batch * args.max_new / (t1 - t0):.1f} tok/s incl. compile)")

    # correctness: greedy continuation == argmax over the teacher-forced
    # full forward at each position
    full_tokens = jnp.concatenate([prompts, out_greedy], axis=1)
    batch = {"tokens": full_tokens}
    if extras:
        batch.update(extras)
    logits, _ = model.forward(params, cfg, batch)
    for t in range(args.max_new):
        pos = args.prompt_len + t - 1
        expect = jnp.argmax(logits[:, pos], -1)
        np.testing.assert_array_equal(np.asarray(out_greedy[:, t]),
                                      np.asarray(expect))
    print("  KV-cache decode == teacher-forced forward: OK")


if __name__ == "__main__":
    main()
