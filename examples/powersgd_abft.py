"""The paper's kernels at work inside the distributed-training substrate:

1. PowerSGD gradient compression -- both projections are tall-and-skinny
   GEMMs (TSM2R + TSMT); shows the wire-byte reduction for a DP all-reduce
   and the error-feedback recovery property.
2. ABFT checksums -- encode/verify a parameter tree, inject a bit flip,
   watch it get caught (the paper's own motivating application).

    PYTHONPATH=src python examples/powersgd_abft.py
"""

import jax
import jax.numpy as jnp

from repro.core import tsmm
from repro.ft import abft
from repro.optim import powersgd

key = jax.random.PRNGKey(0)

# One policy scope instead of threading interpret= through every call:
# interpret mode pins the Pallas kernels to their Python bodies (CPU demo).
POLICY = tsmm.GemmPolicy(interpret=True)

# --- PowerSGD ---------------------------------------------------------------
def spectral_grad(k, d1, d2, decay=0.5):
    """Gradients in practice have fast-decaying spectra -- synthesize one."""
    u = jax.random.normal(k, (d1, 32))
    v = jax.random.normal(jax.random.fold_in(k, 1), (32, d2))
    scales = decay ** jnp.arange(32)
    return (u * scales) @ v * 0.01


grads = {
    "mlp/w_up": spectral_grad(key, 2048, 8192),
    "mlp/w_down": spectral_grad(jax.random.fold_in(key, 1), 8192, 2048),
    "norm/scale": jnp.ones((2048,)),
}
cfg = powersgd.PowerSGDConfig(rank=4, min_size=0)
state = powersgd.init(cfg, grads, jax.random.PRNGKey(2))


def fake_psum(x):   # MEAN over a 2-replica DP group with identical grads
    return (x + x) / 2.0


with tsmm.policy(POLICY):
    out, state, metrics = powersgd.compress_tree(cfg, grads, state,
                                                 psum=fake_psum)
dense_bytes = sum(g.size * 4 for g in jax.tree.leaves(grads))
print(f"PowerSGD rank-4: compression ratio {metrics['powersgd_compression']:.1f}x "
      f"({dense_bytes/1e6:.1f} MB dense all-reduce -> "
      f"{dense_bytes/metrics['powersgd_compression']/1e6:.2f} MB)")
rel = float(jnp.linalg.norm(out["mlp/w_up"] - grads["mlp/w_up"])
            / jnp.linalg.norm(grads["mlp/w_up"]))
print(f"  round-1 relative error {rel:.3f} on a decaying-spectrum gradient "
      "(error feedback replays any residual next step)")

# --- ABFT --------------------------------------------------------------------
params = {"w": jax.random.normal(jax.random.fold_in(key, 3), (4096, 1024))}
cs = abft.encode_tree(params, policy=POLICY)
ok, _ = abft.verify_tree(params, cs, policy=POLICY)
print(f"ABFT clean verify: {bool(ok)}")
corrupt = {"w": params["w"].at[1234, 56].add(1.0)}   # one flipped value
ok2, devs = abft.verify_tree(corrupt, cs, policy=POLICY)
print(f"ABFT after single-element corruption: detected={not bool(ok2)}")
assert bool(ok) and not bool(ok2)
print("OK")
