"""End-to-end training driver: train a ~100M-param llama-style model on
the synthetic pipeline for a few hundred steps, with checkpointing,
watchdog, and ABFT -- the full production loop from launch/train.py.

Default runs a CPU-sized config so the example completes in minutes; pass
--full-100m for the 100M-parameter configuration (the same code path; on
one CPU core a few hundred steps takes hours -- size it to your hardware):

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300
"""

import argparse
import sys

from repro.configs.base import ModelConfig
from repro.configs import registry
from repro.launch import train as train_launcher


def config_100m() -> ModelConfig:
    # ~100M params: 12L x d512 x ffn 2048, 16k vocab
    return ModelConfig(
        name="llama-100m", family="dense", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=16384, head_dim=64,
        tie_embeddings=True, q_chunk=128, kv_chunk=128, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args, _ = ap.parse_known_args()

    if args.full_100m:
        cfg = config_100m()
        n = cfg.param_count()
        print(f"[example] llama-100m: {n/1e6:.1f}M params")
        # register ad hoc so the launcher can resolve it
        registry._MODULES["llama-100m"] = type(
            "M", (), {"CONFIG": cfg, "smoke": staticmethod(lambda: cfg)})
        argv = ["--arch", "llama-100m", "--steps", str(args.steps or 300),
                "--global-batch", "8", "--seq-len", "256",
                "--ckpt-dir", "/tmp/repro_train_100m", "--ckpt-every", "50",
                "--abft-every", "50", "--lr", "1e-3"]
    else:
        argv = ["--arch", "llama3.2-3b", "--smoke",
                "--steps", str(args.steps or 120), "--global-batch", "8",
                "--seq-len", "64", "--ckpt-dir", "/tmp/repro_train_smoke",
                "--ckpt-every", "40", "--abft-every", "40", "--lr", "3e-3"]
    print(f"[example] launching: train {' '.join(argv)}")
    train_launcher.main(argv)


if __name__ == "__main__":
    sys.exit(main())
