"""Quickstart: the TSM2X public API in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Shows: shape-dispatched tall-and-skinny matmul (the paper's TSM2R/TSM2L),
the transposed TSMT extension, batched N-d operands, the scoped GemmPolicy
(dense A/B arm, hardware spec selection), the performance model's bound
classifier, and kernel-vs-oracle validation (interpret mode on CPU).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model, tsmm
from repro.kernels import ref

key = jax.random.PRNGKey(0)

# --- Paper case (i): large regular x tall-and-skinny (TSM2R) ---------------
m = k, n = (4096, 4096), 8
a = jax.random.normal(key, (4096, 4096), jnp.float32)
b = jax.random.normal(jax.random.fold_in(key, 1), (4096, 8), jnp.float32)
c = tsmm.tsmm(a, b)                       # dispatches to the TSM2R kernel
np.testing.assert_allclose(np.asarray(c), np.asarray(ref.tsm2r_ref(a, b)),
                           rtol=1e-3, atol=1e-4)
print(f"TSM2R 4096x4096 @ 4096x8 -> {c.shape}, "
      f"kind={tsmm.classify_gemm(4096, 4096, 8)}, "
      f"bound={perf_model.classify(4096, 4096, 8)}")

# --- Paper case (ii): tall-and-skinny x small square (TSM2L) ---------------
a2 = jax.random.normal(key, (102400, 4), jnp.float32)
b2 = jax.random.normal(jax.random.fold_in(key, 2), (4, 4), jnp.float32)
c2 = tsmm.tsmm(a2, b2)
np.testing.assert_allclose(np.asarray(c2), np.asarray(ref.tsm2l_ref(a2, b2)),
                           rtol=1e-3, atol=1e-4)
print(f"TSM2L 102400x4 @ 4x4 -> {c2.shape}, "
      f"bound={perf_model.classify(102400, 4, 4)}  (the paper's latency case)")

# --- Beyond paper: transposed reduction over huge m (TSMT) ------------------
x = jax.random.normal(key, (65536, 128), jnp.float32)
y = jax.random.normal(jax.random.fold_in(key, 3), (65536, 4), jnp.float32)
q = tsmm.tsmm_t(x, y)                     # X^T Y without materializing X^T
np.testing.assert_allclose(np.asarray(q), np.asarray(x.T @ y), rtol=1e-3,
                           atol=1e-3)
print(f"TSMT  (65536x128)^T @ 65536x4 -> {q.shape}  (PowerSGD/ABFT shape)")

# --- Batched N-d operands: tsmm owns the leading-dim collapse ---------------
a4 = jax.random.normal(key, (8, 512, 4))          # (batch, m, k)
c4 = tsmm.tsmm(a4, b2)                            # -> (8, 512, 4)
np.testing.assert_allclose(np.asarray(c4),
                           np.asarray(jnp.einsum("bmk,kn->bmn", a4, b2)),
                           rtol=1e-3, atol=1e-3)
print(f"batched {a4.shape} @ {b2.shape} -> {c4.shape} "
      "(classified on the collapsed tall dim)")

# --- GemmPolicy: every dispatch knob, lexically scoped ----------------------
with tsmm.policy(mode="dense"):                   # the A/B escape hatch
    c_dense = tsmm.tsmm(a2, b2)
np.testing.assert_allclose(np.asarray(c_dense), np.asarray(c2), rtol=1e-3,
                           atol=1e-3)
with tsmm.policy(spec=perf_model.V5P):            # newer hardware generation
    print(f"policy(spec=V5P): bound for 20480^2 x n=200 = "
          f"{tsmm.bound_class(20480, 20480, 200)} "
          f"(V5E: {perf_model.classify(20480, 20480, 200)})")
with tsmm.record_dispatches() as log:             # the dispatch spy
    tsmm.tsmm(a, b)
print(f"dispatch spy: {log[0].kind} via {log[0].executor} "
      f"for shape {log[0].shape}")

# --- The performance model that drives block choice -------------------------
bm, bk, splits = perf_model.choose_params_tsm2r(20480, 20480, 16)
print(f"v5e params for 20480^2 x n=16: block_m={bm} block_k={bk} "
      f"splits={splits}, "
      f"modeled bw util="
      f"{perf_model.modeled_bandwidth_utilization(20480, 20480, 16, bm, bk, splits=splits):.1%}")
print(f"t2_threshold(v5e, bf16) = {perf_model.t2_threshold():.0f} "
      "(paper: all n<=32 cases sit below it => memory-bound)")
print("OK")
