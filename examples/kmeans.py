"""K-means on GPU/TPU via tall-and-skinny GEMM -- the paper's motivating
application (Section 1: "recent highly optimized K-means implementations
use GEMM as their core computation ... mostly tall-and-skinny").

Distance expansion: ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2; the x.c term
is X[n_points, d] @ C^T[d, k_clusters] with k_clusters << n_points -- a
TSM2R shape served by repro.core.tsmm.

    PYTHONPATH=src python examples/kmeans.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tsmm

N, D, K, ITERS = 200_000, 64, 8, 10


def make_blobs(key):
    centers = jax.random.normal(key, (K, D)) * 5.0
    ks = jax.random.split(jax.random.fold_in(key, 1), K)
    pts = [centers[i] + jax.random.normal(ks[i], (N // K, D)) for i in range(K)]
    return jnp.concatenate(pts), centers


def kmeans_step(x, centroids):
    # TSM2R: (N, D) @ (D, K), K=8 skinny
    dots = tsmm.tsmm(x, centroids.T)
    d2 = (jnp.sum(x * x, 1, keepdims=True) - 2 * dots
          + jnp.sum(centroids * centroids, 1)[None, :])
    assign = jnp.argmin(d2, axis=1)
    # centroid update is a segment mean: one-hot^T @ x is ALSO tall-skinny
    # (N huge, K skinny) -- the TSMT orientation.
    onehot = jax.nn.one_hot(assign, K, dtype=x.dtype)
    sums = tsmm.tsmm_t(x, onehot).T          # (K, D)
    counts = onehot.sum(0)[:, None]
    new_c = sums / jnp.maximum(counts, 1)
    inertia = jnp.take_along_axis(d2, assign[:, None], 1).sum()
    return new_c, assign, inertia


@jax.jit
def _pp_farthest(x, centers, n_filled):
    """One k-means++ pass at the FIXED (N, D) @ (D, K) shape: unfilled
    center rows are masked out of the min instead of sliced off, so every
    pass reuses one compiled kernel and one tuning bucket."""
    dots = tsmm.tsmm(x, centers.T)                     # (N, K) skinny
    d2 = (jnp.sum(x * x, 1, keepdims=True) - 2 * dots
          + jnp.sum(centers * centers, 1)[None, :])
    d2 = jnp.where(jnp.arange(K)[None, :] < n_filled, d2, jnp.inf)
    return jnp.argmax(d2.min(axis=1))


def kmeanspp_init(key, x):
    """k-means++ seeding -- each min-distance pass is itself a TSM2R.

    The centers operand is padded to the full (K, D) width up front and
    the filled count rides in as a traced scalar: the naive "stack what
    we have so far" formulation retraces the tsmm K-1 times with a
    growing skinny dim (a jit cache entry AND an autotune bucket per i).
    """
    idx = jax.random.randint(key, (), 0, x.shape[0])
    centers = jnp.zeros((K, D), x.dtype).at[0].set(x[idx])
    for i in range(1, K):
        nxt = _pp_farthest(x, centers, i)   # farthest-point: deterministic
        centers = centers.at[i].set(x[nxt])
    return centers


def main():
    key = jax.random.PRNGKey(0)
    x, true_centers = make_blobs(key)
    step = jax.jit(kmeans_step)
    t0 = time.time()
    # naive random init almost never covers all blobs (8!/8^8 ~ 0.2%);
    # k-means++ does -- and its distance pass is another TSM2R.
    centroids = kmeanspp_init(jax.random.fold_in(key, 2), x)
    for i in range(ITERS):
        centroids, assign, inertia = step(x, centroids)
        if i % 3 == 0 or i == ITERS - 1:
            print(f"iter {i}: inertia {float(inertia):.4e}")
    print(f"{ITERS} iters in {time.time() - t0:.2f}s on {jax.devices()[0]}")
    # verify recovered centers match the generating ones (up to permutation)
    d = np.linalg.norm(np.asarray(true_centers)[:, None]
                       - np.asarray(centroids)[None], axis=-1)
    match = d.min(axis=1)
    print(f"center recovery error: max {match.max():.3f} (should be < 0.5)")
    assert match.max() < 0.5
    print("OK")


if __name__ == "__main__":
    main()
