"""Partition-rule engine: spec assignment, divisibility guards, strategies.

These run against a mesh built from the single local device via an
AbstractMesh-free path: rules and guards are pure functions of axis sizes,
so we construct Mesh objects over a 1-device 'grid' with logical sizes via
jax.sharding.AbstractMesh (no real devices needed for spec logic)."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding
from repro.models import model

# AbstractMesh's signature drifted across JAX versions; construct through
# the repo's compat path.
MESH = sharding.abstract_mesh((16, 16), ("data", "model"))
MESH3 = sharding.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _shapes(arch):
    cfg = registry.get_config(arch)
    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return cfg, jax.eval_shape(lambda k: model.init(k, cfg), key_s)


def _flat_with_paths(tree):
    return {sharding.path_str(p): v for p, v in
            jax.tree_util.tree_flatten_with_path(tree)[0]}


def test_dense_tp_rules():
    cfg, shapes = _shapes("llama3.2-3b")
    specs = sharding.make_param_specs(cfg, shapes, MESH)
    flat = _flat_with_paths(specs)
    # attention q projection: heads over model (last dim), scan dim None
    assert flat["segments/0/attn/wq"][-1] == "model"
    assert flat["segments/0/attn/wo"][-2] == "model"
    # vocab over model
    assert flat["embed/table"][0] == "model"
    # norms replicated
    assert all(a is None for a in tuple(flat["segments/0/norm1/scale"]))


def test_divisibility_guard_falls_back():
    cfg, shapes = _shapes("hubert-xlarge")   # vocab 504 % 16 != 0
    specs = sharding.make_param_specs(cfg, shapes, MESH)
    flat = _flat_with_paths(specs)
    assert flat["embed/table"][0] is None      # guarded to replicate
    # d_ff 5120 divides => still sharded
    assert flat["segments/0/ffn/w_up"][-1] == "model"


def test_moe_expert_rules_ep_vs_tp_fallback():
    cfg, shapes = _shapes("deepseek-v3-671b")  # 256 experts: EP
    specs = sharding.make_param_specs(cfg, shapes, MESH)
    flat = _flat_with_paths(specs)
    k = [p for p in flat if p.endswith("experts/w_gate")][0]
    assert flat[k][-3] == "model"              # expert dim over model
    assert flat[k][-2] == "data"               # FSDP (671B > threshold)

    cfg2, shapes2 = _shapes("mixtral-8x7b")    # 8 experts < 16: TP fallback
    specs2 = sharding.make_param_specs(cfg2, shapes2, MESH)
    flat2 = _flat_with_paths(specs2)
    k2 = [p for p in flat2 if p.endswith("experts/w_gate")][0]
    assert flat2[k2][-3] is None               # expert dim replicated
    assert flat2[k2][-1] == "model"            # d_ff sharded instead


def test_dp_strategy_replicates_params_shards_moments():
    cfg, shapes = _shapes("llama3.2-3b")
    specs = sharding.make_param_specs(cfg, shapes, MESH, strategy="dp")
    assert all(all(a is None for a in tuple(s)) for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    opt = sharding.make_opt_specs(specs, mesh=MESH, params_shape=shapes,
                                  zero1=True)
    flat = _flat_with_paths(opt["moments"])
    mk = [p for p in flat if p.endswith("attn/wq/m")][0]
    assert ("data", "model") in tuple(flat[mk])


def test_cache_specs_sequence_parallel_fallback():
    cfg = registry.get_config("qwen2-72b")     # kv=8 < 16 => SP on seq dim
    cache_shape = jax.eval_shape(lambda: model.init_cache(cfg, 128, 1024))
    specs = sharding.cache_specs(cfg, MESH, cache_shape)
    flat = _flat_with_paths(specs)
    k = [p for p in flat if p.endswith("/k")][0]
    spec = tuple(flat[k])
    assert spec[-3] == "model"                 # sequence dim sharded
    assert spec[-2] is None                    # kv heads (8) replicated


def test_batch_specs_multi_pod():
    cfg = registry.get_config("llama3.2-3b")
    batch = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)}
    specs = sharding.batch_specs(cfg, MESH3, batch)
    assert tuple(specs["tokens"])[0] == ("pod", "data")
    # batch=1 (long_500k): falls back to replicated
    b1 = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    specs1 = sharding.batch_specs(cfg, MESH3, b1)
    assert tuple(specs1["tokens"])[0] is None
