"""GemmPolicy surface: scoping, validation, thresholds, batched N-d
entries, the backend registry, and the dispatch spy.

Everything runs single-device (interpret mode); the >1-device shard_map
executor is covered by tests/test_shard_map.py in a subprocess with
``--xla_force_host_platform_device_count``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import perf_model, tsmm
from repro.kernels import compat, ref

TOL = dict(rtol=1e-3, atol=1e-3)


def _rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Policy object + scoping
# ---------------------------------------------------------------------------

def test_policy_defaults():
    p = tsmm.GemmPolicy()
    assert p.mode == "auto" and p.spec is perf_model.V5E
    assert (p.skinny_ratio, p.max_skinny, p.min_tall) == (16, 256, 2048)
    assert (p.max_skinny_t, p.skinny_ratio_t) == (512, 4)


def test_policy_mode_validation_at_construction():
    with pytest.raises(ValueError, match="valid modes"):
        tsmm.GemmPolicy(mode="tsmr")
    with pytest.raises(ValueError, match="valid values"):
        tsmm.GemmPolicy(shard_map="sometimes")


def test_unknown_force_kind_raises():
    a, b = _rand(0, (64, 8)), _rand(1, (8, 4))
    with pytest.raises(ValueError, match="valid kinds are auto, dense, tsm2r, tsm2l"):
        tsmm.tsmm(a, b, mode="tsmr")
    with pytest.raises(ValueError, match="valid kinds are auto, dense, tsm2r, tsm2l"):
        tsmm.tsmm(a, b, force="tsmt")          # deprecated alias validates too
    x, y = _rand(2, (64, 8)), _rand(3, (64, 4))
    with pytest.raises(ValueError, match="valid kinds are auto, dense, tsmt"):
        tsmm.tsmm_t(x, y, mode="tsm2r")


def test_policy_nesting_and_restoration():
    base = tsmm.current_policy()
    with tsmm.policy(mode="dense") as p1:
        assert tsmm.current_policy() is p1
        with tsmm.policy(interpret=True) as p2:
            # inner scope derives from the outer one
            assert p2.mode == "dense" and p2.interpret is True
        assert tsmm.current_policy() is p1
    assert tsmm.current_policy() is base


def test_policy_restored_across_exceptions():
    with pytest.raises(RuntimeError):
        with tsmm.policy(mode="dense"):
            raise RuntimeError("boom")
    assert tsmm.current_policy().mode == "auto"


def test_policy_explicit_base():
    pinned = tsmm.GemmPolicy(mode="dense", interpret=True)
    with tsmm.policy(pinned) as p:
        assert p is pinned
    with tsmm.policy(pinned, mode="auto") as p:
        assert p.mode == "auto" and p.interpret is True


def test_trace_time_capture_under_jit():
    """A jitted caller bakes the scoped policy into its cache entry."""
    a, b = _rand(2, (4096, 16)), _rand(3, (16, 8))
    f = jax.jit(lambda a_, b_: tsmm.tsmm(a_, b_))
    with tsmm.policy(mode="dense"):
        with tsmm.record_dispatches() as log:
            f(a, b)
        assert [e.executor for e in log] == ["dense-xla"]
    # Cached call outside the scope: no re-trace, no new dispatch decision.
    with tsmm.record_dispatches() as log:
        f(a, b)
    assert log == []
    # A fresh jit outside the scope classifies and hits the kernel path.
    g = jax.jit(lambda a_, b_: tsmm.tsmm(a_, b_))
    with tsmm.record_dispatches() as log:
        g(a, b)
    assert [(e.kind, e.executor) for e in log] == [("tsm2l", "pallas-tpu")]


# ---------------------------------------------------------------------------
# Classifier thresholds as policy fields
# ---------------------------------------------------------------------------

def test_classify_gemm_boundaries():
    p = tsmm.GemmPolicy()
    assert tsmm.classify_gemm(2048, 16, 8, p) == "tsm2l"     # at min_tall
    assert tsmm.classify_gemm(2047, 16, 8, p) == "dense"     # below it
    assert tsmm.classify_gemm(8192, 256, 8, p) == "tsm2l"    # at max_skinny k
    assert tsmm.classify_gemm(8192, 257, 8, p) == "tsm2r"    # past it, k>=16n
    assert tsmm.classify_gemm(2048, 2048, 256, p) == "dense"   # m < 16n
    assert tsmm.classify_gemm(4096, 4096, 256, p) == "tsm2r"   # m == 16n
    assert tsmm.classify_gemm(4096, 4096, 257, p) == "dense"   # n past bound


def test_classify_gemm_t_boundaries():
    """Pin the transposed-entry boundary the named fields own: b <= 512
    (t2_threshold ~ 481 rounded up to the lane multiple) and m >= 4*max."""
    p = tsmm.GemmPolicy()
    assert tsmm.classify_gemm_t(2048, 128, 512, p) == "tsmt"   # both at bound
    assert tsmm.classify_gemm_t(2048, 128, 513, p) == "dense"  # b past bound
    assert tsmm.classify_gemm_t(2047, 128, 512, p) == "dense"  # below min_tall
    assert tsmm.classify_gemm_t(2048, 513, 512, p) == "dense"  # m < 4*513
    assert tsmm.classify_gemm_t(4 * 513, 513, 8, p) == "tsmt"  # m == 4*max
    assert tsmm.classify_gemm_t(4 * 513 - 1, 513, 8, p) == "dense"


def test_classify_matches_legacy_constants():
    """The field defaults reproduce the legacy module-global behavior
    (16*max//4 == 4*max exactly)."""
    for m, a_dim, b_dim in [(4096, 32, 8), (2048, 128, 512), (100000, 300, 16),
                            (512, 512, 1), (8192, 2048, 8)]:
        legacy = ("tsmt" if (m >= 2048 and b_dim <= 512
                             and m >= 16 * max(a_dim, b_dim) // 4)
                  else "dense")
        assert tsmm.classify_gemm_t(m, a_dim, b_dim) == legacy


def test_threshold_overrides_change_routing():
    with tsmm.policy(min_tall=64):
        assert tsmm.classify_gemm(128, 128, 2) == "tsm2l"   # k <= max_skinny
        assert tsmm.classify_gemm(128, 512, 2) == "tsm2r"
    assert tsmm.classify_gemm(128, 128, 2) == "dense"
    with tsmm.policy(max_skinny_t=8):
        assert tsmm.classify_gemm_t(4096, 32, 16) == "dense"
    assert tsmm.classify_gemm_t(4096, 32, 16) == "tsmt"


def test_spec_field_drives_perf_model():
    # n ~ 200 sits between the two generations' flops/byte ridges
    # (v5e ~ 241, v5p ~ 166): the same shape flips bound class with spec.
    assert tsmm.bound_class(20480, 20480, 200) == "memory"
    with tsmm.policy(spec=perf_model.V5P):
        assert tsmm.bound_class(20480, 20480, 200) == "compute"
    assert perf_model.get_spec("v5p") is perf_model.V5P
    with pytest.raises(ValueError, match="unknown TPU spec"):
        perf_model.get_spec("v6z")


# ---------------------------------------------------------------------------
# Batched N-d entries
# ---------------------------------------------------------------------------

def test_batched_tsmm_matches_oracle():
    a = _rand(4, (4, 1024, 16))        # collapses to (4096, 16) -> tsm2l
    b = _rand(5, (16, 8))
    with tsmm.record_dispatches() as log:
        got = tsmm.tsmm(a, b, interpret=True)
    assert log[0].kind == "tsm2l" and log[0].shape == (4096, 16, 8)
    want = jnp.einsum("bmk,kn->bmn", a, b)
    assert got.shape == (4, 1024, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_batched_tsmm_grad_matches_oracle():
    a, b = _rand(6, (2, 2048, 16)), _rand(7, (16, 8))
    def loss(fn):
        return lambda a_, b_: jnp.sum(jnp.tanh(fn(a_, b_)))

    da, db = jax.grad(loss(lambda a_, b_: tsmm.tsmm(a_, b_, interpret=True)),
                      (0, 1))(a, b)
    ra, rb = jax.grad(loss(lambda a_, b_: jnp.einsum("bmk,kn->bmn", a_, b_)),
                      (0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(da), np.asarray(ra), **TOL)
    np.testing.assert_allclose(np.asarray(db), np.asarray(rb), **TOL)


def test_batched_tsmm_t_matches_oracle():
    x, y = _rand(8, (2, 2048, 32)), _rand(9, (2, 2048, 8))
    with tsmm.record_dispatches() as log:
        got = tsmm.tsmm_t(x, y, interpret=True)
    assert log[0].kind == "tsmt" and log[0].shape == (4096, 32, 8)
    want = x.reshape(-1, 32).T @ y.reshape(-1, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_batched_dense_path_is_reshape_free_and_correct():
    a = _rand(10, (2, 64, 128))        # too small: dense
    b = _rand(11, (128, 512))
    with tsmm.record_dispatches() as log:
        got = tsmm.tsmm(a, b)
    assert [e.executor for e in log] == ["dense-xla"]
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.einsum("bmk,kn->bmn", a, b)),
                               rtol=1e-4, atol=1e-4)


def test_shape_validation():
    with pytest.raises(ValueError, match="lhs"):
        tsmm.tsmm(_rand(0, (8,)), _rand(1, (8, 4)))
    with pytest.raises(ValueError, match="contraction mismatch"):
        tsmm.tsmm(_rand(0, (8, 16)), _rand(1, (8, 4)))
    with pytest.raises(ValueError, match="identical leading dims"):
        tsmm.tsmm_t(_rand(0, (2, 64, 8)), _rand(1, (3, 64, 4)))


# ---------------------------------------------------------------------------
# Registry + executor pinning
# ---------------------------------------------------------------------------

def test_builtin_executors_registered():
    names = set(tsmm.executors())
    assert {"pallas-tpu", "interpret", "dense-xla", "shard_map"} <= names


def test_register_and_pin_custom_executor():
    calls = []

    def traced_dense(entry, kind, a, b, p):
        calls.append((entry, kind))
        return tsmm.executors()["dense-xla"](entry, kind, a, b, p)

    tsmm.register_executor("test-dense", traced_dense)
    try:
        with pytest.raises(ValueError, match="already registered"):
            tsmm.register_executor("test-dense", traced_dense)
        a, b = _rand(12, (4096, 16)), _rand(13, (16, 8))
        with tsmm.policy(executor="test-dense"):
            out = tsmm.tsmm(a, b)
        assert calls == [("mm", "tsm2l")]
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.tsm2l_ref(a, b)), **TOL)
    finally:
        tsmm.unregister_executor("test-dense")
    assert "test-dense" not in tsmm.executors()


def test_unregistered_executor_pin_raises():
    a, b = _rand(14, (4096, 16)), _rand(15, (16, 8))
    with tsmm.policy(executor="nope"):
        with pytest.raises(ValueError, match="not registered"):
            tsmm.tsmm(a, b)


def test_interpret_policy_field_selects_interpret_executor():
    a, b = _rand(16, (4096, 16)), _rand(17, (16, 8))
    with tsmm.policy(interpret=True):
        with tsmm.record_dispatches() as log:
            tsmm.tsmm(a, b)
    assert [e.executor for e in log] == ["interpret"]


def test_backward_policy_strips_force_and_executor():
    p = tsmm.GemmPolicy(mode="tsm2r", executor="interpret")
    bp = tsmm.backward_policy(p)
    assert bp.mode == "auto" and bp.executor is None
    dense = tsmm.GemmPolicy(mode="dense")
    assert tsmm.backward_policy(dense) is dense


def test_backward_honors_dense_scope():
    """grad of a tsmm traced under mode='dense' stays dense end to end."""
    a, b = _rand(18, (4096, 16)), _rand(19, (16, 8))
    with tsmm.policy(mode="dense"):
        with tsmm.record_dispatches() as log:
            jax.grad(lambda a_: jnp.sum(tsmm.tsmm(a_, b)))(a)
    assert {e.executor for e in log} == {"dense-xla"}


def test_enabled_is_policy_alias():
    assert tsmm.enabled()
    with tsmm.policy(mode="dense"):
        assert not tsmm.enabled()


# ---------------------------------------------------------------------------
# Benchmark report plumbing (the --json surface)
# ---------------------------------------------------------------------------

def test_bench_report_shape(tmp_path):
    import importlib
    import json
    import pathlib
    import sys

    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root))
    try:
        run_mod = importlib.import_module("benchmarks.run")
    finally:
        sys.path.remove(str(root))
    report = run_mod.build_report(
        {"sec": ("ok", [("row_a", 1.5, "kind=tsm2r"), ("row_b", "n/a")])})
    assert report["schema"].startswith("repro-tsm2x-bench/")
    assert report["policy"]["mode"] == tsmm.current_policy().mode
    rows = report["sections"]["sec"]["rows"]
    assert rows[0] == {"name": "row_a", "us_per_call": 1.5,
                       "derived": "kind=tsm2r"}
    assert rows[1]["us_per_call"] is None
    kinds = {(c["m"], c["k"], c["n"]): c["kind"]
             for c in report["classification"]}
    assert kinds[(20480, 20480, 2)] == "tsm2r"
    assert kinds[(4096, 4096, 1024)] == "dense"
    (tmp_path / "BENCH_test.json").write_text(json.dumps(report))


# ---------------------------------------------------------------------------
# reduce= knob + mesh-derived dp_axes (PR 4)
# ---------------------------------------------------------------------------

def test_reduce_validation():
    assert tsmm.GemmPolicy(reduce="psum_scatter").reduce == "psum_scatter"
    with pytest.raises(ValueError, match="psum_scatter"):
        tsmm.GemmPolicy(reduce="allreduce")


def test_backward_policy_keeps_scatter_downgrades_none():
    p = tsmm.GemmPolicy(reduce="psum_scatter")
    assert tsmm.backward_policy(p).reduce == "psum_scatter"
    assert tsmm.backward_policy(p) is p  # nothing to strip: same object
    p_none = tsmm.GemmPolicy(reduce="none", mode="tsm2r", executor="interpret")
    bp = tsmm.backward_policy(p_none)
    assert bp.reduce == "psum"           # stacked partials can't be a cotangent
    assert bp.mode == "auto" and bp.executor is None


def test_scatter_executor_registered_and_mmt_only():
    assert "shard_map-scatter" in tsmm.executors()
    a = jnp.ones((4096, 512), jnp.bfloat16)
    b = jnp.ones((512, 8), jnp.bfloat16)
    with tsmm.policy(executor="shard_map-scatter"):
        with pytest.raises(RuntimeError, match="only applies to tsmm_t"):
            tsmm.tsmm(a, b)


def test_derive_dp_axes_rules():
    am = compat.abstract_mesh
    # single non-model-named axis is DP, whatever the name
    assert tsmm.derive_dp_axes(am((8,), ("anything",))) == ("anything",)
    # ...but a lone model-named axis is pure TP, never DP
    assert tsmm.derive_dp_axes(am((8,), ("model",))) == ()
    assert tsmm.derive_dp_axes(am((8,), ("tp",))) == ()
    # conventional names win, mesh order preserved
    assert tsmm.derive_dp_axes(am((2, 4, 2), ("pod", "data", "model"))) \
        == ("pod", "data")
    assert tsmm.derive_dp_axes(am((4, 2), ("batch", "model"))) == ("batch",)
    # no conventional name: everything not model/pipeline-named is DP
    assert tsmm.derive_dp_axes(am((4, 2), ("nodes", "tensor"))) == ("nodes",)
    # pure model/pipe mesh: no DP axes at all
    assert tsmm.derive_dp_axes(am((4, 2), ("model", "pipe"))) == ()
    # distributed.sharding shares the derivation
    from repro.distributed import sharding
    assert sharding.dp_axes(am((2, 2), ("replica", "model"))) == ("replica",)


def test_reduce_has_no_effect_off_mesh():
    a = jnp.ones((4096, 512), jnp.bfloat16)
    b = jnp.ones((512, 8), jnp.bfloat16)
    with tsmm.policy(reduce="psum_scatter"):
        with tsmm.record_dispatches() as log:
            jax.jit(lambda a_, b_: tsmm.tsmm(a_, b_)).lower(a, b)
    assert {e.executor for e in log} == {"pallas-tpu"}


def test_executor_pin_collective_mismatch_raises():
    """A pinned shard_map executor must refuse a mismatched reduce= rather
    than silently changing the output layout the scope asked for."""
    from jax.sharding import Mesh

    x = jnp.ones((4096, 64), jnp.float32)
    y = jnp.ones((4096, 8), jnp.float32)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    with mesh:
        with tsmm.policy(executor="shard_map", reduce="psum_scatter"):
            with pytest.raises(RuntimeError, match="shard_map-scatter"):
                tsmm.tsmm_t(x, y)
        with tsmm.policy(executor="shard_map-scatter"):  # default psum
            with pytest.raises(RuntimeError, match="psum_scatter"):
                tsmm.tsmm_t(x, y)
