"""Parameter-chooser contracts: candidate enumeration, VMEM feasibility,
and the documented tie-break rule (ties toward *deeper* pipelines along
the streamed/reduction axis), applied uniformly to all three choosers.

A zero-overhead spec (step_overhead = dma_latency = 0) collapses the
latency term, making every reduction-axis block size model-time-equal --
the exact boundary the tie-break rule governs. The old code preferred
*larger* block_k on ties (shallower grids) and never applied any rule to
the tsm2l/tsmt choosers.
"""

import dataclasses

import jax.numpy as jnp
import pytest

from repro.core import perf_model

ZERO_LAT = dataclasses.replace(perf_model.V5E, step_overhead=0.0,
                               dma_latency=0.0)


# ---------------------------------------------------------------------------
# Tie-break boundaries
# ---------------------------------------------------------------------------

def test_tsm2r_ties_break_toward_deeper_k_pipeline():
    """With latency terms zeroed, every feasible block_k ties (the B-refetch
    term depends only on block_m): the chooser must take the smallest
    block_k -- the deepest k-pipeline -- not the largest. Splitting is
    never a tie on a single-core spec: S > 1 adds partials traffic for no
    occupancy gain, so S == 1 wins strictly."""
    m, k, n = 8192, 2048, 8
    bm, bk, s = perf_model.choose_params_tsm2r(m, k, n, ZERO_LAT,
                                               jnp.bfloat16)
    cands = perf_model.tsm2r_candidates(m, k, n, ZERO_LAT, jnp.bfloat16)
    assert bk == min(c[1] for c in cands) == 128
    # Residual tie on block_m resolved toward fewer B-window re-fetches:
    # b_bytes scales with ceil(m/bm), so the largest bm wins *strictly*.
    assert bm == 4096
    assert s == 1


def test_tsm2r_no_tie_still_prefers_fewer_steps():
    """With real latency terms, fewer grid steps win outright -- the
    tie-break must not override a strict model-time ordering."""
    bm, bk, s = perf_model.choose_params_tsm2r(4096, 1024, 8, perf_model.V5E,
                                               jnp.bfloat16)
    assert (bm, bk, s) == (4096, 1024, 1)


def test_tsm2l_ties_break_toward_deeper_m_pipeline():
    m, k, n = 16384, 16, 16
    bm = perf_model.choose_params_tsm2l(m, k, n, ZERO_LAT, jnp.bfloat16)
    assert bm == min(perf_model.tsm2l_candidates(m, k, n, ZERO_LAT,
                                                 jnp.bfloat16)) == 256


def test_tsmt_ties_break_toward_deeper_reduction_pipeline():
    """m is the streamed reduction for TSMT: ties on block_m go to the
    smallest; block_a is resolved strictly (fewer Y re-fetches); S == 1
    wins strictly on a single-core spec (partials cost, no occupancy)."""
    m, a, b = 4096, 1024, 8
    bm, ba, s = perf_model.choose_params_tsmt(m, a, b, ZERO_LAT,
                                              jnp.bfloat16)
    assert bm == 256
    assert ba == max(c[1] for c in perf_model.tsmt_candidates(
        m, a, b, ZERO_LAT, jnp.bfloat16)) == 1024
    assert s == 1


# ---------------------------------------------------------------------------
# Candidate enumeration (the grid the autotuner shares)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,args", [
    ("tsm2r", (20480, 20480, 16)),
    ("tsm2l", (1_000_000, 16, 16)),
    ("tsmt", (8192, 128, 8)),
])
def test_choice_is_always_a_candidate(kind, args):
    cands = getattr(perf_model, f"{kind}_candidates")(*args)
    choice = getattr(perf_model, f"choose_params_{kind}")(*args)
    assert choice in cands


def test_candidates_respect_vmem_budget():
    budget = perf_model.V5E.vmem_bytes * perf_model.V5E.vmem_usable
    for bm, bk, _ in perf_model.tsm2r_candidates(30720, 30720, 16):
        assert perf_model.tsm2r_vmem_usage(bm, bk, 16, jnp.bfloat16) <= budget
    for bm in perf_model.tsm2l_candidates(1_000_000, 16, 16):
        assert perf_model.tsm2l_vmem_usage(bm, 16, 16, jnp.bfloat16) <= budget
    for bm, ba, _ in perf_model.tsmt_candidates(8192, 512, 8):
        assert perf_model.tsmt_vmem_usage(bm, ba, 8, jnp.bfloat16) <= budget


def test_candidates_respect_shape_quantization():
    """No candidate exceeds the lane/sublane roundup of the actual dims --
    the same filter kernels/ops.py clamps the runtime blocks with."""
    for bm, bk, _ in perf_model.tsm2r_candidates(4096, 130, 8):
        assert bm <= 4096
        assert bk <= perf_model._roundup(130, perf_model.V5E.lane) == 256


def test_tiny_shape_falls_back_to_single_block():
    assert perf_model.tsm2r_candidates(64, 64, 4) == []
    bm, bk, s = perf_model.choose_params_tsm2r(64, 64, 4)
    assert (bm, bk, s) == (64, 128, 1)


# ---------------------------------------------------------------------------
# Occupancy + split-reduction (the split-K dimension of the search)
# ---------------------------------------------------------------------------

def test_split_candidates_keep_whole_reduction_slices():
    """S > 1 is only enumerated when every slice owns >= one full block of
    the reduction axis -- deeper splits would be pure zero-padding."""
    for bm, bk, s in perf_model.tsm2r_candidates(8192, 512, 8):
        assert s == 1 or s * bk <= perf_model._roundup(512, 128)
    for bm, ba, s in perf_model.tsmt_candidates(4096, 64, 8):
        assert s == 1 or s * bm <= perf_model._roundup(4096, 8)
    # and S > 1 IS reachable on both grids
    assert any(s > 1 for *_, s in perf_model.tsm2r_candidates(8192, 512, 8))
    assert any(s > 1 for *_, s in perf_model.tsmt_candidates(4096, 64, 8))


def test_occupancy_term():
    assert perf_model.occupancy(1, perf_model.V5E) == 1.0
    assert perf_model.occupancy(1, perf_model.V5P) == 0.5
    assert perf_model.occupancy(2, perf_model.V5P) == 1.0
    assert perf_model.occupancy(64, perf_model.V5P) == 1.0


def test_occupancy_model_selects_split_for_powersgd_shape():
    """The ISSUE's headline case: a PowerSGD-shaped tsmt (huge m, a = b =
    16) collapses to ONE parallel grid cell, so on the 2-core v5p the
    occupancy-aware argmin must split the reduction; the single-core v5e
    never pays the partials traffic for nothing."""
    m, a, b = 1 << 20, 16, 16
    bm_p, ba_p, s_p = perf_model.choose_params_tsmt(m, a, b, perf_model.V5P,
                                                    jnp.float32)
    assert s_p > 1, (bm_p, ba_p, s_p)
    # modeled time actually improves vs the sequential choice
    t_split = perf_model.tsmt_model_time(m, a, b, bm_p, ba_p,
                                         perf_model.V5P, jnp.float32,
                                         splits=s_p)
    t_seq = perf_model.tsmt_model_time(m, a, b, bm_p, ba_p, perf_model.V5P,
                                       jnp.float32, splits=1)
    assert t_split < t_seq
    *_, s_e = perf_model.choose_params_tsmt(m, a, b, perf_model.V5E,
                                            jnp.float32)
    assert s_e == 1


def test_split_partials_traffic_is_priced():
    """S = 1 must model zero partials bytes; S > 1 must cost more memory
    time at equal occupancy (same spec, parallel cells already >= cores)."""
    assert perf_model.split_partials_bytes(1, 4096, 8) == 0
    assert perf_model.split_partials_bytes(4, 4096, 8) > 0
    # m/bm = 8 parallel cells saturate even v5p's 2 cores: splitting can
    # only add partial-stack traffic, so modeled time must not improve.
    t1 = perf_model.tsm2r_model_time(2048, 2048, 8, 256, 128,
                                     perf_model.V5P, jnp.bfloat16, splits=1)
    t4 = perf_model.tsm2r_model_time(2048, 2048, 8, 256, 128,
                                     perf_model.V5P, jnp.bfloat16, splits=4)
    assert t4 >= t1
