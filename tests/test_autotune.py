"""Autotuner subsystem: TuningTable round-trip + key stability, the
``GemmPolicy.tuning_table`` override of the analytic block choice
(asserted via a kernel-kwargs spy), measured autotuning + calibration on
synthetic timings, and an interpret-mode smoke of ``benchmarks.run
--autotune``.
"""

import dataclasses
import importlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, perf_model, tsmm
from repro.kernels import ops, ref


def _rand(seed, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


def _record(kind="tsm2r", shape=(4096, 1024, 8), dtype="float32",
            spec="tpu_v5e", executor="interpret", params=None,
            model_pick=None):
    params = params or {"block_m": 256, "block_k": 128}
    return autotune.TuningRecord(
        kind=kind, bucket=autotune.bucket_shape(*shape), dtype=dtype,
        spec_name=spec, executor=executor, shape=shape,
        params=tuple(sorted(params.items())), measured_us=120.0,
        model_us=100.0, model_error=0.2,
        model_pick=tuple(sorted((model_pick or params).items())),
        model_pick_measured_us=150.0)


# ---------------------------------------------------------------------------
# Bucketing + keys
# ---------------------------------------------------------------------------

def test_bucket_dim_scheme():
    # <= one lane tile: exact (skinny dims flip kernel choice sharply)
    assert [autotune.bucket_dim(d) for d in (1, 8, 100, 128)] == [1, 8, 100, 128]
    # above: next power of two
    assert autotune.bucket_dim(129) == 256
    assert autotune.bucket_dim(4096) == 4096
    assert autotune.bucket_dim(20480) == 32768


def test_record_key_stability():
    """The on-disk key format is an API: loaders from other processes /
    commits must produce identical keys for identical cells."""
    key = autotune.record_key("tsm2r", autotune.bucket_shape(20480, 20480, 16),
                              "bfloat16", "tpu_v5e", "pallas-tpu")
    assert key == "tsm2r|32768x32768x16|bfloat16|tpu_v5e|pallas-tpu"
    assert _record().key == "tsm2r|4096x1024x8|float32|tpu_v5e|interpret"


def test_table_roundtrip_and_lookup(tmp_path):
    rec = _record()
    tbl = autotune.TuningTable.from_records([rec])
    path = tmp_path / "table.json"
    tbl.save(path)
    data = json.loads(path.read_text())
    assert data["schema"] == autotune.TABLE_SCHEMA
    assert data["records"][0]["key"] == rec.key
    loaded = autotune.TuningTable.load(path)
    assert loaded == tbl
    # lookup buckets the query shape: any shape in the bucket hits.
    hit = loaded.lookup("tsm2r", 3000, 1000, 8, dtype=jnp.float32,
                        spec="tpu_v5e", executor="interpret")
    assert hit == rec and hit.params_dict == {"block_m": 256, "block_k": 128}
    assert loaded.lookup("tsm2r", 3000, 1000, 16, dtype=jnp.float32,
                         spec="tpu_v5e", executor="interpret") is None
    assert loaded.lookup("tsm2r", 3000, 1000, 8, dtype=jnp.float32,
                         spec="tpu_v5e", executor="pallas-tpu") is None


def test_table_add_replaces_same_key():
    tbl = autotune.TuningTable.from_records([_record()])
    newer = _record(params={"block_m": 512, "block_k": 256})
    tbl2 = tbl.add(newer)
    assert len(tbl2.records) == 1
    assert tbl2.records[0].params_dict == {"block_m": 512, "block_k": 256}
    assert len(tbl.records) == 1  # original untouched (immutable)


def test_table_is_hashable_on_policy():
    """The table rides through custom_vjp nondiff args on the policy."""
    tbl = autotune.TuningTable.from_records([_record()])
    pol = tsmm.GemmPolicy(tuning_table=tbl)
    assert hash(pol) == hash(tsmm.GemmPolicy(tuning_table=tbl))
    assert pol != tsmm.GemmPolicy()


def test_from_json_rejects_foreign_schema():
    with pytest.raises(ValueError, match="not a tuning table"):
        autotune.TuningTable.from_json({"schema": "repro-tsm2x-bench/1",
                                        "records": []})


# ---------------------------------------------------------------------------
# tuning_table overrides the analytic choice (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.fixture
def tsm2r_spy(monkeypatch):
    seen = []
    orig = ops.tsm2r_pallas

    def spy(a, b, *, block_m, block_k, interpret=None):
        seen.append({"block_m": block_m, "block_k": block_k})
        return orig(a, b, block_m=block_m, block_k=block_k,
                    interpret=interpret)

    monkeypatch.setattr(ops, "tsm2r_pallas", spy)
    return seen


def test_tuning_table_overrides_analytic_choice(tsm2r_spy):
    m, k, n = 4096, 1024, 8
    a, b = _rand(0, (m, k)), _rand(1, (k, n))
    analytic = perf_model.choose_params_tsm2r(m, k, n, perf_model.V5E,
                                              a.dtype)
    tuned = {"block_m": 256, "block_k": 128}
    assert tuned != dict(zip(("block_m", "block_k"), analytic))
    tbl = autotune.TuningTable.from_records(
        [_record(shape=(m, k, n), params=tuned)])

    with tsmm.policy(tuning_table=tbl, interpret=True):
        got = tsmm.tsmm(a, b)
    assert tsm2r_spy[-1] == tuned
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.tsm2r_ref(a, b)),
                               rtol=1e-4, atol=1e-4)

    # same call without the table: analytic params, same numerics.
    with tsmm.policy(interpret=True):
        tsmm.tsmm(a, b)
    assert tuple(tsm2r_spy[-1].values()) == analytic[:2]


def test_explicit_block_kwargs_beat_table(tsm2r_spy):
    m, k, n = 4096, 1024, 8
    a, b = _rand(2, (m, k)), _rand(3, (k, n))
    tbl = autotune.TuningTable.from_records(
        [_record(shape=(m, k, n), params={"block_m": 256, "block_k": 128})])
    with tsmm.policy(tuning_table=tbl, interpret=True):
        ops.tsm2r(a, b, block_m=512, block_k=256)
    assert tsm2r_spy[-1] == {"block_m": 512, "block_k": 256}


def test_table_miss_on_other_executor_falls_back(tsm2r_spy):
    """A table tuned for pallas-tpu must not drive interpret-mode calls."""
    m, k, n = 4096, 1024, 8
    a, b = _rand(4, (m, k)), _rand(5, (k, n))
    tbl = autotune.TuningTable.from_records(
        [_record(shape=(m, k, n), executor="pallas-tpu",
                 params={"block_m": 256, "block_k": 128})])
    analytic = perf_model.choose_params_tsm2r(m, k, n, perf_model.V5E,
                                              a.dtype)
    with tsmm.policy(tuning_table=tbl, interpret=True):
        tsmm.tsmm(a, b)
    assert tuple(tsm2r_spy[-1].values()) == analytic[:2]


# ---------------------------------------------------------------------------
# Schema back-compat (v1 tables: no "splits" param, no "fits" block)
# ---------------------------------------------------------------------------

def _v1_payload(m=4096, k=1024, n=8):
    return {
        "schema": "repro-tsm2x-tuning/1",
        "records": [{
            "key": "ignored-on-load",
            "kind": "tsm2r", "bucket": [m, k, n], "dtype": "float32",
            "spec": "tpu_v5e", "executor": "interpret", "shape": [m, k, n],
            "params": {"block_m": 256, "block_k": 128},
            "measured_us": 10.0, "model_us": 9.0, "model_error": 0.1,
            "model_pick": {"block_m": 256, "block_k": 128},
            "model_pick_measured_us": 10.0,
        }],
    }


def test_v1_table_loads_and_defaults_to_sequential(tsm2r_spy):
    """Pre-split tables (schema /1) must keep loading; their records carry
    no "splits" key, so consumption runs the sequential kernel they
    actually measured -- and fitted_spec is the identity."""
    tbl = autotune.TuningTable.from_json(_v1_payload())
    rec = tbl.lookup("tsm2r", 4096, 1024, 8, dtype=jnp.float32,
                     spec="tpu_v5e", executor="interpret")
    assert rec is not None and "splits" not in rec.params_dict
    assert tbl.fitted_spec("tsm2r", 4096, 1024, 8, dtype=jnp.float32,
                           spec=perf_model.V5E) == perf_model.V5E
    a, b = _rand(10, (4096, 1024)), _rand(11, (1024, 8))
    with tsmm.policy(tuning_table=tbl, interpret=True):
        got = tsmm.tsmm(a, b)
    assert tsm2r_spy[-1] == {"block_m": 256, "block_k": 128}  # sequential
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.tsm2r_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


def test_table_roundtrips_splits_and_fits(tmp_path):
    """The v2 additions survive save/load: splits in record params, the
    per-bucket + global fits block."""
    rec = _record(params={"block_m": 256, "block_k": 128, "splits": 4})
    fits = (
        autotune.SpecFit("tsm2r", autotune.bucket_shape(4096, 1024, 8),
                         "float32", "tpu_v5e", 1e-6, 2e-6,
                         vmem_usable=0.75),
        autotune.SpecFit(*autotune.GLOBAL_FIT, "tpu_v5e", 3e-7, 1.5e-6),
    )
    tbl = autotune.TuningTable.from_records([rec], fits)
    path = tmp_path / "v2.json"
    tbl.save(path)
    loaded = autotune.TuningTable.load(path)
    assert loaded == tbl
    hit = loaded.lookup("tsm2r", 4096, 1024, 8, dtype=jnp.float32,
                        spec="tpu_v5e", executor="interpret")
    assert hit.params_dict["splits"] == 4
    # bucket-local fit wins over the global cell; off-bucket gets global
    local = loaded.fitted_spec("tsm2r", 4096, 1024, 8, dtype=jnp.float32,
                               spec=perf_model.V5E)
    assert (local.step_overhead, local.dma_latency) == (1e-6, 2e-6)
    # the fitted vmem budget rides along (and only ever widens)
    assert local.vmem_usable == 0.75
    other = loaded.fitted_spec("tsmt", 65536, 64, 8, dtype=jnp.float32,
                               spec=perf_model.V5E)
    assert (other.step_overhead, other.dma_latency) == (3e-7, 1.5e-6)
    # the global cell carries no vmem correction: budget untouched
    assert other.vmem_usable == perf_model.V5E.vmem_usable


def test_bucket_fit_drives_analytic_choice(tsm2r_spy):
    """A table with NO record for the bucket but a bucket-local fit must
    run the analytic chooser under the fitted constants: a zero-latency
    fit flips the tsm2r tie-break to the deepest k-pipeline (bk=128),
    which the stock V5E constants would never pick for this shape."""
    m, k, n = 4096, 1024, 8
    stock = perf_model.choose_params_tsm2r(m, k, n, perf_model.V5E,
                                           jnp.float32)
    fit = autotune.SpecFit("tsm2r", autotune.bucket_shape(m, k, n),
                           "float32", "tpu_v5e", 0.0, 0.0)
    tbl = autotune.TuningTable.from_records([], [fit])
    a, b = _rand(12, (m, k)), _rand(13, (k, n))
    with tsmm.policy(tuning_table=tbl, interpret=True):
        tsmm.tsmm(a, b)
    assert tsm2r_spy[-1]["block_k"] == 128 != stock[1]


def test_calibrate_populates_per_bucket_fits():
    pol = tsmm.GemmPolicy(interpret=True)
    res = autotune.calibrate([("tsm2r", 1024, 256, 8), ("tsmt", 1024, 64, 8)],
                             dtype=jnp.float32, policy=pol, reps=1, warmup=0)
    fits = {(f.kind, f.bucket) for f in res.table.fits}
    assert ("*", (0, 0, 0)) in fits              # the global cell
    assert ("tsm2r", autotune.bucket_shape(1024, 256, 8)) in fits
    assert ("tsmt", autotune.bucket_shape(1024, 64, 8)) in fits
    # the table stays policy-hashable with fits attached
    assert hash(tsmm.GemmPolicy(tuning_table=res.table)) is not None


# ---------------------------------------------------------------------------
# Measured autotuning (interpret mode, tiny shapes)
# ---------------------------------------------------------------------------

def test_autotune_shape_produces_consistent_record():
    pol = tsmm.GemmPolicy(interpret=True)
    rec = autotune.autotune_shape("tsm2r", 1024, 256, 8, dtype=jnp.float32,
                                  policy=pol, reps=1, warmup=0)
    assert rec.kind == "tsm2r" and rec.executor == "interpret"
    assert rec.shape == (1024, 256, 8)
    cands = perf_model.tsm2r_candidates(1024, 256, 8, pol.spec, jnp.float32)
    assert tuple(rec.params_dict[k]
                 for k in ("block_m", "block_k", "splits")) in cands
    assert rec.measured_us > 0 and rec.model_error >= 0
    assert rec.model_pick_measured_us > 0  # the analytic pick was timed too
    tbl = autotune.TuningTable.from_records([rec])
    assert tbl.lookup("tsm2r", 1024, 256, 8, dtype=jnp.float32,
                      spec=pol.spec.name, executor="interpret") == rec


def test_autotune_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown kernel kind"):
        autotune.autotune_shape("tsmr", 1024, 256, 8)


def test_explore_vmem_widens_the_measured_search():
    """The measured search must be able to probe past the model's VMEM
    feasibility filter -- otherwise a model-pruned winner can never be
    observed and fit_spec's vmem_usable correction is unreachable."""
    tight = dataclasses.replace(perf_model.V5E, vmem_usable=0.02)
    strict, _, pick = autotune._kind_plan("tsm2r", 8192, 4096, 8, tight,
                                          jnp.bfloat16)
    explored, _, _ = autotune._kind_plan("tsm2r", 8192, 4096, 8, tight,
                                         jnp.bfloat16, explore_vmem=4.0)
    assert set(map(tuple, (c.items() for c in strict))) < \
        set(map(tuple, (c.items() for c in explored)))
    budget = tight.vmem_bytes * tight.vmem_usable
    over = [c for c in explored
            if perf_model.tsm2r_vmem_usage(c["block_m"], c["block_k"], 8,
                                           jnp.bfloat16) > budget]
    assert over, "explored set must contain strictly-over-budget configs"
    assert pick in strict or strict == []


def test_build_table_warns_on_bucket_collision():
    pol = tsmm.GemmPolicy(interpret=True)
    with pytest.warns(UserWarning, match="share table bucket"):
        tbl = autotune.build_table(
            [("tsm2r", 2000, 512, 8), ("tsm2r", 1500, 512, 8)],
            dtype=jnp.float32, policy=pol, reps=1, warmup=0)
    assert len(tbl.records) == 1  # merged: the faster winner survives


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def _synthetic_observations(true_spec):
    obs = []
    for m, k, n, bm, bk in [(4096, 4096, 8, 256, 128),
                            (4096, 4096, 8, 1024, 512),
                            (8192, 2048, 16, 512, 128),
                            (2048, 2048, 8, 256, 256)]:
        t = perf_model.tsm2r_model_time(m, k, n, bm, bk, true_spec,
                                        jnp.bfloat16)
        obs.append(autotune.Observation(
            "tsm2r", m, k, n, "bfloat16",
            (("block_k", bk), ("block_m", bm)), t))
    for m, bm in [(1_000_000, 256), (1_000_000, 4096)]:
        t = perf_model.tsm2l_model_time(m, 16, 16, bm, true_spec, jnp.bfloat16)
        obs.append(autotune.Observation("tsm2l", m, 16, 16, "bfloat16",
                                        (("block_m", bm),), t))
    return obs


def test_calibrate_reduces_model_error_on_synthetic_timings():
    """Timings generated from a spec with 8x step overhead / 4x DMA latency:
    fitting must recover the scales and collapse the error."""
    true_spec = dataclasses.replace(perf_model.V5E,
                                    step_overhead=perf_model.V5E.step_overhead * 8,
                                    dma_latency=perf_model.V5E.dma_latency * 4)
    obs = _synthetic_observations(true_spec)
    result = autotune.fit_spec(perf_model.V5E, obs)
    assert result.error_before > 0.05
    assert result.error_after < result.error_before * 0.2
    assert result.spec.step_overhead > perf_model.V5E.step_overhead
    assert result.spec.dma_latency > perf_model.V5E.dma_latency


def test_fit_spec_raises_vmem_usable_for_measured_winners():
    """A measured winner the modeled budget would have pruned proves the
    budget too conservative: vmem_usable is raised minimally to admit it."""
    tight = dataclasses.replace(perf_model.V5E, vmem_usable=0.01)
    obs = [autotune.Observation(
        "tsm2r", 8192, 8192, 8, "bfloat16",
        (("block_k", 2048), ("block_m", 4096)),
        perf_model.tsm2r_model_time(8192, 8192, 8, 4096, 2048))]
    need = obs[0].vmem_bytes() / tight.vmem_bytes
    result = autotune.fit_spec(tight, obs, fit=())
    assert result.spec.vmem_usable == pytest.approx(need)


def test_fit_spec_empty_observations_is_identity():
    result = autotune.fit_spec(perf_model.V5E, [])
    assert result.spec == perf_model.V5E
    assert result.error_before == result.error_after == 0.0


def test_calibrate_base_table_merges_records_and_ages_out_fits():
    """Partial re-calibration: ``calibrate(base_table=...)`` keeps base
    records (new measurements win shared buckets) but drops the base's
    SpecFit cells -- stale fitted constants from an older run must not
    keep steering the analytic chooser."""
    pol = tsmm.GemmPolicy(interpret=True)
    base = autotune.calibrate([("tsm2r", 1024, 256, 8),
                               ("tsm2l", 1024, 16, 16)],
                              dtype=jnp.float32, policy=pol,
                              reps=1, warmup=0).table
    # poison one base fit so survival would be observable
    stale = autotune.SpecFit("tsm2l", autotune.bucket_shape(1024, 16, 16),
                             "float32", pol.spec.name,
                             step_overhead=123.0, dma_latency=456.0)
    base = autotune.TuningTable(records=base.records, fits=(stale,))

    res = autotune.calibrate([("tsm2r", 1024, 256, 8)], dtype=jnp.float32,
                             policy=pol, reps=1, warmup=0, base_table=base)
    keys = {r.key for r in res.table.records}
    # the un-remeasured base record survives; the shared bucket is replaced
    assert any(k.startswith("tsm2l|") for k in keys)
    assert any(k.startswith("tsm2r|") for k in keys)
    new_rec = next(r for r in res.table.records if r.kind == "tsm2r")
    assert new_rec.shape == (1024, 256, 8)
    # every fit comes from THIS run: the poisoned tsm2l cell is gone
    assert all(f.step_overhead != 123.0 for f in res.table.fits)
    fit_kinds = {f.kind for f in res.table.fits}
    assert "tsm2l" not in fit_kinds and "tsm2r" in fit_kinds
    assert ("*", (0, 0, 0)) in {(f.kind, f.bucket) for f in res.table.fits}


# ---------------------------------------------------------------------------
# benchmarks.run --autotune smoke (interpret mode)
# ---------------------------------------------------------------------------

def _import_bench_run():
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root))
    try:
        return importlib.import_module("benchmarks.run")
    finally:
        sys.path.remove(str(root))


def test_run_autotune_smoke(tmp_path):
    run_mod = _import_bench_run()
    out = tmp_path / "BENCH_smoke.json"
    run_mod.main(["--json", str(out), "--autotune",
                  "--autotune-shapes", "tsm2r:1024,256,8",
                  "--sections", "Table3/4"])
    report = json.loads(out.read_text())
    at = report["autotune"]
    assert at["table"]["records"], "autotune table must not be empty"
    assert at["model_error"] and all("model_error" in e
                                     for e in at["model_error"])
    assert {"error_before", "error_after", "fitted"} <= set(at["calibration"])
    sanity = report["dispatch_sanity"]
    assert sanity and all(s["ok"] for s in sanity)
    # the tuned table round-trips through the public loader
    tbl = autotune.TuningTable.from_json(at["table"])
    assert tbl.lookup("tsm2r", 1024, 256, 8, dtype=jnp.float32,
                      spec="tpu_v5e", executor="interpret") is not None


def test_parse_autotune_shapes_errors():
    run_mod = _import_bench_run()
    assert run_mod.parse_autotune_shapes("tsm2r:4096,1024,8;tsm2l:8192,16,16") \
        == [("tsm2r", 4096, 1024, 8), ("tsm2l", 8192, 16, 16)]
    with pytest.raises(SystemExit):
        run_mod.parse_autotune_shapes("tsm2r:oops")
