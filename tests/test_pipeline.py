"""GPipe pipeline: schedule correctness vs sequential application."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import pipeline


def _layer_fn(lp, x):
    return jnp.tanh(x @ lp["w"] + lp["b"])


def _stack_params(key, n_layers, d):
    ks = jax.random.split(key, n_layers)
    return {
        "w": jnp.stack([jax.random.normal(k, (d, d)) * 0.5 for k in ks]),
        "b": jnp.zeros((n_layers, d)),
    }


def _sequential(params, x):
    def body(h, lp):
        return _layer_fn(lp, h), None
    out, _ = jax.lax.scan(body, x, params)
    return out


@pytest.mark.parametrize("n_layers,n_stages,n_micro", [
    (8, 4, 4), (8, 2, 3), (6, 3, 1), (4, 4, 5),
])
def test_pipeline_matches_sequential(n_layers, n_stages, n_micro):
    d, b = 16, 4
    params = _stack_params(jax.random.PRNGKey(0), n_layers, d)
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, b, d))

    want = jax.vmap(lambda mb: _sequential(params, mb))(x)

    stages = pipeline.split_stages(params, n_stages)
    stage_fn = pipeline.make_stage_fn(_layer_fn)
    got = jax.jit(lambda sp, mb: pipeline.pipeline_apply(
        stage_fn, sp, mb, stage_axis=None))(stages, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pipeline_gradients_flow():
    d = 8
    params = _stack_params(jax.random.PRNGKey(2), 4, d)
    stages = pipeline.split_stages(params, 2)
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 2, d))
    stage_fn = pipeline.make_stage_fn(_layer_fn)

    def loss(sp):
        out = pipeline.pipeline_apply(stage_fn, sp, x, stage_axis=None)
        return jnp.sum(out ** 2)

    g = jax.grad(loss)(stages)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
        assert float(jnp.abs(leaf).max()) > 0
