"""Train-loop rollback/retry: an injected mid-run fault must roll back to
the last good snapshot and reconverge to the fault-free trajectory, and
the checkpoint escalation path must survive when no snapshot exists."""

import pytest

from repro.launch import train

_BASE = ["--arch", "llama3.2-3b", "--smoke", "--global-batch", "4",
         "--seq-len", "32", "--log-every", "100"]


def _run(*extra, steps=6):
    return train.main(_BASE + ["--steps", str(steps)] + list(extra))


@pytest.fixture(scope="module")
def clean_metrics():
    return _run()


def test_chaos_rollback_reconverges(clean_metrics):
    """The headline acceptance test: a one-shot NaN injected into the
    params before step 3 trips step_ok, rolls back to the step-2
    snapshot, replays, and finishes with EXACTLY the clean run's final
    loss (deterministic data + deterministic compute => bit-equal
    trajectory after rollback)."""
    chaos = _run("--chaos-step", "3")
    assert chaos["fault_events"] == 1
    assert chaos["fault_retries"] == 1
    assert chaos["final_loss"] == clean_metrics["final_loss"]


def test_clean_run_has_no_retries(clean_metrics):
    assert clean_metrics["fault_retries"] == 0
    assert clean_metrics["fault_events"] == 0


def test_chaos_escalates_to_checkpoint(tmp_path, clean_metrics):
    """With snapshots disabled the fault must escalate to
    Checkpointer.restore_latest_good and still reconverge."""
    chaos = _run("--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
                 "--snapshot-every", "0", "--chaos-step", "4")
    assert chaos["fault_retries"] == 1
    assert chaos["final_loss"] == clean_metrics["final_loss"]


def test_fault_with_no_recovery_path_raises():
    with pytest.raises(RuntimeError, match=r"\[ft-retries\]"):
        _run("--snapshot-every", "0", "--chaos-step", "2")


def test_online_abft_scope_trains(clean_metrics):
    """--abft verify wraps every training GEMM in the checksum guard; a
    clean run must be unaffected (same final loss as unguarded)."""
    guarded = _run("--abft", "verify", steps=3)
    plain = _run(steps=3)
    assert guarded["final_loss"] == plain["final_loss"]
