"""Contract layer (`repro.analysis.contracts`) + offline auditor.

Three tiers:
* predicate units -- one test per contract rule, with the numbers pinned;
* agreement -- the perf model's candidate grids, the choosers, and
  ``ops.resolve_params`` all stay inside the contract set, and resolved
  configs actually RUN (interpret mode) and match the jnp oracle;
* acceptance -- the auditor provably rejects seeded violations (an
  over-budget tuning entry, a non-lane-quantized block, an indivisible
  psum_scatter axis) and passes clean on the committed tree.
"""

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import audit, contracts
from repro.core import autotune, perf_model, tsmm
from repro.kernels import ops, ref

V5E = perf_model.V5E
F32 = jnp.float32


def _rules(violations):
    return [v.rule for v in violations]


# ---------------------------------------------------------------------------
# Predicate units: one per rule
# ---------------------------------------------------------------------------

def test_footprints_are_the_single_source():
    """perf_model's vmem_usage functions are aliases of the contract
    footprints -- the PR-3 drift class (two copies of the math) is gone."""
    assert (perf_model.tsm2r_vmem_usage(1024, 512, 8, F32)
            == contracts.tsm2r_footprint(1024, 512, 8, F32))
    assert (perf_model.tsm2l_vmem_usage(4096, 16, 16, F32)
            == contracts.tsm2l_footprint(4096, 16, 16, F32))
    assert (perf_model.tsmt_vmem_usage(2048, 128, 8, F32)
            == contracts.tsmt_footprint(2048, 128, 8, F32))
    # pinned value: 2*bm*bk*4 + 2*bk*128*4 + bm*128*4 + bm*128*4
    assert contracts.tsm2r_footprint(256, 128, 8, F32) == (
        2 * 256 * 128 * 4 + 2 * 128 * 128 * 4 + 256 * 128 * 4
        + 256 * 128 * 4)


def test_lane_quant_violation():
    vios = contracts.check_kernel_config(
        "tsm2r", (4096, 512, 8), {"block_m": 256, "block_k": 130}, F32, V5E)
    assert "lane-quant" in _rules(vios)
    assert not contracts.feasible(
        "tsm2r", (4096, 512, 8), {"block_m": 256, "block_k": 130}, F32, V5E)


def test_sublane_quant_violation():
    vios = contracts.check_kernel_config(
        "tsm2r", (4096, 512, 8), {"block_m": 100, "block_k": 128}, F32, V5E)
    assert "sublane-quant" in _rules(vios)


def test_vmem_budget_violation():
    tight = dataclasses.replace(V5E, vmem_usable=0.01)
    vios = contracts.check_kernel_config(
        "tsm2r", (8192, 4096, 8), {"block_m": 4096, "block_k": 2048},
        F32, tight)
    assert "vmem-budget" in _rules(vios)


def test_block_exceeds_dim_violation():
    vios = contracts.check_kernel_config(
        "tsm2r", (1000, 100, 8), {"block_m": 2048, "block_k": 128}, F32, V5E)
    assert "block-exceeds-dim" in _rules(vios)
    # block_k past ceil_mult(k, 128) is pure padding too
    vios = contracts.check_kernel_config(
        "tsm2r", (4096, 100, 8), {"block_m": 256, "block_k": 256}, F32, V5E)
    assert "block-exceeds-dim" in _rules(vios)


def test_split_whole_slice_violation():
    # k=512: 4 slices x block_k=256 = 1024 > ceil_mult(512, 128)
    vios = contracts.check_kernel_config(
        "tsm2r", (4096, 512, 8),
        {"block_m": 256, "block_k": 256, "splits": 4}, F32, V5E)
    assert "split-whole-slice" in _rules(vios)
    # 2 slices x 256 == 512: exactly whole, legal
    assert contracts.feasible(
        "tsm2r", (4096, 512, 8),
        {"block_m": 256, "block_k": 256, "splits": 2}, F32, V5E)


def test_tsm2l_split_unsupported():
    vios = contracts.check_kernel_config(
        "tsm2l", (65536, 16, 16), {"block_m": 4096, "splits": 2}, F32, V5E)
    assert "split-unsupported" in _rules(vios)


def test_accumulator_limit_is_not_a_candidate_filter():
    """The TSMT b-limit is a dispatch contract on the shape: the checker
    reports it, but ``feasible`` (the candidate filter) must NOT prune on
    it -- the enumerated grid the model scores stays shape-independent."""
    params = {"block_m": 256, "block_a": 128, "splits": 1}
    shape = (65536, 128, contracts.TSMT_MAX_B + 1)
    assert "accumulator-limit" in _rules(
        contracts.check_kernel_config("tsmt", shape, params, F32, V5E))
    assert contracts.feasible("tsmt", shape, params, F32, V5E)
    # a max_skinny_t-style override raises the limit
    assert "accumulator-limit" not in _rules(contracts.check_kernel_config(
        "tsmt", shape, params, F32, V5E, max_b=1024))


def test_param_schema_violations():
    assert _rules(contracts.check_kernel_config(
        "tsmr", (4096, 512, 8), {}, F32, V5E)) == ["unknown-kind"]
    assert _rules(contracts.check_kernel_config(
        "tsm2r", (4096, 512, 8), {"block_m": 256}, F32, V5E)) == [
            "missing-params"]
    assert _rules(contracts.check_kernel_config(
        "tsm2r", (4096, 512, 8), {"block_m": 256, "block_k": -1},
        F32, V5E)) == ["bad-param"]


def test_grid_divisibility_contract():
    ok = contracts.check_grid(
        "tsm2r", (4096, 1024, 8),
        {"block_m": 256, "block_k": 256, "splits": 2})
    assert ok == []
    bad = contracts.check_grid(
        "tsm2r", (4096, 1000, 8),
        {"block_m": 256, "block_k": 256, "splits": 2})
    assert "grid-divisibility" in _rules(bad)
    bad_t = contracts.check_grid(
        "tsmt", (4100, 128, 8), {"block_m": 256, "block_a": 128, "splits": 2})
    assert "grid-divisibility" in _rules(bad_t)


def test_grid_divisibility_reduce_kind():
    ok = contracts.check_grid("reduce", (4, 4096, 16), {"block_r": 256})
    assert ok == []
    bad = contracts.check_grid("reduce", (4, 4100, 16), {"block_r": 256})
    assert _rules(bad) == ["grid-divisibility"]


def test_launch_grid_all_kinds():
    assert contracts.launch_grid(
        "tsm2r", (4096, 1024, 8), {"block_m": 256, "block_k": 256}) == (
            (16, 4), ("parallel", "arbitrary"))
    assert contracts.launch_grid(
        "tsm2r", (4096, 1024, 8),
        {"block_m": 256, "block_k": 256, "splits": 2}) == (
            (2, 16, 2), ("parallel", "parallel", "arbitrary"))
    assert contracts.launch_grid(
        "tsm2l", (8192, 16, 16), {"block_m": 512}) == (
            (16,), ("arbitrary",))
    assert contracts.launch_grid(
        "tsmt", (4096, 128, 8), {"block_m": 256, "block_a": 128}) == (
            (1, 16), ("parallel", "arbitrary"))
    assert contracts.launch_grid(
        "tsmt", (4096, 128, 8),
        {"block_m": 256, "block_a": 64, "splits": 2}) == (
            (2, 2, 8), ("parallel", "parallel", "arbitrary"))
    assert contracts.launch_grid(
        "reduce", (4, 4096, 16), {"block_r": 256}) == (
            (16,), ("parallel",))
    with pytest.raises(ValueError, match="unknown kernel kind"):
        contracts.launch_grid("tsmr", (1, 1, 1), {})


def test_epilogue_block_r_plan():
    from repro.kernels import reduce as kreduce

    budget = int(contracts.vmem_budget(V5E))
    # single slice and small stacks take the fused jnp.sum path
    assert kreduce.epilogue_block_r(1, 1 << 20, 16, block_r=256,
                                    vmem_budget=budget) is None
    assert kreduce.epilogue_block_r(4, 128, 16, block_r=128,
                                    vmem_budget=budget) is None
    # a big split tsm2r stack keeps the emitting kernel's row block...
    assert kreduce.epilogue_block_r(4, 1 << 16, 16, block_r=256,
                                    vmem_budget=budget) == 256
    # ...and halves it while the per-cell stack would overrun VMEM
    small = kreduce.epilogue_block_r(64, 1 << 16, 512, block_r=1024,
                                     vmem_budget=1 << 22)
    assert small is not None and small < 1024
    assert (1 << 16) % small == 0
    # a feasible block that does not divide rows falls back to jnp.sum
    assert kreduce.epilogue_block_r(4, 100000, 16, block_r=192,
                                    vmem_budget=budget) is None


def test_scatter_divisibility_contract():
    assert contracts.scatter_divisible(64, 2)
    assert not contracts.scatter_divisible(63, 2)
    assert contracts.check_scatter(64, 2) == []
    assert _rules(contracts.check_scatter(63, 2)) == [
        "psum-scatter-divisibility"]


def test_qr_stage_shapes_contract():
    # replicated: two stages, both (m, r, r), Gram first
    assert contracts.qr_stage_shapes(8192, 16) == (
        ("tsmt", (8192, 16, 16)), ("tsm2l", (8192, 16, 16)))
    # tree-TSQR: the same stages on the per-shard row count
    assert contracts.qr_stage_shapes(8192, 16, shards=4) == (
        ("tsmt", (2048, 16, 16)), ("tsm2l", (2048, 16, 16)))
    with pytest.raises(ValueError, match="tile"):
        contracts.qr_stage_shapes(100, 8, shards=3)
    with pytest.raises(ValueError, match="shards"):
        contracts.qr_stage_shapes(100, 8, shards=0)


def test_audit_qr_sweep_clean_and_counts():
    """The qr-resolved sweep covers every stage of every (shape, shards)
    cell it declares, and the committed resolver passes all of them."""
    checked, vios = audit.audit_qr_configs()
    assert vios == [], vios
    # every declared cell that tiles contributes both stages x spec arms
    cells = sum(1 for m, r in audit.QR_SWEEP_SHAPES
                for s in audit.QR_SWEEP_SHARDS if m % s == 0)
    assert checked >= cells * 2, (checked, cells)


def test_executor_reduce_ok():
    assert contracts.executor_reduce_ok(("psum", "none"), "psum")
    assert not contracts.executor_reduce_ok(("psum_scatter",), "psum")


def test_backward_policy_contract_on_real_policies():
    """tsmm.backward_policy satisfies the contract for every reachable
    field combo (the auditor's sweep, pinned here as a test)."""
    for mode in ("auto", "dense", "tsm2r"):
        for reduce_ in ("psum", "psum_scatter", "none"):
            for split in ("auto", "never", 4):
                p = tsmm.GemmPolicy(mode=mode, reduce=reduce_, split=split,
                                    executor="shard_map")
                assert contracts.check_backward_policy(
                    p, tsmm.backward_policy(p)) == []


def test_backward_policy_contract_catches_drift():
    p = tsmm.GemmPolicy(reduce="none", split=4, mode="tsm2r",
                        executor="shard_map")
    bad = p  # identity "backward": keeps everything it must change
    rules = _rules(contracts.check_backward_policy(p, bad))
    assert set(rules) == {"backward-reduce", "backward-split",
                          "backward-executor", "backward-mode"}


def test_tuning_record_contract_unknown_executor():
    vios = contracts.check_tuning_record(
        "tsm2r", (4096, 1024, 8), {"block_m": 256, "block_k": 128}, F32,
        V5E, executor="cuda", known_executors=("pallas-tpu", "interpret"))
    assert "unknown-executor" in _rules(vios)


# ---------------------------------------------------------------------------
# Agreement: model grids / choosers / resolver stay inside the contract set
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,shape", [
    ("tsm2r", (4096, 1024, 8)),
    ("tsm2r", (4100, 130, 3)),
    ("tsm2l", (65536, 16, 16)),
    ("tsmt", (8200, 130, 8)),
])
def test_candidates_are_contract_clean(kind, shape):
    checked, vios = audit.audit_candidate_grids(shapes={kind: (shape,)})
    assert checked > 0 and vios == []


def test_resolver_sweep_is_contract_clean():
    checked, vios = audit.audit_resolved_configs()
    assert checked > 0
    assert vios == [], [v.to_json() for v in vios]


def _oracle(kind, x, y):
    if kind == "tsmt":
        return ref.tsmt_ref(x, y)
    return ref.tsm2r_ref(x, y)


def _rand_shape(rng, kind):
    if kind == "tsm2r":
        return (rng.randrange(256, 2048), rng.randrange(128, 1024),
                rng.randrange(1, 17))
    if kind == "tsm2l":
        return (rng.randrange(1024, 8192), rng.randrange(2, 17),
                rng.randrange(2, 17))
    return (rng.randrange(256, 4096), rng.randrange(2, 65),
            rng.randrange(2, 17))


@pytest.mark.parametrize("kind", ["tsm2r", "tsm2l", "tsmt"])
@pytest.mark.parametrize("case", range(3))
def test_resolved_configs_run_and_match_oracle(kind, case):
    """Seeded sweep: the resolver's params pass the contracts AND the
    kernel launched with them (interpret mode, verify_contracts on)
    reproduces the oracle -- the contract set is sufficient, not just
    necessary."""
    rng = random.Random(1000 * case + {"tsm2r": 1, "tsm2l": 2,
                                       "tsmt": 3}[kind])
    m, d1, d2 = _rand_shape(rng, kind)
    pol = tsmm.GemmPolicy(interpret=True, verify_contracts=True)
    params = ops.resolve_params(kind, m, d1, d2, F32, pol, interpret=True)
    assert contracts.feasible(kind, (m, d1, d2), params, F32, pol.spec)
    k1, k2 = jax.random.split(jax.random.PRNGKey(case))
    if kind == "tsmt":
        x = jax.random.uniform(k1, (m, d1), F32, -1, 1)
        y = jax.random.uniform(k2, (m, d2), F32, -1, 1)
        got = ops.tsmt(x, y, policy=pol, **params)
    else:
        x = jax.random.uniform(k1, (m, d1), F32, -1, 1)
        y = jax.random.uniform(k2, (d1, d2), F32, -1, 1)
        op = ops.tsm2r if kind == "tsm2r" else ops.tsm2l
        legal = {k: v for k, v in params.items()
                 if kind == "tsm2r" or k == "block_m"}
        got = op(x, y, policy=pol, **legal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(_oracle(kind, x, y), np.float32),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# verify_contracts: the trace-time assertion mode
# ---------------------------------------------------------------------------

def test_verify_contracts_rejects_bad_explicit_block():
    pol = tsmm.GemmPolicy(interpret=True, verify_contracts=True)
    with pytest.raises(ValueError, match=r"\[lane-quant\]"):
        ops.resolve_params("tsm2r", 4096, 512, 8, F32, pol, block_k=130,
                           interpret=True)
    with pytest.raises(ValueError, match="verify_contracts"):
        ops.tsm2r(jnp.ones((1024, 512), F32), jnp.ones((512, 8), F32),
                  block_k=130, policy=pol)


def test_verify_contracts_off_still_runs_quietly():
    """Without the flag, a misquantized explicit block still runs (Mosaic
    pads) -- the historical behavior stays available for debugging."""
    a = jax.random.uniform(jax.random.PRNGKey(0), (512, 256), F32, -1, 1)
    b = jax.random.uniform(jax.random.PRNGKey(1), (256, 8), F32, -1, 1)
    got = ops.tsm2r(a, b, block_k=130, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.tsm2r_ref(a, b)),
                               rtol=1e-3, atol=1e-3)


def test_verify_contracts_default_resolution_never_raises():
    pol = tsmm.GemmPolicy(interpret=True, verify_contracts=True)
    for kind, shape in [("tsm2r", (20480, 20480, 16)),
                        ("tsm2l", (100000, 8, 8)),
                        ("tsmt", (65536, 16, 16))]:
        ops.resolve_params(kind, *shape, jnp.bfloat16, pol, interpret=True)


# ---------------------------------------------------------------------------
# Auditor acceptance: seeded violations are rejected, clean tree passes
# ---------------------------------------------------------------------------

def test_audit_rejects_over_budget_tuning_entry():
    rec = autotune.TuningRecord(
        kind="tsm2r", bucket=autotune.bucket_shape(8192, 4096, 8),
        dtype="float32", spec_name="tpu_v5e", executor="interpret",
        shape=(8192, 4096, 8),
        # 4096x4096 f32 blocks: ~190 MiB footprint >> any fitted budget
        params=(("block_k", 4096), ("block_m", 4096), ("splits", 1)),
        measured_us=1.0, model_us=1.0, model_error=0.0,
        model_pick=(("block_k", 4096), ("block_m", 4096), ("splits", 1)),
        model_pick_measured_us=1.0)
    table = autotune.TuningTable.from_records([rec])
    checked, vios = audit.audit_tuning_table(table)
    assert checked == 1
    assert "vmem-budget" in _rules(vios)


def test_audit_rejects_non_lane_quantized_tuning_entry():
    rec = autotune.TuningRecord(
        kind="tsm2r", bucket=autotune.bucket_shape(4096, 512, 8),
        dtype="float32", spec_name="tpu_v5e", executor="interpret",
        shape=(4096, 512, 8),
        params=(("block_k", 130), ("block_m", 256), ("splits", 1)),
        measured_us=1.0, model_us=1.0, model_error=0.0,
        model_pick=(("block_k", 130), ("block_m", 256), ("splits", 1)),
        model_pick_measured_us=1.0)
    _, vios = audit.audit_tuning_table(
        autotune.TuningTable.from_records([rec]))
    assert "lane-quant" in _rules(vios)


def test_audit_rejects_bucket_mismatch():
    rec = autotune.TuningRecord(
        kind="tsm2r", bucket=(1, 1, 1), dtype="float32",
        spec_name="tpu_v5e", executor="interpret", shape=(4096, 512, 8),
        params=(("block_k", 128), ("block_m", 256), ("splits", 1)),
        measured_us=1.0, model_us=1.0, model_error=0.0,
        model_pick=(("block_k", 128), ("block_m", 256), ("splits", 1)),
        model_pick_measured_us=1.0)
    _, vios = audit.audit_tuning_table(
        autotune.TuningTable.from_records([rec]))
    assert "bucket-mismatch" in _rules(vios)


def test_audit_rejects_indivisible_scatter_axis():
    bench = {"dispatch_sanity": [{
        "arm": "mesh_psum_scatter", "shape": [4096, 63, 8],
        "expected": ["pallas-tpu", "shard_map-scatter"],
        "observed": ["pallas-tpu", "shard_map-scatter"], "ok": True,
    }]}
    checked, vios = audit.audit_bench(bench)
    assert checked == 1
    assert "psum-scatter-divisibility" in _rules(vios)


def test_audit_rejects_failed_or_unknown_dispatch_arm():
    bench = {"dispatch_sanity": [
        {"arm": "auto", "shape": [4096, 512, 8], "expected": "pallas-tpu",
         "observed": ["cuda-core"], "ok": False},
    ]}
    _, vios = audit.audit_bench(bench)
    rules = _rules(vios)
    assert "bench-dispatch-failed" in rules
    assert "bench-dispatch-mismatch" in rules
    assert "unknown-executor" in rules


def test_audit_clean_on_committed_tree():
    """`python -m repro.analysis.audit --strict` over the committed bench,
    tuning table, executors and policies finds nothing."""
    report = audit.run_audit()
    assert report["schema"] == audit.AUDIT_SCHEMA
    assert report["ok"], report
    assert report["checked"] > 1000
    # every section actually ran against the committed artifacts
    assert set(report["sections"]) >= {"candidate-grids", "resolved-configs",
                                       "policies", "tuning-table",
                                       "bench-dispatch", "qr-resolved"}


def test_audit_cli_strict_and_json(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = audit.main(["--strict", "--json", str(out)])
    assert rc == 0
    text = capsys.readouterr().out
    assert "0 violation(s) -- clean" in text
    import json
    data = json.loads(out.read_text())
    assert data["schema"] == audit.AUDIT_SCHEMA and data["ok"]
