"""Per-architecture smoke tests: reduced configs, one forward + one
gradient step on CPU; shape and finiteness assertions; prefill/decode
equivalence for the decoder archs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import losses, model

ARCHS = registry.ARCH_NAMES


def _batch(cfg, key, b=2, s=32):
    ks = jax.random.split(key, 4)
    batch = {}
    if cfg.input_mode == "frames":
        batch["frames"] = jax.random.normal(ks[0], (b, s, cfg.frame_dim))
    else:
        batch["tokens"] = jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)
    batch["targets"] = jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[2], (b, cfg.vision_seq, cfg.vision_dim))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = registry.get_config(arch, smoke=True)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, metrics = model.forward(params, cfg, batch)
    b, s = (batch.get("tokens") if "tokens" in batch else batch["frames"]).shape[:2]
    assert logits.shape == (b, s, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all()
    for v in metrics.values():
        assert np.isfinite(float(v))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_decreases_nothing_nan(arch):
    cfg = registry.get_config(arch, smoke=True)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        logits, _ = model.forward(p, cfg, batch)
        loss, _ = losses.lm_loss(logits, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()
    # plain SGD step must reduce loss on the same batch (sanity, lr tiny)
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype), params, grads)
    assert float(loss_fn(params2)) < float(loss) + 1e-6


DECODER_ARCHS = [a for a in ARCHS if a != "hubert-xlarge"]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """prefill(S-3) + 3 decode steps reproduce forward()'s logits."""
    cfg = registry.get_config(arch, smoke=True)
    params = model.init(jax.random.PRNGKey(0), cfg)
    s_total, s0 = 16, 13
    batch = _batch(cfg, jax.random.PRNGKey(1), b=2, s=s_total)
    logits_full, _ = model.forward(params, cfg, batch)

    cache = model.init_cache(cfg, 2, s_total)
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :s0]
    last, cache = model.prefill(params, cfg, pre_batch, cache)
    np.testing.assert_allclose(last, logits_full[:, s0 - 1], rtol=2e-3, atol=2e-3)
    for t in range(s0, s_total):
        logits_t, cache = model.decode_step(params, cfg,
                                            batch["tokens"][:, t:t + 1], t, cache)
        np.testing.assert_allclose(logits_t, logits_full[:, t],
                                   rtol=2e-3, atol=2e-3)


def test_param_counts_sane():
    """Full configs' analytic param counts are in the published ballpark."""
    expect = {
        "llama3.2-3b": (2.5e9, 4.5e9),
        "qwen2-72b": (65e9, 80e9),
        "mistral-nemo-12b": (10e9, 14e9),
        "deepseek-v3-671b": (600e9, 720e9),
        "mixtral-8x7b": (42e9, 52e9),
        "chatglm3-6b": (5.5e9, 8e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "zamba2-1.2b": (0.8e9, 1.8e9),
        "llama-3.2-vision-11b": (8e9, 12e9),
        "hubert-xlarge": (0.7e9, 1.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = registry.get_config("deepseek-v3-671b")
    active = cfg.active_param_count()
    assert 30e9 < active < 50e9   # published: ~37B activated


def test_skip_matrix():
    ok, _ = registry.cell_supported("hubert-xlarge", "decode_32k")
    assert not ok
    ok, _ = registry.cell_supported("qwen2-72b", "long_500k")
    assert not ok
    ok, _ = registry.cell_supported("rwkv6-1.6b", "long_500k")
    assert ok
    ok, _ = registry.cell_supported("mixtral-8x7b", "long_500k")
    assert ok
    cells = list(registry.all_cells())
    assert len(cells) == 32
