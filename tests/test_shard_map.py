"""shard_map executor under a real >1-device mesh.

JAX fixes the device count at first backend use, so these run in a
subprocess with ``--xla_force_host_platform_device_count=2``. The script
asserts (via the dispatch spy) that ``tsmm`` under a data-parallel mesh
routes through the shard_map executor down to a per-shard Pallas kernel,
that numerics and gradients match the dense path, and that the
non-divisible / shard_map="never" cases fall back to dense exactly like
the old mesh guard.
"""

import os
import pathlib
import re
import subprocess
import sys


_ROOT = pathlib.Path(__file__).resolve().parents[1]

_SCRIPT = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import tsmm

devs = jax.devices()
assert len(devs) == 2, f"expected 2 host devices, got {len(devs)}"
mesh = Mesh(np.array(devs), ("data",))

a = jax.random.normal(jax.random.PRNGKey(0), (8192, 2048), jnp.float32)
b = jax.random.normal(jax.random.PRNGKey(1), (2048, 8), jnp.float32)
dense = jax.jit(lambda a_, b_: tsmm.tsmm(a_, b_, mode="dense"))(a, b)

# --- auto-routing under the mesh: shard_map -> per-shard pallas kernel ---
with mesh:
    with tsmm.record_dispatches() as log:
        f = jax.jit(lambda a_, b_: tsmm.tsmm(a_, b_))
        out = f(a, b)
execs = [(e.entry, e.kind, e.executor, e.shape) for e in log]
assert ("mm", "tsm2r", "shard_map", (8192, 2048, 8)) in execs, execs
# the per-shard re-dispatch runs the kernel on the LOCAL tall-skinny shape
assert ("mm", "tsm2r", "pallas-tpu", (4096, 2048, 8)) in execs, execs
np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                           rtol=2e-3, atol=2e-3)

# --- grad under the mesh still lands in tall-skinny classes -------------
# TSM2L shape: per-shard Abar is TSM2L again, Bbar the TSMTTSM shape.
al = jax.random.normal(jax.random.PRNGKey(4), (8192, 16), jnp.float32)
bl = jax.random.normal(jax.random.PRNGKey(5), (16, 8), jnp.float32)
with mesh:
    with tsmm.record_dispatches() as log:
        g = jax.jit(jax.grad(lambda a_, b_: jnp.sum(tsmm.tsmm(a_, b_)),
                             (0, 1)))
        da, db = g(al, bl)
kinds = {(e.entry, e.kind) for e in log}
assert ("mm", "tsm2l") in kinds, kinds      # fwd + Abar: tiny contraction
assert ("mmt", "tsmt") in kinds, kinds      # Bbar: TSMTTSM shape
rda, rdb = jax.grad(lambda a_, b_: jnp.sum(a_ @ b_), (0, 1))(al, bl)
np.testing.assert_allclose(np.asarray(da), np.asarray(rda), rtol=2e-3,
                           atol=2e-3)
np.testing.assert_allclose(np.asarray(db), np.asarray(rdb), rtol=2e-3,
                           atol=2e-3)

# --- tsmm_t: per-shard partials psum to the replicated product ----------
x = jax.random.normal(jax.random.PRNGKey(2), (8192, 32), jnp.float32)
y = jax.random.normal(jax.random.PRNGKey(3), (8192, 8), jnp.float32)
with mesh:
    with tsmm.record_dispatches() as log:
        q = jax.jit(lambda x_, y_: tsmm.tsmm_t(x_, y_))(x, y)
execs = [(e.entry, e.kind, e.executor) for e in log]
assert ("mmt", "tsmt", "shard_map") in execs, execs
np.testing.assert_allclose(np.asarray(q), np.asarray(x.T @ y),
                           rtol=2e-3, atol=2e-3)

# --- fallbacks: non-divisible tall dim / shard_map="never" --------------
a_odd = a[:8191]
with mesh:
    with tsmm.record_dispatches() as log:
        jax.jit(lambda a_, b_: tsmm.tsmm(a_, b_))(a_odd, b)
    assert [e.executor for e in log] == ["dense-xla"], log
    with tsmm.policy(shard_map="never"):
        with tsmm.record_dispatches() as log:
            jax.jit(lambda a_, b_: tsmm.tsmm(a_, b_))(a, b)
        assert [e.executor for e in log] == ["dense-xla"], log
    # shard_map="require" raises on the unshardable shape
    try:
        with tsmm.policy(shard_map="require"):
            tsmm.tsmm(a_odd, b)
    except RuntimeError as e:
        assert "require" in str(e)
    else:
        raise AssertionError("shard_map='require' did not raise")

# --- outside the mesh scope nothing changes -----------------------------
with tsmm.record_dispatches() as log:
    tsmm.tsmm(a, b)
assert [e.executor for e in log] == ["pallas-tpu"], log
print("SHARD_MAP_OK")
"""


def _two_device_env():
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "", flags)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count=2 "
                        f"{flags}").strip()
    env["PYTHONPATH"] = (str(_ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.pop("REPRO_TSMM", None)
    return env


def test_shard_map_executor_on_two_device_mesh():
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=_two_device_env(),
                       capture_output=True, text=True, timeout=600,
                       cwd=_ROOT)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHARD_MAP_OK" in r.stdout
