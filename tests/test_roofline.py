"""Loop-aware HLO cost parser: validated against hand-countable programs."""


import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analyze


def _compile(fn, *shapes):
    return jax.jit(fn).lower(*[jax.ShapeDtypeStruct(s, jnp.float32)
                               for s in shapes]).compile()


def test_single_dot_flops():
    comp = _compile(lambda a, b: a @ b, (64, 128), (128, 32))
    cost = analyze.hlo_cost(comp.as_text())
    want = 2 * 64 * 128 * 32
    assert want * 0.9 <= cost["flops"] <= want * 1.2, cost["flops"]


def test_scan_multiplies_flops():
    n = 7

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=n)
        return out

    comp = _compile(f, (32, 64), (64, 64))
    cost = analyze.hlo_cost(comp.as_text())
    want = n * 2 * 32 * 64 * 64
    assert want * 0.9 <= cost["flops"] <= want * 1.5, \
        (cost["flops"], want, cost["flops"] / want)


def test_nested_scan_multiplies_flops():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    comp = _compile(f, (16, 32), (32, 32))
    cost = analyze.hlo_cost(comp.as_text())
    want = 15 * 2 * 16 * 32 * 32
    assert want * 0.9 <= cost["flops"] <= want * 1.5, \
        (cost["flops"], want, cost["flops"] / want)


def test_bytes_scale_with_scan():
    def f(x):
        def body(c, _):
            return c * 2.0 + 1.0, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    comp = _compile(f, (1024, 1024))
    cost = analyze.hlo_cost(comp.as_text())
    # each iteration reads+writes ~4MB; 10 iterations => >= 40MB-ish
    assert cost["bytes accessed"] >= 10 * 2 * 1024 * 1024 * 4 * 0.8


def test_collective_parse_psum():
    # single-device psum lowers away; craft HLO text instead
    hlo = """
ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256] parameter(0)
  ROOT %ar = f32[128,256] all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    stats = analyze.parse_collectives(hlo)
    rbytes = 128 * 256 * 4
    want = 2 * (4 - 1) / 4 * rbytes
    assert abs(stats.wire_bytes - want) < 1e-6
    assert stats.counts["all-reduce"] == 1


def test_collective_inside_while_multiplied():
    hlo = """
%cond (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(6)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}
%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %x = f32[64] get-tuple-element(%p), index=1
  %ar = f32[64] all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[64]) tuple(%i, %ar)
}
ENTRY %main (a: (s32[], f32[64])) -> (s32[], f32[64]) {
  %a = (s32[], f32[64]) parameter(0)
  ROOT %w = (s32[], f32[64]) while(%a), condition=%cond, body=%body
}
"""
    stats = analyze.parse_collectives(hlo)
    rbytes = 64 * 4
    want = 6 * 2 * (2 - 1) / 2 * rbytes
    assert abs(stats.wire_bytes - want) < 1e-6


def test_model_flops_shapes():
    from repro.configs import registry
    from repro.configs.base import SHAPES
    cfg = registry.get_config("llama3.2-3b")
    t = analyze.model_flops(cfg, SHAPES["train_4k"])
    assert t == pytest.approx(6 * cfg.param_count() * 4096 * 256, rel=1e-6)
    d = analyze.model_flops(cfg, SHAPES["decode_32k"])
    assert d == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)
