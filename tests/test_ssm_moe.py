"""Mamba2 chunked-vs-recurrent, RWKV6 chunked-vs-step, MoE invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import mamba2, moe, rwkv6
from repro.models.mamba2 import Mamba2Config
from repro.models.moe import MoEConfig
from repro.models.rwkv6 import RWKV6Config


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------

CFG_M = Mamba2Config(d_inner=32, n_heads=4, state_dim=8, n_groups=2, chunk=8)


def _mamba_params(key, d_model=16):
    return mamba2.mamba2_init(key, d_model, CFG_M, jnp.float32)


def test_mamba2_chunked_matches_recurrent():
    p = _mamba_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16))
    got = mamba2.mamba2_fwd(p, x, CFG_M)
    want = mamba2.mamba2_ref_recurrent(p, x, CFG_M)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 6, 12, 24])
def test_mamba2_chunk_invariance(chunk):
    cfg = Mamba2Config(d_inner=32, n_heads=4, state_dim=8, n_groups=2, chunk=chunk)
    p = _mamba_params(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 24, 16))
    base = mamba2.mamba2_fwd(p, x, CFG_M)
    got = mamba2.mamba2_fwd(p, x, cfg)
    np.testing.assert_allclose(got, base, rtol=2e-4, atol=2e-4)


def test_mamba2_prefill_state_seeds_decode():
    """fwd(S, return_state) then decode(t) == fwd(S+3) at tail positions."""
    p = _mamba_params(jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 19, 16))
    full = mamba2.mamba2_fwd(p, x, CFG_M)
    s0 = 16
    _, (ssm, conv) = mamba2.mamba2_fwd(p, x[:, :s0], CFG_M, return_state=True)
    for t in range(s0, 19):
        out, ssm, conv = mamba2.mamba2_decode(p, x[:, t:t + 1], ssm, conv, CFG_M)
        np.testing.assert_allclose(out[:, 0], full[:, t], rtol=2e-3, atol=2e-4)


def test_mamba2_no_nans_long_decay():
    """Extreme dt must not overflow the chunked log-decay path."""
    p = _mamba_params(jax.random.PRNGKey(6))
    p = dict(p, dt_bias=jnp.full_like(p["dt_bias"], 6.0))  # huge decay
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(7), (1, 32, 16))
    out = mamba2.mamba2_fwd(p, x, CFG_M)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

CFG_R = RWKV6Config(n_heads=4, head_dim=8, decay_lora_rank=4, chunk=8)


def _rwkv_params(key, d=32):
    return rwkv6.rwkv6_time_mix_init(key, d, CFG_R, jnp.float32)


def test_rwkv6_chunked_matches_step():
    p = _rwkv_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    got = rwkv6.rwkv6_time_mix(p, x, CFG_R)
    want = rwkv6.rwkv6_time_mix_ref(p, x, CFG_R)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 6, 24])
def test_rwkv6_chunk_invariance(chunk):
    cfg = RWKV6Config(n_heads=4, head_dim=8, decay_lora_rank=4, chunk=chunk)
    p = _rwkv_params(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 24, 32))
    np.testing.assert_allclose(rwkv6.rwkv6_time_mix(p, x, cfg),
                               rwkv6.rwkv6_time_mix(p, x, CFG_R),
                               rtol=2e-4, atol=2e-4)


def test_rwkv6_prefill_then_decode():
    p = _rwkv_params(jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 32))
    full = rwkv6.rwkv6_time_mix(p, x, CFG_R)
    s0 = 13
    _, (st, xprev) = rwkv6.rwkv6_time_mix(p, x[:, :s0], CFG_R, return_state=True)
    for t in range(s0, 16):
        out, st, xprev = rwkv6.rwkv6_time_mix_decode(p, x[:, t:t + 1], st, xprev, CFG_R)
        np.testing.assert_allclose(out[:, 0], full[:, t], rtol=2e-3, atol=2e-4)


def test_rwkv6_channel_mix_shift():
    p = rwkv6.rwkv6_channel_mix_init(jax.random.PRNGKey(6), 32, 64, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 8, 32))
    full = rwkv6.rwkv6_channel_mix(p, x)
    # per-token with carried x_prev must match
    prev = jnp.zeros((1, 1, 32))
    for t in range(8):
        out = rwkv6.rwkv6_channel_mix(p, x[:, t:t + 1], x_prev=prev)
        np.testing.assert_allclose(out[:, 0], full[:, t], rtol=1e-4, atol=1e-5)
        prev = x[:, t:t + 1]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

CFG_E = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=2.0)


def _moe_params(key, d=16, cfg=CFG_E):
    return moe.moe_init(key, d, cfg, jnp.float32)


def dense_moe_oracle(params, x, cfg):
    """All-experts dense evaluation with the same router weights."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    w, idx, _ = moe.route(params, xt, cfg)
    ew = params["experts"]
    g = jnp.einsum("td,edf->tef", xt, ew["w_gate"])
    u = jnp.einsum("td,edf->tef", xt, ew["w_up"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, ew["w_down"])
    onehot = jax.nn.one_hot(idx, cfg.n_experts)          # (t,k,e)
    combine = jnp.einsum("tk,tke->te", w, onehot)
    out = jnp.einsum("te,ted->td", combine, y) * cfg.routed_scale
    if cfg.n_shared:
        from repro.models import layers
        out = out + layers.swiglu(params["shared"], xt)
    return out.reshape(b, s, d)


@pytest.mark.parametrize("router", ["softmax", "sigmoid"])
def test_moe_matches_dense_oracle(router):
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=8.0,
                    router=router, n_shared=1 if router == "sigmoid" else 0,
                    d_ff_shared=32, routed_scale=2.5 if router == "sigmoid" else 1.0)
    p = _moe_params(jax.random.PRNGKey(0), cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    got, metrics = moe.moe_fwd(p, x, cfg)
    want = dense_moe_oracle(p, x, cfg)
    # capacity_factor=8 => nothing dropped => exact match
    assert float(metrics["moe_dropped_frac"]) < 1e-6
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_excess():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=0.25)
    p = _moe_params(jax.random.PRNGKey(2), cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 16))
    out, metrics = moe.moe_fwd(p, x, cfg)
    assert float(metrics["moe_dropped_frac"]) > 0.0
    assert np.isfinite(np.asarray(out)).all()


def test_moe_weights_sum_to_one():
    p = _moe_params(jax.random.PRNGKey(4))
    xt = jax.random.normal(jax.random.PRNGKey(5), (32, 16))
    w, idx, _ = moe.route(p, xt, CFG_E)
    np.testing.assert_allclose(w.sum(-1), np.ones(32), rtol=1e-5)
    assert (idx >= 0).all() and (idx < CFG_E.n_experts).all()


def test_router_bias_pushes_balance():
    p = _moe_params(jax.random.PRNGKey(6),
                    cfg=MoEConfig(4, 2, 32, router="sigmoid"))
    counts = jnp.array([100.0, 10.0, 10.0, 10.0])
    p2 = moe.update_router_bias(p, counts, rate=0.1)
    # overloaded expert bias goes down, underloaded up
    assert p2["router_bias"][0] < p["router_bias"][0]
    assert (p2["router_bias"][1:] > p["router_bias"][1:]).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), t=st.integers(8, 48))
def test_moe_token_conservation(seed, t):
    """With ample capacity every token receives exactly its top-k mixture:
    output is linear in the combine weights which sum to 1 -- check the
    combine path by verifying no token's output is zeroed."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=8.0)
    p = _moe_params(jax.random.PRNGKey(seed), cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, t, 16))
    out, metrics = moe.moe_fwd(p, x, cfg)
    assert float(metrics["moe_dropped_frac"]) < 1e-6
    assert (np.abs(np.asarray(out)).sum(-1) > 0).all()
