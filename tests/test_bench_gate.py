"""benchmarks/check_regression.py: the bench-regression gate logic.

Pure-dict fixtures (no jax); pins the two failure classes the gate exists
for -- and specifically that the model-gap check uses the log-scale metric
that can actually fire when the model under-predicts (the report's
model_error ratio saturates at 1.0 in that direction)."""

import importlib
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
try:
    gate = importlib.import_module("benchmarks.check_regression")
finally:
    sys.path.remove(str(_ROOT))


def _report(arm_ok=True, model_us=1.0, measured_us=100.0):
    observed = ["pallas-tpu"] if arm_ok else ["dense-xla"]
    return {
        "dispatch_sanity": [
            {"arm": "auto", "expected": "pallas-tpu",
             "observed": observed, "ok": arm_ok},
        ],
        "autotune": {"model_error": [
            {"kind": "tsm2r", "m": 2048, "d1": 512, "d2": 8,
             "model_error": abs(model_us - measured_us) / measured_us,
             "model_us": model_us, "measured_us": measured_us},
        ]},
    }


def test_gate_passes_against_itself():
    assert gate.check(_report(), _report()) == []


def test_gate_catches_dispatch_regression():
    failures = gate.check(_report(arm_ok=False), _report())
    assert len(failures) == 1 and "regressed" in failures[0]


def test_gate_catches_dropped_arm_and_row():
    failures = gate.check({"dispatch_sanity": [], "autotune": {}}, _report())
    assert any("missing" in f for f in failures)
    assert len(failures) == 2  # arm + model-error row


def test_gate_fires_despite_ratio_ceiling():
    # Both reports have model_error ~0.99 (the ratio's under-prediction
    # ceiling); only the log gap separates them: ln(100) vs ln(100000).
    base = _report(model_us=1.0, measured_us=100.0)
    cur = _report(model_us=1.0, measured_us=100000.0)
    failures = gate.check(cur, base)
    assert len(failures) == 1 and "worsened" in failures[0], failures
    # and the noise floor keeps small drifts quiet: 100 -> 120 us
    assert gate.check(_report(measured_us=120.0), base) == []


def test_gate_new_arm_must_pass_itself():
    cur = _report()
    cur["dispatch_sanity"].append(
        {"arm": "new", "expected": "x", "observed": ["y"], "ok": False})
    failures = gate.check(cur, _report())
    assert len(failures) == 1 and "(new) failed" in failures[0]


def test_update_baseline_rewrites_and_then_gates_clean(tmp_path, capsys):
    import json

    cur_path = tmp_path / "BENCH_cur.json"
    base_path = tmp_path / "BENCH_baseline.json"
    current = _report(measured_us=321.0)
    cur_path.write_text(json.dumps(current))
    base_path.write_text(json.dumps(_report(measured_us=1.0)))  # stale

    gate.main([str(cur_path), "--baseline", str(base_path),
               "--update-baseline"])
    assert "baseline updated" in capsys.readouterr().out
    assert json.loads(base_path.read_text()) == current
    # the refreshed baseline gates the same report clean
    gate.main([str(cur_path), "--baseline", str(base_path)])
    assert "OK" in capsys.readouterr().out


def test_update_baseline_refuses_failing_report(tmp_path):
    import json

    import pytest

    cur_path = tmp_path / "BENCH_bad.json"
    base_path = tmp_path / "BENCH_baseline.json"
    cur_path.write_text(json.dumps(_report(arm_ok=False)))
    base_path.write_text(json.dumps(_report()))
    with pytest.raises(SystemExit):
        gate.main([str(cur_path), "--baseline", str(base_path),
                   "--update-baseline"])
    # the baseline file is untouched
    assert json.loads(base_path.read_text()) == _report()
