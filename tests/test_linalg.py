"""repro.linalg: CholeskyQR2 vs the jnp.linalg.qr oracle.

Orthogonality (``max|QᵀQ − I|``) and reconstruction across condition
numbers 1e0-1e7 at f32 (the 1e-4-at-cond-1e6 bar is the subsystem's
acceptance criterion), bf16 inputs, odd/non-lane-multiple shapes via
hypothesis, the custom_vjp against the oracle's gradient, dispatch-spy
proof that both GEMM stages (and their cotangents) run on the tsmt/tsm2l
executors, policy scoping, and the shift fallback on rank-deficient
input. The 2-device tree-TSQR variant lives in
tests/test_linalg_shard_map.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import linalg
from repro.core import tsmm
from repro.kernels import ops

M, R = 8192, 16


def _conditioned(m, r, cond, key=0, dtype=jnp.float32):
    """A = U diag(logspace) Vᵀ with exactly the requested 2-norm cond."""
    rng = np.random.default_rng(key)
    u, _ = np.linalg.qr(rng.standard_normal((m, r)))
    v, _ = np.linalg.qr(rng.standard_normal((r, r)))
    s = np.logspace(0, -np.log10(cond), r)
    return jnp.asarray((u * s) @ v.T, dtype)


def _orth_err(q):
    q = np.asarray(q, np.float32)
    return float(np.max(np.abs(q.T @ q - np.eye(q.shape[1]))))


def _sign_fixed_oracle(a):
    q, r = jnp.linalg.qr(a)
    s = jnp.where(jnp.diag(r) < 0, -1.0, 1.0)
    return q * s[None, :], r * s[:, None]


@pytest.mark.parametrize("cond", [1e0, 1e2, 1e4, 1e6, 1e7])
def test_orthogonality_and_reconstruction_f32(cond):
    a = _conditioned(M, R, cond)
    q, r = linalg.qr(a)
    # the acceptance bar: <= 1e-4 through cond 1e6 (typ. ~3e-7)
    assert _orth_err(q) <= (1e-4 if cond <= 1e6 else 1e-3)
    rec = float(jnp.linalg.norm(q @ r - a) / jnp.linalg.norm(a))
    assert rec <= 1e-5
    # R: upper-triangular with the non-negative-diagonal sign convention
    assert float(jnp.max(jnp.abs(jnp.tril(r, -1)))) == 0.0
    assert float(jnp.min(jnp.diag(r))) >= 0.0


@pytest.mark.parametrize("cond", [1e0, 1e2])
def test_matches_oracle_up_to_column_signs(cond):
    a = _conditioned(M, R, cond, key=1)
    q, r = linalg.qr(a)
    q_ref, r_ref = _sign_fixed_oracle(a)
    # with both sign conventions fixed the factorization is unique, so
    # the comparison is direct (the "up to column signs" of the criterion)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_ref),
                               atol=1e-4 * cond)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_ref),
                               rtol=1e-4 * cond, atol=1e-5)


def test_bf16_input():
    a = _conditioned(M, R, 1e2, key=2, dtype=jnp.bfloat16)
    q, r = linalg.qr(a)
    assert q.dtype == jnp.bfloat16
    assert r.dtype == jnp.float32
    # orthogonality is bounded by the bf16 rounding of Q itself (~2*eps)
    assert _orth_err(q) <= 0.05
    rec = float(jnp.linalg.norm(q.astype(jnp.float32) @ r
                                - a.astype(jnp.float32))
                / jnp.linalg.norm(a.astype(jnp.float32)))
    assert rec <= 0.05


def test_both_stages_dispatch_on_kernels():
    a = _conditioned(M, R, 1e2, key=3)
    with tsmm.record_dispatches() as log:
        linalg.qr(a)
    assert {e.executor for e in log} == {"pallas-tpu"}, log
    assert {e.kind for e in log} == {"tsm2l", "tsmt"}, log
    # one Gram + one apply per pass, nothing else touches the dispatcher
    assert len(log) == 2 * linalg.DEFAULT_PASSES, log


def test_policy_scope_threads_through_both_stages():
    a = _conditioned(M, R, 1e2, key=3)
    with tsmm.policy(mode="dense"):
        with tsmm.record_dispatches() as log:
            q_dense, r_dense = linalg.qr(a)
    assert {e.executor for e in log} == {"dense-xla"}, log
    q, r = linalg.qr(a)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_dense),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_dense),
                               rtol=1e-4, atol=1e-5)


def test_explicit_policy_wins_over_scope():
    a = _conditioned(M, R, 1e2, key=3)
    with tsmm.policy(mode="dense"):
        with tsmm.record_dispatches() as log:
            linalg.qr(a, policy=tsmm.GemmPolicy())
    assert {e.executor for e in log} == {"pallas-tpu"}, log


def test_grad_matches_oracle():
    a = _conditioned(2048, 8, 1e1, key=4)
    w_q = jnp.cos(jnp.arange(2048 * 8, dtype=jnp.float32).reshape(2048, 8))
    w_r = jnp.sin(jnp.arange(64, dtype=jnp.float32).reshape(8, 8))

    def loss(fact, x):
        q, r = fact(x)
        return jnp.sum(q * w_q) + jnp.sum(r * w_r)

    g = jax.grad(lambda x: loss(linalg.qr, x))(a)
    g_ref = jax.grad(lambda x: loss(_sign_fixed_oracle, x))(a)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-3, atol=1e-4)


def test_grad_dispatches_tall_skinny():
    a = _conditioned(M, R, 1e2, key=5)
    with tsmm.record_dispatches() as log:
        jax.grad(lambda x: jnp.sum(linalg.qr(x)[0]))(a)
    # forward (tsmt+tsm2l per pass) AND the cotangent GEMMs (dQᵀQ ->
    # tsmt, the two R^{-T} applies -> tsm2l) all stay on the kernels
    assert {e.executor for e in log} == {"pallas-tpu"}, log
    bwd = log[2 * linalg.DEFAULT_PASSES:]
    assert {e.kind for e in bwd} == {"tsm2l", "tsmt"}, log


def test_rank_deficient_shift_fallback():
    a = _conditioned(4096, 8, 1e2, key=6)
    a = a.at[:, 3].set(a[:, 2])      # exactly dependent column
    q, r = linalg.qr(a)
    assert bool(jnp.all(jnp.isfinite(q))) and bool(jnp.all(jnp.isfinite(r)))
    # the shifted factor still reconstructs (Q R = A holds through shifts)
    rec = float(jnp.linalg.norm(q @ r - a) / jnp.linalg.norm(a))
    assert rec <= 1e-4


def test_under_jit_and_ops_reexport():
    a = _conditioned(M, R, 1e2, key=7)
    q, r = jax.jit(linalg.tsqr)(a)
    q2, r2 = ops.tsqr(a)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r2), atol=1e-4)


def test_validation():
    with pytest.raises(ValueError, match="2-D"):
        linalg.qr(jnp.zeros((4, 4, 4)))
    with pytest.raises(ValueError, match="tall-skinny"):
        linalg.qr(jnp.zeros((8, 16)))
    with pytest.raises(ValueError, match="passes"):
        linalg.qr(jnp.zeros((64, 4)), passes=0)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(8, 3000), r=st.integers(1, 40))
def test_odd_shapes_property(m, r):
    r = min(r, max(1, m // 2))       # keep the Gaussian well-conditioned
    rng = np.random.default_rng(m * 41 + r)
    a = jnp.asarray(rng.standard_normal((m, r)), jnp.float32)
    q, rr = linalg.qr(a)
    assert q.shape == (m, r) and rr.shape == (r, r)
    assert _orth_err(q) <= 1e-3
    rec = float(jnp.linalg.norm(q @ rr - a) / jnp.linalg.norm(a))
    assert rec <= 1e-3
