"""Shared test setup.

Provides a deterministic fallback for ``hypothesis`` when the package is
not installed (the CI image bakes only the JAX toolchain): a minimal
``given``/``settings``/``strategies`` shim is registered in ``sys.modules``
so the four property-test modules collect AND execute. The shim draws
``min(max_examples, 8)`` pseudo-random examples from a fixed seed -- less
adversarial than real hypothesis (no shrinking, no example database), but
the invariants still run on every CI pass. With hypothesis installed
(requirements-dev.txt), the real package wins untouched.

The fallback is for NETWORK-LESS LOCAL runs only. In CI (the ``CI`` env
var every major provider sets) the real package is a hard requirement:
activating the stub there means the install step silently lost
requirements-dev.txt, so it raises instead of degrading -- for every job
in the workflow, not just the one that remembers to assert. The explicit
escape hatch ``REPRO_ALLOW_HYPOTHESIS_FALLBACK=1`` exists for CI-like
sandboxes that genuinely cannot install packages.
"""

import functools
import inspect
import os
import random
import sys
import types

try:
    import hypothesis  # noqa: F401  (real package present: do nothing)
except ModuleNotFoundError:
    if (os.environ.get("CI")
            and os.environ.get("REPRO_ALLOW_HYPOTHESIS_FALLBACK") != "1"):
        raise RuntimeError(
            "hypothesis is not installed but the CI env var is set: the "
            "deterministic conftest fallback must never run in CI (it is "
            "weaker than the real package -- no shrinking, 8 examples). "
            "Install requirements-dev.txt, or set "
            "REPRO_ALLOW_HYPOTHESIS_FALLBACK=1 for a sandbox that truly "
            "cannot.") from None
    _STUB_MAX_EXAMPLES = 8

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._stub_max_examples = max_examples
            return fn
        return deco

    def _given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_stub_max_examples",
                                _STUB_MAX_EXAMPLES), _STUB_MAX_EXAMPLES)
                rng = random.Random(0)
                for _ in range(n):
                    draw = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **kwargs, **draw)
            # Hide the drawn params from pytest's fixture resolution (real
            # hypothesis does the same): the exposed signature keeps only
            # params not supplied by a strategy.
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strats])
            wrapper.__dict__.pop("__wrapped__", None)
            wrapper.hypothesis_stub = True
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.floats = _floats
    _st.booleans = _booleans

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
