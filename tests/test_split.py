"""Split-reduction (split-K) coverage: oracle equality of the split
kernels across odd shapes, the GemmPolicy.split knob end-to-end (kernel
spies + dispatch events), backward_policy semantics, the tsmt accumulator
limit, and the partials tree-reduce epilogue.

The split kernels accumulate each reduction slice in f32 and the epilogue
sums the (S, ...) f32 stack, so split outputs match the sequential kernels
up to one final reassociation -- tolerances here are the same as the
sequential-vs-oracle ones in tests/test_kernels.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import tsmm
from repro.kernels import ops, ref
from repro.kernels.reduce import (JNP_REDUCE_MAX_ELEMS, reduce_partials,
                                  sum_partials_pallas)

jax.config.update("jax_enable_x64", False)


def _rand(seed, shape, dtype=jnp.float32):
    x = jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32,
                           minval=-1.0, maxval=1.0)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Oracle equality (split == sequential == jnp reference)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("splits", [2, 4, 8])
@pytest.mark.parametrize("m,a,b", [
    (8192, 128, 8),       # PowerSGD Q = G^T P with r=8
    (10000, 300, 16),     # non-divisible everywhere
    (4100, 1, 1),         # degenerate skinny: the occupancy-starved case
])
def test_tsmt_split_matches_sequential(m, a, b, splits):
    x, y = _rand(m + a, (m, a)), _rand(m + b, (m, b))
    seq = ops.tsmt(x, y, splits=1, interpret=True)
    got = ops.tsmt(x, y, splits=splits, interpret=True)
    np.testing.assert_allclose(got, seq, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got, ref.tsmt_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("splits", [2, 4])
@pytest.mark.parametrize("m,k,n", [
    (2048, 1024, 4),
    (1000, 777, 16),      # padding on both m and k
])
def test_tsm2r_split_matches_sequential(m, k, n, splits):
    a, b = _rand(m + k, (m, k)), _rand(m + n, (k, n))
    seq = ops.tsm2r(a, b, splits=1, interpret=True)
    got = ops.tsm2r(a, b, splits=splits, interpret=True)
    np.testing.assert_allclose(got, seq, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got, ref.tsm2r_ref(a, b), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tsmt_split_dtypes(dtype):
    x, y = _rand(0, (8192, 16), dtype), _rand(1, (8192, 16), dtype)
    got = ops.tsmt(x, y, splits=4, interpret=True)
    tol = (dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16
           else dict(rtol=1e-4, atol=1e-4))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.tsmt_ref(x, y), np.float32),
                               **tol)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(257, 3000), a=st.integers(1, 64), b=st.integers(1, 16),
       splits=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_tsmt_split_oracle_property(m, a, b, splits, seed):
    """Odd shapes (m a non-multiple of S*bm more often than not, a=1/b=1
    included): split output == f32-accumulated oracle."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(k1, (m, a), jnp.float32, -1, 1)
    y = jax.random.uniform(k2, (m, b), jnp.float32, -1, 1)
    got = ops.tsmt(x, y, block_m=256, block_a=64, splits=splits,
                   interpret=True)
    np.testing.assert_allclose(got, ref.tsmt_ref(x, y), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(64, 800), k=st.integers(130, 700),
       n=st.integers(1, 16), splits=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**31 - 1))
def test_tsm2r_split_oracle_property(m, k, n, splits, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.uniform(k1, (m, k), jnp.float32, -1, 1)
    b = jax.random.uniform(k2, (k, n), jnp.float32, -1, 1)
    got = ops.tsm2r(a, b, block_m=256, block_k=128, splits=splits,
                    interpret=True)
    np.testing.assert_allclose(got, ref.tsm2r_ref(a, b), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# The policy knob, end-to-end (kernel spies + dispatch events)
# ---------------------------------------------------------------------------

@pytest.fixture
def tsmt_split_spy(monkeypatch):
    calls = {"split": [], "seq": 0}
    orig_split = ops.tsmt_pallas_split
    orig_seq = ops.tsmt_pallas

    def spy_split(x, y, *, block_m, block_a, splits, interpret=None):
        calls["split"].append(splits)
        return orig_split(x, y, block_m=block_m, block_a=block_a,
                          splits=splits, interpret=interpret)

    def spy_seq(x, y, *, block_m, block_a, interpret=None):
        calls["seq"] += 1
        return orig_seq(x, y, block_m=block_m, block_a=block_a,
                        interpret=interpret)

    monkeypatch.setattr(ops, "tsmt_pallas_split", spy_split)
    monkeypatch.setattr(ops, "tsmt_pallas", spy_seq)
    return calls


def test_policy_split_pin_reaches_the_kernel(tsmt_split_spy):
    x, y = _rand(0, (4096, 64)), _rand(1, (4096, 8))
    with tsmm.policy(split=4, interpret=True):
        got = tsmm.tsmm_t(x, y)
    assert tsmt_split_spy["split"] == [4] and tsmt_split_spy["seq"] == 0
    np.testing.assert_allclose(got, ref.tsmt_ref(x, y), rtol=1e-4, atol=1e-4)


def test_policy_split_never_forces_sequential(tsmt_split_spy):
    x, y = _rand(2, (4096, 64)), _rand(3, (4096, 8))
    # even a tuning-table winner with splits > 1 must not override "never"
    from repro.core import autotune
    rec = autotune.TuningRecord(
        kind="tsmt", bucket=autotune.bucket_shape(4096, 64, 8),
        dtype="float32", spec_name="tpu_v5e", executor="interpret",
        shape=(4096, 64, 8),
        params=(("block_a", 128), ("block_m", 256), ("splits", 4)),
        measured_us=1.0, model_us=1.0, model_error=0.0,
        model_pick=(("block_a", 128), ("block_m", 256), ("splits", 4)),
        model_pick_measured_us=1.0)
    tbl = autotune.TuningTable.from_records([rec])
    with tsmm.policy(split="never", tuning_table=tbl, interpret=True):
        tsmm.tsmm_t(x, y)
    assert tsmt_split_spy["split"] == [] and tsmt_split_spy["seq"] == 1


def test_tuning_table_splits_drive_dispatch(tsmt_split_spy):
    """An "auto" scope consumes the measured splits from the table."""
    from repro.core import autotune
    rec = autotune.TuningRecord(
        kind="tsmt", bucket=autotune.bucket_shape(4096, 64, 8),
        dtype="float32", spec_name="tpu_v5e", executor="interpret",
        shape=(4096, 64, 8),
        params=(("block_a", 128), ("block_m", 256), ("splits", 2)),
        measured_us=1.0, model_us=1.0, model_error=0.0,
        model_pick=(("block_a", 128), ("block_m", 256), ("splits", 2)),
        model_pick_measured_us=1.0)
    tbl = autotune.TuningTable.from_records([rec])
    x, y = _rand(4, (4096, 64)), _rand(5, (4096, 8))
    with tsmm.policy(tuning_table=tbl, interpret=True):
        got = tsmm.tsmm_t(x, y)
    assert tsmt_split_spy["split"] == [2]
    np.testing.assert_allclose(got, ref.tsmt_ref(x, y), rtol=1e-4, atol=1e-4)


def test_explicit_splits_kwarg_beats_policy(tsmt_split_spy):
    x, y = _rand(6, (4096, 64)), _rand(7, (4096, 8))
    with tsmm.policy(split=8, interpret=True):
        ops.tsmt(x, y, splits=2)
    assert tsmt_split_spy["split"] == [2]


def test_splits_clamped_to_whole_slices(tsmt_split_spy):
    """S is clamped so every reduction slice owns >= one block: a split=16
    pin on a 2-block-deep m sweep runs S=2, not 16x zero-padding."""
    x, y = _rand(8, (512, 64)), _rand(9, (512, 8))
    ops.tsmt(x, y, block_m=256, block_a=64, splits=16, interpret=True)
    assert tsmt_split_spy["split"] == [2]


def test_dispatch_event_records_split_knob():
    x, y = _rand(10, (4096, 64)), _rand(11, (4096, 8))
    with tsmm.policy(split=4, interpret=True):
        with tsmm.record_dispatches() as log:
            tsmm.tsmm_t(x, y)
    assert [e.split for e in log] == [4]
    # The event also carries the launch metadata of the real grid: the
    # split tsmt kernel ran with S=4 leading parallel slices.
    (event,) = log
    meta = event.launches[0]
    assert meta.kind == "tsmt" and meta.splits == 4
    assert len(meta.grid) == 3 and meta.grid[0] == 4
    assert meta.dimension_semantics == ("parallel", "parallel", "arbitrary")
    with tsmm.record_dispatches() as log:
        with tsmm.policy(interpret=True):
            tsmm.tsmm_t(x, y)
    assert [e.split for e in log] == ["auto"]
    assert all(lm.kind in ("tsmt", "reduce")
               for e in log for lm in e.launches)


def test_dispatch_event_launch_grid_matches_contract():
    """The grid/semantics stamped on DispatchEvent.launches equal the pure
    contracts.launch_grid derivation for the same padded shape -- the
    invariant kernel_verify enforces as launch-meta-drift over the audit
    sweep, spot-checked here end-to-end through dispatch."""
    from repro.analysis import audit, contracts

    shape = (4096, 64, 8)
    pol = tsmm.GemmPolicy(split=2, interpret=True)
    params = ops.resolve_params("tsmt", *shape, jnp.float32, pol,
                                interpret=True)
    padded = audit._padded_shape("tsmt", shape, params)
    want = contracts.launch_grid("tsmt", padded, params)

    x, y = _rand(12, (4096, 64)), _rand(13, (4096, 8))
    with tsmm.policy(split=2, interpret=True):
        with tsmm.record_dispatches() as log:
            tsmm.tsmm_t(x, y)
    (event,) = log
    meta = next(lm for lm in event.launches if lm.kind == "tsmt")
    assert (meta.grid, meta.dimension_semantics) == want


# ---------------------------------------------------------------------------
# GemmPolicy.split validation + backward semantics
# ---------------------------------------------------------------------------

def test_policy_split_validation():
    assert tsmm.GemmPolicy(split="auto").split == "auto"
    assert tsmm.GemmPolicy(split=4).split == 4
    assert tsmm.GemmPolicy(split="never").split == "never"
    with pytest.raises(ValueError, match="split"):
        tsmm.GemmPolicy(split="sometimes")
    with pytest.raises(ValueError, match="split"):
        tsmm.GemmPolicy(split=0)
    with pytest.raises(ValueError, match="split"):
        tsmm.GemmPolicy(split=True)


def test_backward_policy_strips_int_split_preserves_never():
    """An int pin is shape-specific (forward shape only) -> backward goes
    back to "auto"; "never" is scope intent -> preserved; "auto" is a
    no-op (same object back)."""
    bp = tsmm.backward_policy(tsmm.GemmPolicy(split=4))
    assert bp.split == "auto"
    bp = tsmm.backward_policy(tsmm.GemmPolicy(split="never"))
    assert bp.split == "never"
    p = tsmm.GemmPolicy()
    assert tsmm.backward_policy(p) is p


def test_split_scope_grads_match_oracle():
    """Gradients under a split scope: the forward splits, the backward
    re-dispatches under "auto" (int stripped) and values match the dense
    oracle VJP."""
    x, y = _rand(12, (4096, 32)), _rand(13, (4096, 8))

    def f_split(x_, y_):
        with tsmm.policy(split=4, interpret=True):
            return tsmm.tsmm_t(x_, y_).sum()

    def f_oracle(x_, y_):
        return ref.tsmt_ref(x_, y_).sum()

    gx, gy = jax.grad(f_split, argnums=(0, 1))(x, y)
    ox, oy = jax.grad(f_oracle, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, ox, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy, oy, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# tsmt unblocked-accumulator limit (satellite)
# ---------------------------------------------------------------------------

def test_tsmt_rejects_oversized_b():
    x = jnp.zeros((4096, 8), jnp.float32)
    y = jnp.zeros((4096, ops.TSMT_MAX_B + 1), jnp.float32)
    with pytest.raises(ValueError, match="accumulator limit"):
        ops.tsmt(x, y, interpret=True)
    # at the limit it still dispatches (classifier boundary)
    ok = ops.tsmt(x, jnp.zeros((4096, ops.TSMT_MAX_B), jnp.float32),
                  interpret=True)
    assert ok.shape == (8, ops.TSMT_MAX_B)


def test_tsmt_limit_follows_raised_classifier_threshold():
    """A policy that deliberately raises max_skinny_t past TSMT_MAX_B has
    opted into the bigger accumulator tile: the guard must not crash
    shapes the scope's classifier routes to the kernel."""
    x, y = _rand(20, (4096, 8)), _rand(21, (4096, 600))
    with tsmm.policy(max_skinny_t=640, interpret=True):
        got = tsmm.tsmm_t(x, y)
    np.testing.assert_allclose(got, ref.tsmt_ref(x, y), rtol=1e-3, atol=1e-3)
    # past even the raised threshold it still raises
    with pytest.raises(ValueError, match="accumulator limit"):
        with tsmm.policy(max_skinny_t=640, interpret=True):
            ops.tsmt(x, _rand(22, (4096, 700)))


def test_tsmm_t_auto_still_degrades_dense_past_limit():
    """The dispatcher never routes b > max_skinny_t to the kernel, so the
    new guard must not break tsmm_t on such shapes."""
    x, y = _rand(14, (4096, 8)), _rand(15, (4096, 600))
    with tsmm.record_dispatches() as log:
        got = tsmm.tsmm_t(x, y, interpret=True)
    assert [e.kind for e in log] == ["dense"]
    np.testing.assert_allclose(got, ref.tsmt_ref(x, y), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Partials tree-reduce epilogue
# ---------------------------------------------------------------------------

def test_reduce_partials_both_paths_match():
    key = jax.random.PRNGKey(0)
    small = jax.random.normal(key, (4, 128, 8), jnp.float32)
    assert small.size <= JNP_REDUCE_MAX_ELEMS
    np.testing.assert_allclose(
        reduce_partials(small, jnp.float32, block_r=128,
                        vmem_budget=1 << 22, interpret=True),
        jnp.sum(small, axis=0), rtol=1e-6, atol=1e-6)
    big = jax.random.normal(key, (4, 1 << 16, 8), jnp.float32)
    assert big.size > JNP_REDUCE_MAX_ELEMS
    np.testing.assert_allclose(
        reduce_partials(big, jnp.float32, block_r=4096,
                        vmem_budget=1 << 22, interpret=True),
        jnp.sum(big, axis=0), rtol=1e-5, atol=1e-5)


def test_sum_partials_pallas_direct():
    p = jax.random.normal(jax.random.PRNGKey(1), (8, 256, 16), jnp.float32)
    got = sum_partials_pallas(p, block_r=64, out_dtype=jnp.float32,
                              interpret=True)
    np.testing.assert_allclose(got, jnp.sum(p, axis=0), rtol=1e-5, atol=1e-5)
