"""Repo invariant linter (`repro.analysis.lint`): one fixture snippet per
rule, pragma-waiver semantics, and the clean-tree assertion that keeps the
CI job strict."""

import textwrap

from repro.analysis import lint


def _lint(src, rel="models/thing.py"):
    return lint.lint_source(textwrap.dedent(src), rel, rel)


def _rules(errors):
    return [e.rule for e in errors]


# ---------------------------------------------------------------------------
# RA001: jax._src confinement
# ---------------------------------------------------------------------------

def test_jax_src_import_flagged_outside_compat():
    errs = _lint("""\
        import jax._src.pallas as pl
        from jax._src import core
        """, rel="kernels/tsm2r.py")
    assert _rules(errs) == ["jax-src-import", "jax-src-import"]


def test_jax_src_import_allowed_in_compat():
    errs = _lint("from jax._src import pallas\n", rel="kernels/compat.py")
    assert errs == []


def test_plain_jax_import_is_fine():
    assert _lint("import jax\nfrom jax import lax\n",
                 rel="kernels/tsm2r.py") == []


# ---------------------------------------------------------------------------
# RA002: raw parameter matmuls in models//optim//serve/
# ---------------------------------------------------------------------------

def test_raw_param_matmul_flagged_in_models():
    errs = _lint("""\
        import jax.numpy as jnp

        def f(params, x):
            return jnp.dot(x, params["w_out"])
        """)
    assert _rules(errs) == ["raw-param-matmul"]


def test_param_einsum_and_matmul_operator_flagged():
    errs = _lint("""\
        import jax.numpy as jnp

        def f(w, wuk, x):
            a = jnp.einsum("td,df->tf", x, wuk)
            b = x @ w
            return a + b
        """)
    assert _rules(errs) == ["raw-param-matmul", "raw-param-matmul"]


def test_unwrapped_operand_still_matches():
    # .astype/.T/.reshape wrappers must not hide the parameter
    errs = _lint("""\
        import jax.numpy as jnp

        def f(w_q, x):
            return jnp.matmul(x, w_q.astype(jnp.float32).T)
        """)
    assert _rules(errs) == ["raw-param-matmul"]


def test_activation_matmul_not_flagged():
    errs = _lint("""\
        import jax.numpy as jnp

        def f(q, k):
            return jnp.einsum("thd,shd->tsh", q, k)
        """)
    assert errs == []


def test_raw_param_matmul_ignored_outside_scoped_dirs():
    errs = _lint("""\
        import jax.numpy as jnp

        def f(w, x):
            return jnp.dot(x, w)
        """, rel="kernels/ref.py")
    assert errs == []


def test_einsum_spec_string_is_not_an_operand():
    # the "w" in an einsum spec string must not trip the name heuristic
    errs = _lint("""\
        import jax.numpy as jnp

        def f(a, b):
            return jnp.einsum("wx,xy->wy", a, b)
        """)
    assert errs == []


# ---------------------------------------------------------------------------
# RA003: env reads at trace time
# ---------------------------------------------------------------------------

def test_env_read_flagged():
    errs = _lint("""\
        import os

        def f():
            a = os.getenv("REPRO_TSMM")
            b = os.environ.get("REPRO_SPEC", "v5e")
            c = os.environ["HOME"]
            return a, b, c
        """, rel="core/perf_model.py")
    assert _rules(errs) == ["env-read"] * 3


def test_env_read_allowed_in_policy_constructor_and_launch():
    src = """\
        import os

        def _policy_from_env():
            return os.getenv("REPRO_TSMM")
        """
    assert _lint(src, rel="core/tsmm.py") == []
    assert _lint("import os\nv = os.getenv('X')\n",
                 rel="launch/run.py") == []
    # same function name in another file is NOT exempt
    assert _rules(_lint(src, rel="core/autotune.py")) == ["env-read"]


# ---------------------------------------------------------------------------
# RA004: executor reduce contracts
# ---------------------------------------------------------------------------

def test_register_executor_without_reduce_flagged():
    errs = _lint("""\
        from repro.core import tsmm

        tsmm.register_executor("my-exec", lambda *a: None)
        """, rel="core/extras.py")
    assert _rules(errs) == ["executor-contract"]


def test_register_executor_with_reduce_ok():
    errs = _lint("""\
        from repro.core import tsmm

        tsmm.register_executor("my-exec", lambda *a: None,
                               reduce=("psum",))
        """, rel="core/extras.py")
    assert errs == []


# ---------------------------------------------------------------------------
# Pragma waivers
# ---------------------------------------------------------------------------

def test_pragma_waives_same_and_next_line():
    errs = _lint("""\
        import jax.numpy as jnp

        def f(w, x):
            # repro: allow-raw-param-matmul (tested exemption)
            return jnp.dot(x, w)
        """)
    assert errs == []
    errs = _lint("""\
        import jax.numpy as jnp

        def f(w, x):
            return jnp.dot(x, w)  # repro: allow-raw-param-matmul (inline)
        """)
    assert errs == []


def test_pragma_carries_through_comment_block_and_wrapped_stmt():
    """A multi-line pragma comment above a multi-line statement waives the
    whole statement (the moe.py/attention.py idiom)."""
    errs = _lint("""\
        import jax.numpy as jnp

        def f(ew, buf, wsc):
            # repro: allow-raw-param-matmul (grouped per-expert einsum:
            # no 2-D rhs form tsmm accepts; the contraction must stay one
            # GSPMD op)
            g = wsc(jnp.einsum("gecd,edf->gecf", buf, ew["w_gate"]),
                    "model")
            return g
        """)
    assert errs == []


def test_pragma_waives_only_its_rule_and_statement():
    errs = _lint("""\
        import jax.numpy as jnp

        def f(w, x):
            # repro: allow-env-read (wrong rule)
            a = jnp.dot(x, w)
            b = jnp.dot(x, w)
            return a + b
        """)
    # wrong rule name: both dots still flagged
    assert _rules(errs) == ["raw-param-matmul"] * 2
    errs = _lint("""\
        import jax.numpy as jnp

        def f(w, x):
            # repro: allow-raw-param-matmul (first only)
            a = jnp.dot(x, w)
            b = jnp.dot(x, w)
            return a + b
        """)
    # the waiver covers exactly one statement, not the rest of the block
    assert _rules(errs) == ["raw-param-matmul"]


def test_syntax_error_is_reported_not_raised():
    errs = _lint("def f(:\n", rel="models/broken.py")
    assert _rules(errs) == ["syntax-error"]


# ---------------------------------------------------------------------------
# RA005: raw qr/cholesky factorizations in the parameter layers
# ---------------------------------------------------------------------------

def test_raw_linalg_qr_flagged_in_optim():
    errs = _lint("""\
        import jax.numpy as jnp

        def orth(p):
            q, _ = jnp.linalg.qr(p)
            return q
        """, rel="optim/powersgd.py")
    assert _rules(errs) == ["raw-linalg-qr"]


def test_raw_cholesky_spellings_flagged():
    errs = _lint("""\
        import numpy as np
        from jax.scipy import linalg as jsp_linalg

        def f(g):
            a = np.linalg.cholesky(g)
            b = jsp_linalg.cholesky(g)
            return a, b
        """, rel="serve/decode.py")
    assert _rules(errs) == ["raw-linalg-qr", "raw-linalg-qr"]


def test_repro_linalg_call_not_flagged():
    errs = _lint("""\
        from repro import linalg

        def orth(p):
            q, _ = linalg.tsqr(p)
            return q
        """, rel="optim/powersgd.py")
    assert errs == []


def test_raw_linalg_qr_exempt_outside_scoped_dirs():
    src = "import jax.numpy as jnp\nq = jnp.linalg.qr(x)\n"
    assert _lint(src, rel="linalg/tsqr.py") == []
    assert _lint(src, rel="analysis/audit.py") == []


def test_raw_linalg_qr_pragma_waiver():
    errs = _lint("""\
        import jax.numpy as jnp

        def f(b):
            # repro: allow-raw-linalg-qr ((k, k) host-shaped factor, not
            # a tall-skinny operand)
            return jnp.linalg.qr(b)
        """, rel="models/layers.py")
    assert errs == []


# ---------------------------------------------------------------------------
# RA006: undeclared-dimension-semantics
# ---------------------------------------------------------------------------

def test_pallas_call_without_semantics_flagged_in_kernels():
    errs = _lint("""\
        from repro.kernels import compat

        def launch(kernel, grid, specs, out):
            return compat.pallas_call(
                kernel, grid=grid, in_specs=specs, out_specs=out[0],
                out_shape=out[1])
        """, rel="kernels/newkernel.py")
    assert _rules(errs) == ["undeclared-dimension-semantics"]


def test_pallas_call_with_compiler_params_semantics_ok():
    errs = _lint("""\
        from repro.kernels import compat

        def launch(kernel, grid, specs, out):
            return compat.pallas_call(
                kernel, grid=grid, in_specs=specs, out_specs=out[0],
                out_shape=out[1],
                compiler_params=compat.CompilerParams(
                    dimension_semantics=("parallel", "arbitrary")))
        """, rel="kernels/newkernel.py")
    assert errs == []


def test_pallas_call_with_direct_semantics_kwarg_ok():
    errs = _lint("""\
        from jax.experimental import pallas as pl

        def launch(kernel, grid, specs, out):
            return pl.pallas_call(
                kernel, grid=grid, in_specs=specs, out_specs=out[0],
                out_shape=out[1],
                dimension_semantics=("arbitrary",))
        """, rel="kernels/newkernel.py")
    assert errs == []


def test_pallas_call_exempt_outside_kernels_and_in_compat():
    src = ("from jax.experimental import pallas as pl\n"
           "f = pl.pallas_call(k, grid=(4,), in_specs=s, out_specs=o,\n"
           "                   out_shape=sh)\n")
    assert _lint(src, rel="analysis/kernel_verify.py") == []
    assert _lint(src, rel="kernels/compat.py") == []


def test_pallas_call_semantics_pragma_waiver():
    errs = _lint("""\
        from jax.experimental import pallas as pl

        def launch(kernel):
            # repro: allow-undeclared-dimension-semantics (1-cell grid,
            # nothing to parallelize)
            return pl.pallas_call(kernel, grid=(1,), in_specs=[],
                                  out_specs=None, out_shape=None)
        """, rel="kernels/newkernel.py")
    assert errs == []


# ---------------------------------------------------------------------------
# Clean tree
# ---------------------------------------------------------------------------

def test_committed_tree_is_lint_clean():
    """`python -m repro.analysis.lint` on the repro package finds nothing:
    every legitimate exemption carries a documented pragma."""
    errors = lint.lint_paths()
    assert errors == [], "\n".join(str(e) for e in errors)


def test_main_exit_codes(capsys):
    assert lint.main([]) == 0
    assert "clean" in capsys.readouterr().out
