"""Distributed tree-TSQR on a real 2-device mesh (subprocess).

Same harness as tests/test_scatter_shard_map.py: a subprocess pinned to
``--xla_force_host_platform_device_count=2`` runs both reduction
schedules of :func:`repro.linalg.tree_tsqr` inside a shard_map and
asserts, against the replicated :func:`repro.linalg.tsqr` oracle:

* butterfly and gather both return the oracle's Q/R directly (the sign
  convention makes the factorization unique -- no column-sign slack);
* the local Q block stays sharded ((m/2, r) per device) while R comes
  back replicated with a non-negative diagonal;
* the acceptance bar holds distributed: ``max|QᵀQ - I| <= 1e-4`` at f32
  through cond 1e6, where Q is the gathered global basis;
* the dispatch spy sees the per-shard CholeskyQR2 stages on the
  tsmt/tsm2l kernel executors (shard_map="local" -- no re-wrap, no
  dense-xla) plus the tiny tsmm apply of the tree transform;
* reduce="butterfly" on a non-power-of-two axis raises, and the
  explicit reduce= spellings agree with reduce="auto".

This file is in the ruff-format ratchet set (see ci.yml) -- keep edits
formatter-clean.
"""

import os
import pathlib
import re
import subprocess
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]

_SCRIPT = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import linalg
from repro.core import tsmm
from repro.kernels import compat

devs = jax.devices()
assert len(devs) == 2, f"expected 2 host devices, got {len(devs)}"
mesh = Mesh(np.array(devs), ("data",))

M, R = 8192, 16


def conditioned(cond, key=0):
    rng = np.random.default_rng(key)
    u, _ = np.linalg.qr(rng.standard_normal((M, R)))
    v, _ = np.linalg.qr(rng.standard_normal((R, R)))
    s = np.logspace(0, -np.log10(cond), R)
    return jnp.asarray((u * s) @ v.T, jnp.float32)


def orth_err(q):
    q = np.asarray(q, np.float32)
    return float(np.max(np.abs(q.T @ q - np.eye(q.shape[1]))))


def run_tree(a, reduce_):
    def body(a_loc):
        q_loc, r = linalg.tree_tsqr(a_loc, axis="data", reduce=reduce_)
        return q_loc, r

    f = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P("data", None),),
        out_specs=(P("data", None), P(None, None)),
    )
    with mesh:
        return jax.jit(f)(a)


# --- both schedules == replicated oracle at moderate cond ----------------
a = conditioned(1e2)
q_ref, r_ref = linalg.tsqr(a)
for reduce_ in ("butterfly", "gather", "auto"):
    q, r = run_tree(a, reduce_)
    assert q.shape == (M, R) and r.shape == (R, R), (q.shape, r.shape)
    # Q stays row-sharded, R replicated
    assert {s.data.shape for s in q.addressable_shards} == {(M // 2, R)}, (
        reduce_,
        q.addressable_shards,
    )
    assert {s.data.shape for s in r.addressable_shards} == {(R, R)}, reduce_
    np.testing.assert_allclose(
        np.asarray(q), np.asarray(q_ref), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(r), np.asarray(r_ref), rtol=1e-4, atol=1e-4
    )
    assert float(jnp.min(jnp.diag(r))) >= 0.0, reduce_

# --- acceptance bar distributed: orth <= 1e-4 at cond 1e6 ----------------
a6 = conditioned(1e6, key=1)
_, r_ref6 = linalg.tsqr(a6)
for reduce_ in ("butterfly", "gather"):
    q6, r6 = run_tree(a6, reduce_)
    err = orth_err(q6)
    assert err <= 1e-4, (reduce_, err)
    np.testing.assert_allclose(
        np.asarray(r6), np.asarray(r_ref6), rtol=1e-3, atol=1e-4
    )
    rec = float(jnp.linalg.norm(q6 @ r6 - a6) / jnp.linalg.norm(a6))
    assert rec <= 1e-5, (reduce_, rec)

# --- dispatch: per-shard stages stay on the kernels ----------------------
with tsmm.record_dispatches() as log:
    run_tree(a, "butterfly")
assert {e.executor for e in log} == {"pallas-tpu"}, log
kinds = {e.kind for e in log}
assert kinds == {"tsm2l", "tsmt"}, kinds
# every event traced at the LOCAL (m/2) shape: shard_map="local" held
assert {e.shape[0] for e in log} == {M // 2}, log

# --- size-1 axis degenerates to the local factorization ------------------
mesh1 = Mesh(np.array(devs).reshape(2, 1), ("data", "model"))


def body_size1(a_loc):
    # "model" has one shard: the tree is a no-op and the local CholeskyQR2
    # result passes straight through
    return linalg.tree_tsqr(a_loc, axis="model")


with mesh1:
    q1, r1 = jax.jit(
        compat.shard_map(
            body_size1,
            mesh=mesh1,
            in_specs=(P(None, None),),
            out_specs=(P(None, None), P(None, None)),
        )
    )(a)
np.testing.assert_allclose(np.asarray(q1), np.asarray(q_ref), rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(r1), np.asarray(r_ref), rtol=1e-5, atol=1e-5)

# --- reduce= validation ---------------------------------------------------
try:
    linalg.tree_tsqr(a, axis="data", reduce="bogus")
except ValueError as e:
    assert "reduce" in str(e), e
else:
    raise AssertionError("bogus reduce= did not raise")

print("LINALG_TREE_TSQR_OK")
"""


def _two_device_env():
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "", flags)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count=2 {flags}".strip()
    env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("REPRO_TSMM", None)
    return env


def test_tree_tsqr_on_two_device_mesh():
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=_two_device_env(),
        capture_output=True,
        text=True,
        timeout=600,
        cwd=_ROOT,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "LINALG_TREE_TSQR_OK" in r.stdout
