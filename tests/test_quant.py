"""Low-precision TSM2X: int8 tiles with f32 accumulate.

Pins the quantization layer end to end: the per-block symmetric
round-trip bound, quantized-vs-f32 oracle tolerances across the three
kernel kinds (hypothesis odd-shape sweeps), the ``GemmPolicy.quant``
knob (validation, backward derivation, dispatch-spy threading, the
dense arm ignoring it), the pinned-block rejection contract under the
int8 sublane quantum, offline weight records (jit-safe pytrees, serving
round-trip), and the PowerSGD ``compress="int8"`` wire mode.

This file is in the ruff-format ratchet set (see ci.yml) -- keep edits
formatter-clean.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import contracts
from repro.core import perf_model, tsmm
from repro.kernels import quant as kquant
from repro.optim import powersgd


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.uniform(key, shape, jnp.float32, -1, 1).astype(dtype)


# ---------------------------------------------------------------------------
# Round-trip quant/dequant error bounds
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    scale_pow=st.integers(-8, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_bound_per_block(m, n, scale_pow, seed):
    """|x - dq(q(x))| <= absmax/254 per block (half a quantization step),
    across magnitudes: symmetric scales are scale-invariant."""
    block = 8
    x = _rand(jax.random.PRNGKey(seed), (m * block, n)) * (2.0**scale_pow)
    q, scale = kquant.quantize_blocks(x, block)
    assert q.dtype == jnp.int8 and scale.shape == (m, 1)
    back = kquant.dequantize_blocks(q, scale)
    for b in range(m):
        blk = np.asarray(x[b * block : (b + 1) * block])
        err = np.abs(np.asarray(back[b * block : (b + 1) * block]) - blk)
        bound = np.abs(blk).max() / (2 * kquant.QMAX) * 1.0001 + 1e-30
        assert err.max() <= bound, (b, err.max(), bound)


def test_roundtrip_zero_block_guard():
    """All-zero blocks round-trip exactly (scale guard avoids 0-division)."""
    x = jnp.zeros((16, 8), jnp.float32)
    q, scale = kquant.quantize_blocks(x, 8)
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(scale), 1.0)
    back = kquant.dequantize_blocks(q, scale)
    np.testing.assert_array_equal(np.asarray(back), 0.0)


def test_fake_quant_is_roundtrip_in_dtype():
    x = _rand(jax.random.PRNGKey(3), (64, 8))
    y = kquant.fake_quant(x)
    assert y.dtype == x.dtype
    err = float(jnp.max(jnp.abs(y - x)))
    assert err <= float(jnp.max(jnp.abs(x))) / 200
    # non-f32 inputs keep their dtype (bf16 rounding stacks on the
    # quantization step, so only the dtype is pinned here)
    xb = x.astype(jnp.bfloat16)
    assert kquant.fake_quant(xb).dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Quantized kernels vs the f32 oracle (odd-shape property sweeps)
# ---------------------------------------------------------------------------

# Max-norm relative tolerance of the int8 path vs the f32 oracle; the
# README documents 5%, measured ~0.6% on the bench shapes.
_REL_TOL = 0.05


def _rel_err(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-30)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(64, 600),
    k=st.integers(32, 300),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_tsm2r_int8_matches_oracle(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand(k1, (m, k)), _rand(k2, (k, n))
    pol = tsmm.GemmPolicy(mode="tsm2r", quant="int8", interpret=True)
    with tsmm.policy(pol):
        got = tsmm.tsmm(a, b)
    assert got.dtype == a.dtype
    assert _rel_err(got, a @ b) <= _REL_TOL


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(64, 500),
    k=st.integers(2, 32),
    n=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_tsm2l_int8_matches_oracle(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand(k1, (m, k)), _rand(k2, (k, n))
    pol = tsmm.GemmPolicy(mode="tsm2l", quant="int8", interpret=True)
    with tsmm.policy(pol):
        got = tsmm.tsmm(a, b)
    assert _rel_err(got, a @ b) <= _REL_TOL


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(256, 2000),
    a=st.integers(8, 128),
    b=st.integers(1, 16),
    split=st.sampled_from(["auto", 2, "never"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tsmt_int8_matches_oracle(m, a, b, split, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x, y = _rand(k1, (m, a)), _rand(k2, (m, b))
    pol = tsmm.GemmPolicy(quant="int8", split=split, interpret=True)
    with tsmm.policy(pol):
        got = tsmm.tsmm_t(x, y)
    assert _rel_err(got, x.T @ y) <= _REL_TOL


def test_int8_preserves_bf16_output_dtype():
    a = _rand(jax.random.PRNGKey(0), (512, 256), jnp.bfloat16)
    b = _rand(jax.random.PRNGKey(1), (256, 8), jnp.bfloat16)
    pol = tsmm.GemmPolicy(mode="tsm2r", quant="int8", interpret=True)
    with tsmm.policy(pol):
        got = tsmm.tsmm(a, b)
    assert got.dtype == jnp.bfloat16
    want = a.astype(jnp.float32) @ b.astype(jnp.float32)
    assert _rel_err(got, want) <= 0.06


def test_int8_split_partials_match_sequential():
    """Split-K over quantized tiles dequantizes per-step into f32 partials;
    the reduce epilogue must see nothing different."""
    a = _rand(jax.random.PRNGKey(5), (1024, 1024))
    b = _rand(jax.random.PRNGKey(6), (1024, 8))
    base = tsmm.GemmPolicy(mode="tsm2r", quant="int8", interpret=True)
    with tsmm.policy(dataclasses.replace(base, split="never")):
        seq = tsmm.tsmm(a, b)
    with tsmm.policy(dataclasses.replace(base, split=4)):
        par = tsmm.tsmm(a, b)
    np.testing.assert_allclose(
        np.asarray(par), np.asarray(seq), rtol=1e-5, atol=1e-5
    )


def test_int8_grads_flow():
    a = _rand(jax.random.PRNGKey(7), (512, 256))
    b = _rand(jax.random.PRNGKey(8), (256, 8))

    def loss(a_, b_):
        with tsmm.policy(tsmm.GemmPolicy(quant="int8", interpret=True)):
            return jnp.sum(tsmm.tsmm(a_, b_) ** 2)

    ga, gb = jax.grad(loss, argnums=(0, 1))(a, b)

    def loss0(a_, b_):
        return jnp.sum((a_ @ b_) ** 2)

    ga0, gb0 = jax.grad(loss0, argnums=(0, 1))(a, b)
    assert _rel_err(ga, ga0) <= 0.1 and _rel_err(gb, gb0) <= 0.1


# ---------------------------------------------------------------------------
# Policy knob: validation, backward derivation, dispatch threading
# ---------------------------------------------------------------------------


def test_policy_quant_validated():
    with pytest.raises(ValueError, match="quant"):
        tsmm.GemmPolicy(quant="fp8")
    assert tsmm.GemmPolicy(quant="int8").quant == "int8"
    assert tsmm.GemmPolicy().quant == "none"


def test_backward_policy_preserves_quant():
    fwd = tsmm.GemmPolicy(quant="int8", split=4)
    bwd = tsmm.backward_policy(fwd)
    assert bwd.quant == "int8"
    assert not contracts.check_backward_policy(fwd, bwd)
    # and the contract checker notices a drift
    drift = dataclasses.replace(bwd, quant="none")
    vios = contracts.check_backward_policy(fwd, drift)
    assert any(v.rule == "backward-quant" for v in vios)


def test_dispatch_event_carries_quant():
    a = _rand(jax.random.PRNGKey(9), (2048, 512))
    b = _rand(jax.random.PRNGKey(10), (512, 8))
    with tsmm.policy(tsmm.GemmPolicy(quant="int8", interpret=True)):
        with tsmm.record_dispatches() as log:
            jax.jit(lambda a_, b_: tsmm.tsmm(a_, b_))(a, b)
    assert log and all(e.quant == "int8" for e in log)
    assert sorted({e.executor for e in log}) == ["interpret"]


def test_dense_arm_ignores_quant():
    """mode="dense" routes to stock XLA: the knob must not corrupt it."""
    a = _rand(jax.random.PRNGKey(11), (512, 128))
    b = _rand(jax.random.PRNGKey(12), (128, 8))
    with tsmm.policy(tsmm.GemmPolicy(mode="dense", quant="int8")):
        with tsmm.record_dispatches() as log:
            got = jax.jit(lambda a_, b_: tsmm.tsmm(a_, b_))(a, b)
    assert [e.executor for e in log] == ["dense-xla"]
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a @ b), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# Pinned-block rejection under the int8 sublane quantum (the small fix)
# ---------------------------------------------------------------------------


def test_pinned_block_rejected_under_int8_quantum():
    """A block_m pin that is legal for f32 (sublane 8) but off the int8
    32-row quantum must raise a tagged error under verify_contracts, not
    silently re-quantize."""
    from repro.kernels import ops

    pol = tsmm.GemmPolicy(quant="int8", verify_contracts=True)
    with pytest.raises(ValueError, match=r"pinned-block-quant"):
        ops.resolve_params(
            "tsm2r", 4096, 512, 8, jnp.float32, pol, block_m=72, interpret=True
        )
    # the same pin is accepted without quant (8 | 72)
    pol_f32 = tsmm.GemmPolicy(verify_contracts=True)
    p = ops.resolve_params(
        "tsm2r", 4096, 512, 8, jnp.float32, pol_f32, block_m=72, interpret=True
    )
    assert p["block_m"] == 72
    # and a 32-aligned pin passes under quant
    p = ops.resolve_params(
        "tsm2r", 4096, 512, 8, jnp.float32, pol, block_m=64, interpret=True
    )
    assert p["block_m"] == 64


def test_min_sublane_contract():
    spec = perf_model.V5E
    assert contracts.min_sublane(spec, jnp.int8) == 4 * spec.sublane
    assert contracts.min_sublane(spec, jnp.float32) == spec.sublane
    assert contracts.min_sublane(spec, jnp.bfloat16) == spec.sublane


# ---------------------------------------------------------------------------
# Offline weight records (serving path)
# ---------------------------------------------------------------------------


def test_weight_records_roundtrip_and_jit():
    params = {
        "w": _rand(jax.random.PRNGKey(13), (512, 128)),
        "bias": jnp.ones((128,)),
        "small": _rand(jax.random.PRNGKey(14), (8, 8)),
    }
    qp = kquant.quantize_weights(params, block_rows=256, min_size=1024)
    assert kquant.has_quantized_weights(qp)
    assert qp["w"]["q8"].dtype == jnp.int8
    assert qp["w"]["q8_scale"].shape == (2, 1)
    # small/1-D leaves pass through untouched
    assert qp["bias"] is params["bias"] and qp["small"] is params["small"]

    # records are plain jit-safe pytrees
    back = jax.jit(kquant.dequantize_weights)(qp)
    assert _rel_err(back["w"], params["w"]) <= 1 / 200
    np.testing.assert_array_equal(
        np.asarray(back["bias"]), np.asarray(params["bias"])
    )
    assert not kquant.has_quantized_weights(back)


def test_serve_engine_accepts_quantized_weights():
    from repro.configs import registry
    from repro.models import model
    from repro.serve import engine

    cfg = registry.get_config("llama3.2-3b", smoke=True)
    params = model.init(jax.random.PRNGKey(0), cfg)
    low, high = 0, cfg.vocab_size
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 8), low, high)
    base = engine.generate(params, cfg, prompts, max_new=3)
    qparams = kquant.quantize_weights(params, min_size=1024)
    assert kquant.has_quantized_weights(qparams)
    out = engine.generate(qparams, cfg, prompts, max_new=3)
    assert out.shape == base.shape
    assert (np.asarray(out) >= 0).all()
    assert (np.asarray(out) < cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# PowerSGD compress="int8"
# ---------------------------------------------------------------------------


def test_powersgd_compress_validated():
    with pytest.raises(ValueError, match="compress"):
        powersgd.PowerSGDConfig(compress="fp4")


def test_powersgd_int8_close_to_f32_and_counts_bytes():
    cfg8 = powersgd.PowerSGDConfig(rank=4, min_size=0, compress="int8")
    cfg0 = powersgd.PowerSGDConfig(rank=4, min_size=0)
    g = _rand(jax.random.PRNGKey(15), (512, 256))
    zeros = {"w": jnp.zeros((512, 256))}
    st_ = powersgd.init(cfg8, zeros, jax.random.PRNGKey(17))["w"]
    a8, _ = powersgd.compress_one(cfg8, g, st_)
    a0, _ = powersgd.compress_one(cfg0, g, st_)
    assert _rel_err(a8, a0) <= 0.1

    _, _, m8 = powersgd.compress_tree(cfg8, {"w": g}, {"w": st_})
    _, _, m0 = powersgd.compress_tree(cfg0, {"w": g}, {"w": st_})
    # int8 wire format: ~4x fewer factor bytes than f32
    ratio = m8["powersgd_compression"] / m0["powersgd_compression"]
    assert 3.5 <= ratio <= 4.1
