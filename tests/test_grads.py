"""Gradient correctness for the differentiable TSM2X subsystem.

``jax.grad`` through ``tsmm``/``tsmm_t`` (interpret mode on CPU) must match
the pure-jnp oracles in ``kernels/ref.py`` for all three shape classes, and
the backward must stay inside the paper's tall-skinny regime: the VJP of
one class lands in another (TSM2L's Abar is TSM2L-shaped, every Bbar is the
TSMTTSM shape), asserted both via ``classify_gemm`` on the cotangent shapes
and by recording what the backward actually dispatches.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tsmm
from repro.kernels import ops, ref

TOL = dict(rtol=1e-3, atol=1e-3)


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _grads(fn, a, b, ct):
    def loss(a_, b_):
        return jnp.sum(fn(a_, b_) * ct)

    return jax.grad(loss, (0, 1))(a, b)


# ---------------------------------------------------------------------------
# grad(tsmm) == grad(oracle) for the three shape classes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,m,k,n", [
    ("tsm2r", 4096, 2048, 8),    # m ~ k >> n
    ("tsm2l", 4096, 16, 8),      # m >> k ~ n
])
def test_tsmm_grad_matches_oracle(kind, m, k, n):
    assert tsmm.classify_gemm(m, k, n) == kind  # forward hits the kernel
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(m + n), 3)
    a, b, ct = _rand(k1, (m, k)), _rand(k2, (k, n)), _rand(k3, (m, n))
    da, db = _grads(lambda x, y: tsmm.tsmm(x, y, interpret=True), a, b, ct)
    ra, rb = _grads(ref.tsm2r_ref, a, b, ct)
    np.testing.assert_allclose(np.asarray(da), np.asarray(ra), **TOL)
    np.testing.assert_allclose(np.asarray(db), np.asarray(rb), **TOL)


def test_tsmm_t_grad_matches_oracle():
    m, a_dim, b_dim = 4096, 32, 8   # TSMT: reduction over the huge m
    assert tsmm.classify_gemm_t(m, a_dim, b_dim) == "tsmt"
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x, y = _rand(k1, (m, a_dim)), _rand(k2, (m, b_dim))
    ct = _rand(k3, (a_dim, b_dim))
    dx, dy = _grads(lambda u, v: tsmm.tsmm_t(u, v, interpret=True), x, y, ct)
    rx, ry = _grads(ref.tsmt_ref, x, y, ct)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rx), **TOL)
    np.testing.assert_allclose(np.asarray(dy), np.asarray(ry), **TOL)


# ---------------------------------------------------------------------------
# Backward routing stays in the tall-skinny regime
# ---------------------------------------------------------------------------

def test_cotangent_shapes_classify_tall_skinny():
    """The paper's cross-class VJP structure, checked on the classifier."""
    # TSM2L forward C[m,n] = A[m,k] B[k,n] with m >> k ~ n:
    m, k, n = 4096, 16, 8
    assert tsmm.classify_gemm(m, k, n) == "tsm2l"
    # Abar = Chat[m,n] B^T[n,k]  -> TSM2L again (tiny contraction n).
    assert tsmm.classify_gemm(m, n, k) == "tsm2l"
    # Bbar = A^T[k,m] Chat[m,n]  -> the TSMTTSM shape (Ernst et al.).
    assert tsmm.classify_gemm_t(m, k, n) == "tsmt"
    # TSMT forward C[a,b] = X[m,a]^T Y[m,b]:
    a_dim, b_dim = 32, 8
    assert tsmm.classify_gemm_t(m, a_dim, b_dim) == "tsmt"
    # Xbar = Y[m,b] Chat^T[b,a] and Ybar = X[m,a] Chat[a,b] -> TSM2L-shaped.
    assert tsmm.classify_gemm(m, b_dim, a_dim) == "tsm2l"
    assert tsmm.classify_gemm(m, a_dim, b_dim) == "tsm2l"


def test_backward_dispatches_through_classifier(monkeypatch):
    """Record what the VJP actually calls: the TSM2L backward must re-enter
    the dispatcher and route Abar to tsm2l and Bbar to tsmt."""
    calls = []
    real_tsmm, real_tsmm_t = tsmm.tsmm, tsmm.tsmm_t

    def spy_tsmm(a, b, **kw):
        calls.append(("tsmm", tsmm.classify_gemm(a.shape[0], a.shape[1],
                                                 b.shape[1])))
        return real_tsmm(a, b, **kw)

    def spy_tsmm_t(x, y, **kw):
        calls.append(("tsmm_t", tsmm.classify_gemm_t(x.shape[0], x.shape[1],
                                                     y.shape[1])))
        return real_tsmm_t(x, y, **kw)

    monkeypatch.setattr(tsmm, "tsmm", spy_tsmm)
    monkeypatch.setattr(tsmm, "tsmm_t", spy_tsmm_t)

    m, k, n = 4096, 16, 8
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a, b = _rand(k1, (m, k)), _rand(k2, (k, n))
    jax.grad(lambda a_, b_: jnp.sum(
        ops.tsm2l(a_, b_, interpret=True)))(a, b)
    assert ("tsmm", "tsm2l") in calls       # Abar path
    assert ("tsmm_t", "tsmt") in calls      # Bbar path


# ---------------------------------------------------------------------------
# Finite differences (directional) and the escape hatch
# ---------------------------------------------------------------------------

def test_finite_difference_directional():
    m, k, n = 2048, 8, 8
    assert tsmm.classify_gemm(m, k, n) == "tsm2l"
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    a, b = _rand(k1, (m, k)), _rand(k2, (k, n))
    da_dir = _rand(k3, (m, k)) / m  # keep the perturbation small

    def loss(a_):
        return jnp.sum(jnp.tanh(tsmm.tsmm(a_, b, interpret=True)))

    eps = 1e-2
    fd = (loss(a + eps * da_dir) - loss(a - eps * da_dir)) / (2 * eps)
    analytic = jnp.vdot(jax.grad(loss)(a), da_dir)
    np.testing.assert_allclose(float(fd), float(analytic), rtol=1e-2)


def test_repro_tsmm_off_forces_dense(monkeypatch):
    """The deprecated env var still works as a process-default alias: it is
    read into the default GemmPolicy (on refresh), not per-trace."""
    monkeypatch.setenv("REPRO_TSMM", "off")
    try:
        with pytest.deprecated_call():
            tsmm.refresh_default_policy()
        assert tsmm.default_policy().mode == "dense"
        assert not tsmm.enabled()
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        a, b = _rand(k1, (4096, 16)), _rand(k2, (16, 8))
        # Dense path: still correct, still differentiable.
        with tsmm.record_dispatches() as log:
            np.testing.assert_allclose(np.asarray(tsmm.tsmm(a, b)),
                                       np.asarray(ref.tsm2r_ref(a, b)), **TOL)
        assert [e.executor for e in log] == ["dense-xla"]
        da, db = _grads(tsmm.tsmm, a, b, jnp.ones((4096, 8)))
        ra, rb = _grads(ref.tsm2r_ref, a, b, jnp.ones((4096, 8)))
        np.testing.assert_allclose(np.asarray(da), np.asarray(ra), **TOL)
        np.testing.assert_allclose(np.asarray(db), np.asarray(rb), **TOL)
    finally:
        monkeypatch.delenv("REPRO_TSMM")
        tsmm.refresh_default_policy()
    assert tsmm.enabled()
