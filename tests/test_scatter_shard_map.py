"""Sharded-output (psum_scatter) executor under a real >1-device mesh.

Like tests/test_shard_map.py this runs in a subprocess with
``--xla_force_host_platform_device_count=2`` (JAX pins the device count at
first backend use). The script asserts, via the dispatch spy and
addressable-shard shapes (no ``jax.debug.visualize`` parsing):

* ``tsmm_t`` under ``reduce="psum_scatter"`` routes through the
  ``shard_map-scatter`` executor down to a per-shard kernel, returns the
  same global values as the dense oracle, and the output lives row-sharded
  across the mesh (each device holds an (a/2, b) slab);
* a scatter axis that doesn't divide the shard count falls back to dense
  (and ``shard_map="require"`` raises instead);
* gradients route with the matching collective: the weight-gradient
  ``tsmm_t`` inside ``layers.dense``'s custom VJP lands on the scatter
  executor and the parameter grad arrives sharded -- no all-gather;
* the sharded PowerSGD protocol (``compress_one_sharded``) matches the
  replicated-psum oracle numerically, with the Q factor state sharded;
* PowerSGD ``compress="int8"`` keeps the sharded schedule consistent
  with the replicated oracle within the quantization envelope, shards
  stay bit-consistent row slabs of the assembled factor;
* ``dp_axes`` derivation: an unconventionally named single-axis mesh
  ("replica") still routes through shard_map.

This file is in the ruff-format ratchet set (see ci.yml) -- keep edits
formatter-clean.
"""

import os
import pathlib
import re
import subprocess
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]

_SCRIPT = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import tsmm
from repro.kernels import compat
from repro.models import layers
from repro.optim import powersgd

devs = jax.devices()
assert len(devs) == 2, f"expected 2 host devices, got {len(devs)}"
mesh = Mesh(np.array(devs), ("data",))

x = jax.random.normal(jax.random.PRNGKey(2), (8192, 64), jnp.float32)
y = jax.random.normal(jax.random.PRNGKey(3), (8192, 8), jnp.float32)

# --- scatter executor: sharded output, oracle values ---------------------
with mesh:
    with tsmm.policy(reduce="psum_scatter"):
        with tsmm.record_dispatches() as log:
            q = jax.jit(lambda x_, y_: tsmm.tsmm_t(x_, y_))(x, y)
execs = [(e.entry, e.kind, e.executor, e.shape) for e in log]
assert ("mmt", "tsmt", "shard_map-scatter", (8192, 64, 8)) in execs, execs
# per-shard re-dispatch runs the kernel on the LOCAL tall-skinny shape
assert ("mmt", "tsmt", "pallas-tpu", (4096, 64, 8)) in execs, execs
assert q.shape == (64, 8), q.shape
shards = {s.device: s.data.shape for s in q.addressable_shards}
assert len(shards) == 2, shards
assert set(shards.values()) == {(32, 8)}, shards
np.testing.assert_allclose(np.asarray(q), np.asarray(x.T @ y),
                           rtol=2e-3, atol=2e-3)

# --- scatter axis doesn't divide: dense fallback / require raises --------
x63 = x[:, :63]
with mesh:
    with tsmm.policy(reduce="psum_scatter"):
        with tsmm.record_dispatches() as log:
            jax.jit(lambda x_, y_: tsmm.tsmm_t(x_, y_))(x63, y)
        assert [e.executor for e in log] == ["dense-xla"], log
        try:
            with tsmm.policy(shard_map="require"):
                tsmm.tsmm_t(x63, y)
        except RuntimeError as e:
            assert "psum_scatter" in str(e), e
        else:
            raise AssertionError("require + indivisible scatter did not raise")

# --- psum default is untouched: replicated output ------------------------
with mesh:
    with tsmm.record_dispatches() as log:
        q_rep = jax.jit(lambda x_, y_: tsmm.tsmm_t(x_, y_))(x, y)
assert ("mmt", "tsmt", "shard_map") in {
    (e.entry, e.kind, e.executor) for e in log
}, log
assert {s.data.shape for s in q_rep.addressable_shards} == {(64, 8)}, "not replicated"

# --- grads: weight grad lands on the scatter executor, sharded -----------
w = jax.random.normal(jax.random.PRNGKey(4), (256, 8), jnp.float32)
xs = jax.random.normal(jax.random.PRNGKey(5), (8192, 256), jnp.float32)
pol = tsmm.GemmPolicy(reduce="psum_scatter", param_dtype_grads=True)
with mesh:
    with tsmm.policy(pol):
        with tsmm.record_dispatches() as log:
            g = jax.jit(jax.grad(lambda w_, x_: jnp.sum(layers.dense(w_, x_))))
            dw = g(w, xs)
execs = {(e.entry, e.kind, e.executor) for e in log}
assert ("mmt", "tsmt", "shard_map-scatter") in execs, execs
assert {s.data.shape for s in dw.addressable_shards} == {(128, 8)}, "dw not sharded"
ref_dw = jax.grad(lambda w_, x_: jnp.sum(x_ @ w_))(w, xs)
np.testing.assert_allclose(np.asarray(dw), np.asarray(ref_dw),
                           rtol=2e-3, atol=2e-3)

# --- PowerSGD: sharded protocol == replicated-psum oracle ----------------
from jax.sharding import PartitionSpec as P

cfg = powersgd.PowerSGDConfig(rank=4, min_size=0)
d1, d2 = 4096, 512
grads = jax.random.normal(jax.random.PRNGKey(0), (2, d1, d2), jnp.float32)
state0 = powersgd.init(cfg, {"w": jnp.zeros((d1, d2))}, jax.random.PRNGKey(17))
approx_o, st_o = powersgd.compress_one(cfg, grads.mean(0), state0["w"])


def body(g_local):
    st = powersgd.shard_state(state0, "data")["w"]
    assert st["q"].shape == (d2 // 2, cfg.rank), st["q"].shape
    approx, st2 = powersgd.compress_one_sharded(cfg, g_local[0], st, axis="data")
    return approx, st2["q"]


f = compat.shard_map(
    body,
    mesh=mesh,
    in_specs=(P("data", None, None),),
    out_specs=(P(None, None), P("data", None)),
)
with mesh:
    approx_s, q_s = jax.jit(f)(grads)
np.testing.assert_allclose(np.asarray(approx_s), np.asarray(approx_o),
                           rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(q_s), np.asarray(st_o["q"]),
                           rtol=1e-4, atol=1e-4)
assert {s.data.shape for s in q_s.addressable_shards} == {(d2 // 2, cfg.rank)}

# --- PowerSGD orth="tsqr": tree-TSQR orthogonalization == oracle ---------
# Same protocol with the P factor orthogonalized by the distributed
# tree-TSQR (psum_scatter + per-shard CholeskyQR2) instead of pmean +
# replicated Gram-Schmidt/tsqr. The replicated oracle uses tsqr too, so
# sharded and oracle must agree numerically, and the whole compress must
# stay on the kernel executors (the collectives are raw lax/compat
# calls, so every dispatch event is a per-shard kernel execution).
cfg_qr = powersgd.PowerSGDConfig(rank=4, min_size=0, orth="tsqr")
approx_oq, st_oq = powersgd.compress_one(cfg_qr, grads.mean(0), state0["w"])


def body_qr(g_local):
    st = powersgd.shard_state(state0, "data")["w"]
    approx, st2 = powersgd.compress_one_sharded(cfg_qr, g_local[0], st, axis="data")
    return approx, st2["q"]


f_qr = compat.shard_map(
    body_qr,
    mesh=mesh,
    in_specs=(P("data", None, None),),
    out_specs=(P(None, None), P("data", None)),
)
with mesh:
    with tsmm.record_dispatches() as log:
        approx_sq, q_sq = jax.jit(f_qr)(grads)
np.testing.assert_allclose(np.asarray(approx_sq), np.asarray(approx_oq),
                           rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(q_sq), np.asarray(st_oq["q"]),
                           rtol=1e-4, atol=1e-4)
assert {s.data.shape for s in q_sq.addressable_shards} == {(d2 // 2, cfg_qr.rank)}
assert {e.executor for e in log} == {"pallas-tpu"}, log

# --- PowerSGD compress="int8": quantized factor collectives --------------
# Each rank symmetric-quantizes its local P/Q projection immediately
# before the DP collective (the int8 wire format). The sharded schedule
# must stay consistent with the replicated oracle within the
# quantization envelope (per-rank noise <= half a step of the local
# absmax -- NOT bit-exact like the f32 arms above), and the Q factor
# state must stay row-sharded.
cfg_i8 = powersgd.PowerSGDConfig(rank=4, min_size=0, compress="int8")
approx_oi, st_oi = powersgd.compress_one(cfg_i8, grads.mean(0), state0["w"])


def body_i8(g_local):
    st = powersgd.shard_state(state0, "data")["w"]
    approx, st2 = powersgd.compress_one_sharded(cfg_i8, g_local[0], st, axis="data")
    return approx, st2["q"]


f_i8 = compat.shard_map(
    body_i8,
    mesh=mesh,
    in_specs=(P("data", None, None),),
    out_specs=(P(None, None), P("data", None)),
)
with mesh:
    approx_si, q_si = jax.jit(f_i8)(grads)
tol_a = 2e-2 * np.abs(np.asarray(approx_oi)).max()
assert np.abs(np.asarray(approx_si) - np.asarray(approx_oi)).max() <= tol_a
tol_q = 2e-2 * np.abs(np.asarray(st_oi["q"])).max()
assert np.abs(np.asarray(q_si) - np.asarray(st_oi["q"])).max() <= tol_q
assert {s.data.shape for s in q_si.addressable_shards} == {(d2 // 2, cfg_i8.rank)}
# the assembled Q is exactly its row shards stacked in order: the scatter
# left each rank a bit-consistent slab of the quantized-mean factor
slabs = sorted(q_si.addressable_shards, key=lambda s: s.index[0].start or 0)
np.testing.assert_array_equal(
    np.asarray(q_si), np.concatenate([np.asarray(s.data) for s in slabs])
)

# --- split reduction per shard: collective contracts unchanged -----------
# GemmPolicy.split composes with reduce=: partials are summed inside each
# shard's kernel epilogue, so the psum arm stays replicated and the
# psum_scatter arm stays row-sharded, both oracle-equal; the split knob is
# visible on every dispatch event down to the per-shard re-dispatch.
for reduce_, expect_exec, expect_shard in (
    ("psum", "shard_map", (64, 8)),
    ("psum_scatter", "shard_map-scatter", (32, 8)),
):
    with mesh:
        with tsmm.policy(reduce=reduce_, split=2):
            with tsmm.record_dispatches() as log:
                q_split = jax.jit(lambda x_, y_: tsmm.tsmm_t(x_, y_))(x, y)
    execs = {(e.executor, e.split) for e in log}
    assert (expect_exec, 2) in execs, (reduce_, execs)
    assert ("pallas-tpu", 2) in execs, (reduce_, execs)
    assert {s.data.shape for s in q_split.addressable_shards} == {
        expect_shard
    }, (reduce_, q_split.addressable_shards)
    np.testing.assert_allclose(
        np.asarray(q_split), np.asarray(x.T @ y), rtol=2e-3, atol=2e-3
    )

# --- dp_axes derived from an unconventionally named mesh -----------------
mesh_r = Mesh(np.array(devs), ("replica",))
assert tsmm.derive_dp_axes(mesh_r) == ("replica",)
with mesh_r:
    with tsmm.policy(reduce="psum_scatter"):
        with tsmm.record_dispatches() as log:
            jax.jit(lambda x_, y_: tsmm.tsmm_t(x_, y_))(x, y)
assert "shard_map-scatter" in {e.executor for e in log}, log
# explicit override still wins: dp_axes naming no axis on the mesh -> no DP
with mesh_r:
    with tsmm.policy(reduce="psum_scatter", dp_axes=("data",)):
        with tsmm.record_dispatches() as log:
            jax.jit(lambda x_, y_: tsmm.tsmm_t(x_, y_))(x, y)
assert {e.executor for e in log} == {"dense-xla"}, log
print("SCATTER_SHARD_MAP_OK")
"""


def _two_device_env():
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "", flags)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count=2 {flags}".strip()
    env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("REPRO_TSMM", None)
    return env


def test_scatter_executor_on_two_device_mesh():
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=_two_device_env(),
        capture_output=True,
        text=True,
        timeout=600,
        cwd=_ROOT,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SCATTER_SHARD_MAP_OK" in r.stdout
