"""Kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracles.

Sweeps shapes/dtypes per the deliverable spec, plus hypothesis property
tests on GEMM invariants (linearity, zero-padding exactness, transpose
consistency).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import perf_model, tsmm
from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    x = jax.random.uniform(key, shape, jnp.float32, minval=-1.0, maxval=1.0)
    return x.astype(dtype)


def _tol(dtype):
    # f32: blocked accumulation reorders long reductions vs the single-dot
    # oracle; bf16: inputs are quantized before the f32 accumulation.
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# TSM2R: m ~ k >> n  (paper n in {2,4,8,16}; we extend to 32)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [
    (1024, 1024, 2),      # paper's smallest aspect
    (2048, 1024, 4),
    (1536, 2048, 8),      # non-square (paper Fig. 12)
    (1000, 777, 16),      # non-divisible: exercises padding
    (4096, 512, 32),
    (512, 512, 1),        # degenerate n=1 (GEMV edge)
])
def test_tsm2r_matches_ref(m, k, n, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(m * 7 + n))
    a, b = _rand(ka, (m, k), dtype), _rand(kb, (k, n), dtype)
    got = ops.tsm2r(a, b, interpret=True)
    want = ref.tsm2r_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("bm,bk", [(256, 128), (512, 512), (1024, 256)])
def test_tsm2r_block_sweep(bm, bk):
    """Any legal block shape must give identical numerics."""
    a = _rand(jax.random.PRNGKey(0), (2048, 1024), jnp.float32)
    b = _rand(jax.random.PRNGKey(1), (1024, 8), jnp.float32)
    got = ops.tsm2r(a, b, block_m=bm, block_k=bk, interpret=True)
    # rtol: blocked f32 accumulation (k/bk partial sums) reorders the long
    # reduction vs the single-dot oracle; identical numerics ACROSS block
    # shapes is covered by comparing every (bm, bk) to the same oracle.
    np.testing.assert_allclose(got, ref.tsm2r_ref(a, b), rtol=1e-4, atol=1e-5)


def test_tsm2r_block_quantization_matches_model(monkeypatch):
    """Regression (k % 128 != 0): the runtime block_k clamp must use the
    same lane quantization as the perf model's candidate filter. The old
    ``_ceil_mult(k, 8)`` clamp could shrink the chosen block_k (e.g. 256 ->
    136 at k=130) to a shape the VMEM budget was never checked against."""
    seen = {}
    orig = ops.tsm2r_pallas

    def spy(a, b, *, block_m, block_k, interpret=None):
        seen.update(block_m=block_m, block_k=block_k)
        return orig(a, b, block_m=block_m, block_k=block_k,
                    interpret=interpret)

    monkeypatch.setattr(ops, "tsm2r_pallas", spy)
    m, k, n = 4096, 130, 8
    a = _rand(jax.random.PRNGKey(0), (m, k), jnp.float32)
    b = _rand(jax.random.PRNGKey(1), (k, n), jnp.float32)
    got = ops.tsm2r(a, b, interpret=True)
    bm, bk, _ = perf_model.choose_params_tsm2r(m, k, n, perf_model.V5E,
                                               a.dtype)
    assert (seen["block_m"], seen["block_k"]) == (bm, bk)
    assert seen["block_k"] % perf_model.V5E.lane == 0
    np.testing.assert_allclose(got, ref.tsm2r_ref(a, b), rtol=1e-4, atol=1e-4)


def test_tsmt_block_quantization_matches_model(monkeypatch):
    """Same rule for the transposed kernel's lane dim (block_a)."""
    seen = {}
    orig = ops.tsmt_pallas

    def spy(x, y, *, block_m, block_a, interpret=None):
        seen.update(block_m=block_m, block_a=block_a)
        return orig(x, y, block_m=block_m, block_a=block_a,
                    interpret=interpret)

    monkeypatch.setattr(ops, "tsmt_pallas", spy)
    m, a_dim, b_dim = 4096, 130, 8
    x = _rand(jax.random.PRNGKey(2), (m, a_dim), jnp.float32)
    y = _rand(jax.random.PRNGKey(3), (m, b_dim), jnp.float32)
    got = ops.tsmt(x, y, interpret=True)
    bm, ba, _ = perf_model.choose_params_tsmt(m, a_dim, b_dim, perf_model.V5E,
                                              x.dtype)
    assert (seen["block_m"], seen["block_a"]) == (bm, ba)
    np.testing.assert_allclose(got, ref.tsmt_ref(x, y), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# TSM2L: m >> k ~ n  (paper k = n in {8, 16}; m up to 1e7 -- scaled down)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [
    (8192, 8, 8),
    (16384, 16, 16),
    (10000, 16, 8),       # non-divisible m
    (4096, 4, 4),         # paper's 102400x4 @ 4x4 case, scaled
    (8192, 16, 2),
])
def test_tsm2l_matches_ref(m, k, n, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(m + n))
    a, b = _rand(ka, (m, k), dtype), _rand(kb, (k, n), dtype)
    got = ops.tsm2l(a, b, interpret=True)
    want = ref.tsm2l_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("bm", [256, 1024, 4096])
def test_tsm2l_tcf_sweep(bm):
    """block_m (the tcf analogue) never changes numerics."""
    a = _rand(jax.random.PRNGKey(2), (8192, 16), jnp.float32)
    b = _rand(jax.random.PRNGKey(3), (16, 16), jnp.float32)
    got = ops.tsm2l(a, b, block_m=bm, interpret=True)
    np.testing.assert_allclose(got, ref.tsm2l_ref(a, b), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# TSMT: C = X^T Y over huge m (PowerSGD / ABFT shapes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,a,b", [
    (8192, 128, 8),       # PowerSGD Q = G^T P with r=8
    (4096, 512, 4),
    (10000, 300, 16),     # non-divisible everywhere
    (16384, 64, 2),       # ABFT checksum verify
])
def test_tsmt_matches_ref(m, a, b, dtype):
    kx, ky = jax.random.split(jax.random.PRNGKey(m + a + b))
    x, y = _rand(kx, (m, a), dtype), _rand(ky, (m, b), dtype)
    got = ops.tsmt(x, y, interpret=True)
    want = ref.tsmt_ref(x, y)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# Optimization-ladder restatements agree with each other
# ---------------------------------------------------------------------------

def test_v0_v1_ladder_agree():
    a = _rand(jax.random.PRNGKey(4), (512, 256), jnp.float32)
    b = _rand(jax.random.PRNGKey(5), (256, 4), jnp.float32)
    base = ref.tsm2r_ref(a, b)
    np.testing.assert_allclose(ref.tsm2r_v0_inner(a, b), base, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ref.tsm2r_v1_outer(a, b), base, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Property tests (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(64, 600), k=st.integers(32, 300), n=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_tsm2r_linearity(m, k, n, seed):
    """tsm2r(a1 + a2, b) == tsm2r(a1, b) + tsm2r(a2, b)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    a1 = _rand(k1, (m, k), jnp.float32)
    a2 = _rand(k2, (m, k), jnp.float32)
    b = _rand(k3, (k, n), jnp.float32)
    lhs = ops.tsm2r(a1 + a2, b, block_m=256, block_k=128, interpret=True)
    rhs = (ops.tsm2r(a1, b, block_m=256, block_k=128, interpret=True)
           + ops.tsm2r(a2, b, block_m=256, block_k=128, interpret=True))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(64, 500), k=st.integers(2, 32), n=st.integers(2, 32),
       seed=st.integers(0, 2**31 - 1))
def test_tsm2l_transpose_consistency(m, k, n, seed):
    """(A @ B)^T == tsmt(A, ...) relationship: (AB)^T = B^T A^T checked via oracle."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = _rand(k1, (m, k), jnp.float32)
    b = _rand(k2, (k, n), jnp.float32)
    ab = ops.tsm2l(a, b, block_m=256, interpret=True)
    np.testing.assert_allclose(ab, ref.tsm2r_ref(a, b), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(256, 2000), a=st.integers(8, 128), b=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_tsmt_equals_transpose_matmul(m, a, b, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, (m, a), jnp.float32)
    y = _rand(k2, (m, b), jnp.float32)
    got = ops.tsmt(x, y, block_m=256, block_a=64, interpret=True)
    np.testing.assert_allclose(got, x.T @ y, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Dispatcher + perf model
# ---------------------------------------------------------------------------

def test_dispatch_classification():
    assert tsmm.classify_gemm(20480, 20480, 2) == "tsm2r"     # paper case (i)
    assert tsmm.classify_gemm(102400, 4, 4) == "tsm2l"        # paper case (ii)
    assert tsmm.classify_gemm(4096, 4096, 4096) == "dense"
    assert tsmm.classify_gemm(128, 128, 2) == "dense"         # too small to matter


def test_dispatch_numerics():
    a = _rand(jax.random.PRNGKey(6), (4096, 2048), jnp.float32)
    b = _rand(jax.random.PRNGKey(7), (2048, 4), jnp.float32)
    np.testing.assert_allclose(tsmm.tsmm(a, b, interpret=True),
                               ref.tsm2r_ref(a, b), rtol=2e-3, atol=1e-4)


def test_perf_model_bound_classes():
    # Paper Section 1's three regimes:
    assert perf_model.classify(20480, 20480, 2) == "memory"
    assert perf_model.classify(20480, 20480, 4096) == "compute"
    assert perf_model.classify(10_000_000, 16, 16) == "latency"


def test_perf_model_threshold_value():
    # v5e bf16: 197e12 / 819e9 * 2 bytes ~ 481 -- all paper n are memory-bound.
    t = perf_model.t2_threshold()
    assert 400 < t < 600


def test_param_chooser_respects_vmem():
    bm, bk, _ = perf_model.choose_params_tsm2r(30720, 30720, 16)
    use = perf_model.tsm2r_vmem_usage(bm, bk, 16, jnp.bfloat16)
    assert use <= perf_model.V5E.vmem_bytes * perf_model.V5E.vmem_usable
    assert bm % 8 == 0 and bk % 8 == 0


def test_param_chooser_tsm2l_prefers_fat_blocks():
    """Paper Fig. 5: for m=1e7, launching fewer/fatter units wins."""
    bm_small_m = perf_model.choose_params_tsm2l(20_000, 16, 16)
    bm_huge_m = perf_model.choose_params_tsm2l(10_000_000, 16, 16)
    assert bm_huge_m >= bm_small_m
