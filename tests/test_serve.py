"""Serve engine: batched generation, greedy==teacher-forced argmax,
temperature sampling validity, cross-arch cache reuse."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import model
from repro.serve import engine


@pytest.mark.parametrize("arch", ["llama3.2-3b", "zamba2-1.2b", "rwkv6-1.6b"])
def test_generate_greedy_matches_teacher_forcing(arch):
    cfg = registry.get_config(arch, smoke=True)
    params = model.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                 cfg.vocab_size)
    out = engine.generate(params, cfg, prompts, max_new=6)
    assert out.shape == (2, 6)

    full = jnp.concatenate([prompts, out], axis=1)
    logits, _ = model.forward(params, cfg, {"tokens": full})
    for t in range(6):
        expect = jnp.argmax(logits[:, 12 + t - 1], -1)
        np.testing.assert_array_equal(np.asarray(out[:, t]),
                                      np.asarray(expect))


def test_generate_sampling_in_vocab_and_varies():
    cfg = registry.get_config("chatglm3-6b", smoke=True)
    params = model.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    s1 = engine.generate(params, cfg, prompts, 8, temperature=1.0,
                         key=jax.random.PRNGKey(2))
    s2 = engine.generate(params, cfg, prompts, 8, temperature=1.0,
                         key=jax.random.PRNGKey(3))
    assert (np.asarray(s1) >= 0).all() and (np.asarray(s1) < cfg.vocab_size).all()
    assert not np.array_equal(np.asarray(s1), np.asarray(s2))


def test_sample_token_greedy_vs_temperature():
    logits = jnp.array([[1.0, 5.0, 2.0]])
    tok = engine.sample_token(jax.random.PRNGKey(0), logits, temperature=0.0)
    assert int(tok[0]) == 1
    # near-zero temperature sampling concentrates on the argmax
    tok2 = engine.sample_token(jax.random.PRNGKey(0), logits, temperature=0.01)
    assert int(tok2[0]) == 1


def test_sharded_projections_flag_matches_default_off_mesh():
    """sharded_projections scopes reduce="psum_scatter" around the serve
    steps; off-mesh the knob changes nothing, so outputs must be identical
    (the >=2-device layout behavior is pinned in the scatter subprocess
    tests)."""
    from repro.core import tsmm

    cfg = registry.get_config("llama3.2-3b", smoke=True)
    params = model.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                 cfg.vocab_size)
    base = engine.generate(params, cfg, prompts, max_new=4)
    sharded = engine.generate(params, cfg, prompts, max_new=4,
                              sharded_projections=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(sharded))

    # the scope really is applied while the step body runs (trace time)
    prefill, _ = engine.make_serve_fns(cfg, sharded_projections=True)
    seen = {}
    real_prefill = model.prefill
    def spy_prefill(params_, cfg_, batch_, cache_):
        seen["reduce"] = tsmm.current_policy().reduce
        return real_prefill(params_, cfg_, batch_, cache_)
    engine.model.prefill = spy_prefill
    try:
        cache = model.init_cache(cfg, 1, 12)
        jax.eval_shape(prefill, params, {"tokens": prompts}, cache)
    finally:
        engine.model.prefill = real_prefill
    assert seen["reduce"] == "psum_scatter"
    # and no leakage outside the step: scope is per-call, not process state
    assert tsmm.current_policy().reduce == "psum"
