"""Deterministic fault injection (ft/inject.py): bit-flip mechanics,
scope/site semantics, tree poisoning, and the host-side checkpoint
corruptors against the Checkpointer's integrity machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import tsmm
from repro.ft import inject


def test_flip_bit_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)),
                    jnp.float32)
    for bit in (0, 13, 29, 31):
        y = inject.flip_bit(x, 3, 5, bit)
        assert np.asarray(y[3, 5]) != np.asarray(x[3, 5])
        back = inject.flip_bit(y, 3, 5, bit)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
        # everything else untouched
        mask = np.ones(x.shape, bool)
        mask[3, 5] = False
        np.testing.assert_array_equal(np.asarray(y)[mask],
                                      np.asarray(x)[mask])


def test_flip_bit_bf16_and_ndim():
    x = jnp.ones((2, 3, 4), jnp.bfloat16)
    y = inject.flip_bit(x, 1, 2, 14)  # 2-D view is (6, 4)
    assert y.shape == x.shape and y.dtype == x.dtype
    diff = np.asarray(y, np.float32) != np.asarray(x, np.float32)
    assert diff.sum() == 1
    with pytest.raises(ValueError, match=r"\[inject-bit\]"):
        inject.flip_bit(x, 0, 0, 16)


def test_fault_validation():
    with pytest.raises(ValueError, match=r"\[inject-operand\]"):
        inject.GemmFault(site=0, operand="c")
    with pytest.raises(ValueError, match=r"\[inject-fault\]"):
        inject.GemmFault(site=-1)
    with pytest.raises(TypeError, match=r"\[inject-plan\]"):
        with inject.faults("not-a-fault"):
            pass


def test_scope_inactive_is_noop():
    assert not inject.active()
    x, y = jnp.ones((4096, 16)), jnp.ones((4096, 16))
    with tsmm.policy(interpret=True):
        a = np.asarray(tsmm.tsmm_t(x, y))
        with inject.faults() as scope:
            b = np.asarray(tsmm.tsmm_t(x, y))
    np.testing.assert_array_equal(a, b)
    assert scope.sites_seen == 1 and scope.applied == []
    assert not inject.active()


def test_site_counter_is_deterministic():
    x, y = jnp.ones((4096, 16)), jnp.ones((4096, 16))
    f = inject.GemmFault(site=0, operand="out", row=1, col=1, bit=29)
    outs = []
    for _ in range(2):
        with tsmm.policy(interpret=True), inject.faults(f) as scope:
            outs.append(np.asarray(tsmm.tsmm_t(x, y)))
        assert scope.applied == [f]
    np.testing.assert_array_equal(outs[0], outs[1])
    # a site past the trace is never applied
    far = inject.GemmFault(site=99, operand="out")
    with tsmm.policy(interpret=True), inject.faults(far) as scope:
        np.asarray(tsmm.tsmm_t(x, y))
    assert scope.applied == [] and scope.sites_seen == 1


def test_poison_tree():
    tree = {"a": jnp.ones((3, 3)), "n": jnp.int32(2), "b": jnp.ones((4,))}
    out = inject.poison_tree(tree)
    leaves = [x for x in jax.tree.leaves(out)
              if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)]
    assert sum(int(np.isnan(np.asarray(x)).sum()) for x in leaves) == 1
    assert int(np.asarray(out["n"])) == 2
    with pytest.raises(ValueError, match=r"\[inject-poison\]"):
        inject.poison_tree({"n": jnp.int32(1)})


def _save_steps(tmp_path, steps=(1, 2)):
    ckpt = Checkpointer(str(tmp_path), async_write=False)
    for s in steps:
        ckpt.save(s, {"w": jnp.full((64, 8), float(s)),
                      "b": jnp.ones((8,))})
    return ckpt


def test_corrupt_checkpoint_bitflip_caught_by_crc(tmp_path):
    ckpt = _save_steps(tmp_path)
    target = inject.corrupt_checkpoint(str(tmp_path), mode="bitflip", seed=3)
    assert target.endswith(".npy")
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(2)
    restored, step = ckpt.restore_latest_good()
    assert step == 1
    np.testing.assert_array_equal(restored["w"], np.full((64, 8), 1.0))


def test_corrupt_checkpoint_truncate_survived(tmp_path):
    ckpt = _save_steps(tmp_path)
    inject.corrupt_checkpoint(str(tmp_path), mode="truncate", seed=0)
    with pytest.raises(Exception):
        ckpt.restore(2)
    _, step = ckpt.restore_latest_good()
    assert step == 1


def test_corrupt_checkpoint_torn_tmp_ignored(tmp_path):
    ckpt = _save_steps(tmp_path)
    d = inject.corrupt_checkpoint(str(tmp_path), mode="torn-tmp", seed=0)
    assert d.endswith(".tmp")
    assert ckpt.latest_step() == 2  # torn dir invisible to restore
    _, step = ckpt.restore_latest_good()
    assert step == 2


def test_corrupt_checkpoint_is_seeded(tmp_path):
    _save_steps(tmp_path)
    t1 = inject.corrupt_checkpoint(str(tmp_path), mode="bitflip", seed=7)
    # same seed on a fresh identical dir picks the same target file
    import shutil
    other = tmp_path / "other"
    shutil.copytree(tmp_path, other, ignore=shutil.ignore_patterns("other"))
    t2 = inject.corrupt_checkpoint(str(other), mode="bitflip", seed=7)
    assert t1.split("/")[-2:] == t2.split("/")[-2:]
    with pytest.raises(ValueError, match=r"\[inject-ckpt-mode\]"):
        inject.corrupt_checkpoint(str(tmp_path), mode="zero")


def test_restore_latest_good_no_good_checkpoints(tmp_path):
    ckpt = _save_steps(tmp_path, steps=(1,))
    inject.corrupt_checkpoint(str(tmp_path), mode="truncate", seed=0, step=1)
    with pytest.raises(FileNotFoundError, match=r"\[ckpt-none-good\]"):
        ckpt.restore_latest_good()
