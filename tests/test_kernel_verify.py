"""Grid-dataflow verifier (`repro.analysis.kernel_verify`).

Three tiers, mirroring tests/test_contracts.py:

* capture units -- the compat.pallas_call shim records exactly the launch
  the committed entries construct (grid, specs, semantics, scratch), and
  corner sampling kicks in above the cell limit;
* acceptance -- seeded-broken kernels (swapped output index map, missing
  pl.when init guard, parallel tag on the reduction dim, bf16 scratch
  accumulator, out-of-bounds map, unguarded flush) are each rejected with
  the right rule id;
* clean tree -- every committed kernel at representative configs, and the
  full audit_kernel_dataflow sweep arm, verify clean.
"""

import math

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis import audit, contracts, kernel_verify
from repro.core import perf_model
from repro.kernels import compat

F32 = jnp.float32
BF16 = jnp.bfloat16


def _rules(violations):
    return [v.rule for v in violations]


def _capture_one(build, *operands):
    """LaunchCapture of a single compat.pallas_call launch, traced
    abstractly (the same path capture_kernel takes for committed entries).

    ``build`` is a zero-arg callable constructing the launch: the shim
    decides whether to record at *construction* time, so the build must
    happen inside the capture scope (as the committed entries' do)."""
    with compat.capture_launches() as log:
        jax.eval_shape(build(), *operands)
    assert len(log) == 1, log
    return log[0]


# ---------------------------------------------------------------------------
# Capture units
# ---------------------------------------------------------------------------

def test_capture_records_committed_tsm2r_launch():
    caps = kernel_verify.capture_kernel(
        "tsm2r", (256, 512, 8), {"block_m": 64, "block_k": 128}, F32)
    assert len(caps) == 1
    cap = caps[0]
    assert cap.name == "_tsm2r_kernel"
    assert cap.grid == (4, 4)
    assert cap.dimension_semantics == ("parallel", "arbitrary")
    assert [s.block_shape for s in cap.in_specs] == [(64, 128), (128, 8)]
    assert [tuple(o.shape) for o in cap.operands] == [(256, 512), (512, 8)]
    assert [tuple(o.shape) for o in cap.out_shapes] == [(256, 8)]
    (scratch,) = cap.scratch_shapes
    assert tuple(scratch.shape) == (64, 8)
    assert jnp.dtype(scratch.dtype) == F32
    # index maps are the raw callables, evaluable with plain ints
    assert cap.in_specs[0].index_map(2, 3) == (2, 3)
    assert cap.out_specs[0].index_map(2, 3) == (2, 0)


def test_capture_is_scoped_and_nested():
    with compat.capture_launches() as outer:
        kernel_verify.capture_kernel("tsm2l", (128, 16, 8),
                                     {"block_m": 64}, F32)
    # capture_kernel opened its own inner scope; nothing leaks outward
    assert outer == []


def test_sample_cells_exhaustive_and_corner():
    cells, exhaustive = kernel_verify.sample_cells((4, 4))
    assert exhaustive and len(cells) == 16
    big = (128, 64)   # 8192 cells > EXHAUSTIVE_CELL_LIMIT
    assert math.prod(big) > kernel_verify.EXHAUSTIVE_CELL_LIMIT
    cells, exhaustive = kernel_verify.sample_cells(big)
    assert not exhaustive and len(cells) <= 5 ** len(big)
    for d, g in enumerate(big):   # corners per dim: 0, 1, mid, last-1, last
        assert {0, 1, g // 2, g - 2, g - 1} == {c[d] for c in cells}


# ---------------------------------------------------------------------------
# Seeded-broken kernels: each mutation rejected with its rule id
# ---------------------------------------------------------------------------

BM, BK, N = 64, 128, 8
M, K = 4 * BM, 4 * BK
A_SDS = jax.ShapeDtypeStruct((M, K), F32)
B_SDS = jax.ShapeDtypeStruct((K, N), F32)


def _tsm2r_like_launch(kernel, *, out_map, semantics=("parallel", "arbitrary"),
                       scratch_dtype=F32, out_dtype=F32, scratch=True):
    return compat.pallas_call(
        kernel,
        grid=(M // BM, K // BK),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j: (i, j)),
            pl.BlockSpec((BK, N), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BM, N), out_map),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=(
            [compat.VMEM((BM, N), scratch_dtype)] if scratch else []),
        compiler_params=compat.CompilerParams(dimension_semantics=semantics),
        interpret=True,
    )


def _good_kernel(a_ref, b_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def test_healthy_launch_verifies_clean():
    cap = _capture_one(
        lambda: _tsm2r_like_launch(_good_kernel, out_map=lambda i, j: (i, 0)),
        A_SDS, B_SDS)
    assert kernel_verify.verify_capture(cap) == []


def test_swapped_output_index_map_is_a_write_race():
    """Mutation 1: out map (j, 0) instead of (i, 0) -- cells that differ
    in the parallel m dim land on the same output block."""
    cap = _capture_one(
        lambda: _tsm2r_like_launch(_good_kernel, out_map=lambda i, j: (j, 0)),
        A_SDS, B_SDS)
    assert "write-race" in _rules(kernel_verify.verify_capture(cap))


def test_parallel_tag_on_reduction_dim_is_a_write_race():
    """Mutation 2: dimension_semantics ("parallel", "parallel") on the
    sequential-reduction kernel -- the k revisits now race."""
    cap = _capture_one(
        lambda: _tsm2r_like_launch(_good_kernel, out_map=lambda i, j: (i, 0),
                           semantics=("parallel", "parallel")),
        A_SDS, B_SDS)
    vios = kernel_verify.verify_capture(cap)
    assert _rules(vios) == ["write-race"]
    assert "parallel dims [0, 1]" in vios[0].detail


def test_missing_init_guard_is_revisit_init():
    """Mutation 3: direct-accumulation kernel without the
    pl.when(program_id == 0) zero-init."""
    def _no_init(a_ref, b_ref, o_ref):
        o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                              preferred_element_type=jnp.float32)

    cap = _capture_one(
        lambda: _tsm2r_like_launch(_no_init, out_map=lambda i, j: (i, 0),
                           scratch=False),
        A_SDS, B_SDS)
    vios = kernel_verify.verify_capture(cap)
    assert _rules(vios) == ["revisit-init"]
    assert "pl.when(pl.program_id(1) == 0)" in vios[0].detail


def test_bf16_scratch_accumulator_rejected():
    """Mutation 4: bf16 VMEM scratch -- partial accumulators must be f32
    regardless of operand dtype."""
    def _bf16_acc(a_ref, b_ref, o_ref, acc_ref):
        @pl.when(pl.program_id(1) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(a_ref[...],
                                b_ref[...]).astype(acc_ref.dtype)

        @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
        def _flush():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    cap = _capture_one(
        lambda: _tsm2r_like_launch(_bf16_acc, out_map=lambda i, j: (i, 0),
                                   scratch_dtype=BF16),
        A_SDS, B_SDS)
    assert "accumulator-dtype" in _rules(kernel_verify.verify_capture(cap))


def test_bf16_revisited_output_accumulator_rejected():
    """Same family, other site: a direct-accumulation kernel whose
    revisited *output* is bf16."""
    def _init_ok(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(1) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(a_ref[...], b_ref[...]).astype(o_ref.dtype)

    cap = _capture_one(
        lambda: _tsm2r_like_launch(_init_ok, out_map=lambda i, j: (i, 0),
                           scratch=False, out_dtype=BF16),
        A_SDS, B_SDS)
    assert _rules(kernel_verify.verify_capture(cap)) == ["accumulator-dtype"]


def test_out_of_bounds_index_map_rejected():
    """Mutation 5: off-by-one block offset reaches past the padded dim."""
    cap = _capture_one(
        lambda: _tsm2r_like_launch(_good_kernel, out_map=lambda i, j: (i + 1, 0)),
        A_SDS, B_SDS)
    vios = kernel_verify.verify_capture(cap)
    assert "index-bounds" in _rules(vios)


def test_unguarded_flush_is_revisit_flush():
    """Mutation 6: scratch-staged kernel writing the output every step
    instead of under the last-step flush guard."""
    def _no_flush(a_ref, b_ref, o_ref, acc_ref):
        @pl.when(pl.program_id(1) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                                preferred_element_type=jnp.float32)
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    cap = _capture_one(
        lambda: _tsm2r_like_launch(_no_flush, out_map=lambda i, j: (i, 0)),
        A_SDS, B_SDS)
    assert _rules(kernel_verify.verify_capture(cap)) == ["revisit-flush"]


def test_missing_scratch_init_behind_good_flush_is_revisit_init():
    """The flush guard alone is not enough: the scratch accumulator still
    needs its first-step zero-init."""
    def _no_scratch_init(a_ref, b_ref, o_ref, acc_ref):
        acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                                preferred_element_type=jnp.float32)

        @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
        def _flush():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    cap = _capture_one(
        lambda: _tsm2r_like_launch(_no_scratch_init, out_map=lambda i, j: (i, 0)),
        A_SDS, B_SDS)
    vios = kernel_verify.verify_capture(cap)
    assert _rules(vios) == ["revisit-init"]
    assert "scratch acc_ref" in vios[0].detail


def test_lambda_kernel_guard_unverifiable():
    """A revisited output whose kernel body can't be AST-inspected is
    reported, not silently passed."""
    cap = _capture_one(
        lambda: _tsm2r_like_launch(
            eval("lambda a_ref, b_ref, o_ref, acc_ref: None"),
            out_map=lambda i, j: (i, 0)),
        A_SDS, B_SDS)
    assert "guard-unverifiable" in _rules(kernel_verify.verify_capture(cap))


def test_semantics_arity_mismatch_rejected():
    cap = _capture_one(
        lambda: _tsm2r_like_launch(_good_kernel, out_map=lambda i, j: (i, 0),
                           semantics=("parallel",)),
        A_SDS, B_SDS)
    assert _rules(kernel_verify.verify_capture(cap)) == ["semantics-invalid"]


def test_corner_sampling_still_catches_swapped_map():
    """Above the cell limit the verifier samples corners -- and the
    swapped-map race is still caught there."""
    m, k = 128 * BM, 64 * BK   # grid (128, 64): 8192 cells, sampled
    cap = _capture_one(
        lambda: compat.pallas_call(
            _good_kernel,
            grid=(m // BM, k // BK),
            in_specs=[
                pl.BlockSpec((BM, BK), lambda i, j: (i, j)),
                pl.BlockSpec((BK, N), lambda i, j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((BM, N), lambda i, j: (j % 2, 0)),
            out_shape=jax.ShapeDtypeStruct((m, N), F32),
            scratch_shapes=[compat.VMEM((BM, N), F32)],
            compiler_params=compat.CompilerParams(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=True,
        ),
        jax.ShapeDtypeStruct((m, k), F32), jax.ShapeDtypeStruct((k, N), F32))
    _, exhaustive = kernel_verify.sample_cells(cap.grid)
    assert not exhaustive
    assert "write-race" in _rules(kernel_verify.verify_capture(cap))


# ---------------------------------------------------------------------------
# verify_kernel_config: capture plumbing + launch-meta drift
# ---------------------------------------------------------------------------

COMMITTED_CONFIGS = [
    ("tsm2r", (256, 512, 8), {"block_m": 64, "block_k": 128}),
    ("tsm2r", (256, 512, 8), {"block_m": 64, "block_k": 128, "splits": 2}),
    ("tsm2l", (256, 16, 8), {"block_m": 64}),
    ("tsmt", (256, 16, 16), {"block_m": 64, "block_a": 8}),
    ("tsmt", (256, 16, 16), {"block_m": 64, "block_a": 8, "splits": 2}),
    ("reduce", (4, 256, 128), {"block_r": 64}),
]


@pytest.mark.parametrize("kind,padded,params", COMMITTED_CONFIGS,
                         ids=[f"{k}-{'split' if dict(p).get('splits', 1) > 1 else 'seq'}"
                              for k, _, p in COMMITTED_CONFIGS])
@pytest.mark.parametrize("dtype", [BF16, F32])
def test_committed_kernels_verify_clean(kind, padded, params, dtype):
    vios, info = kernel_verify.verify_kernel_config(kind, padded, params,
                                                    dtype)
    assert vios == [], "\n".join(str(v) for v in vios)
    assert info["launches"] == 1 and info["exhaustive"]
    assert info["grid"] == contracts.launch_grid(kind, padded, params)[0]


def test_launch_meta_drift_detected(monkeypatch):
    """If the pure launch_grid derivation stops matching the real launch,
    verify_kernel_config says so (the DispatchEvent metadata would lie)."""
    real = contracts.launch_grid

    def skewed(kind, padded_shape, params):
        grid, sem = real(kind, padded_shape, params)
        return (grid[:-1] + (grid[-1] + 1,)), sem

    monkeypatch.setattr(contracts, "launch_grid", skewed)
    vios, _ = kernel_verify.verify_kernel_config(
        "tsm2l", (256, 16, 8), {"block_m": 64}, F32)
    assert _rules(vios) == ["launch-meta-drift"]


def test_capture_empty_reported(monkeypatch):
    """An entry that bypasses compat.pallas_call produces no capture --
    reported as capture-empty, not silently passed."""
    from repro.kernels import tsm2l

    def raw_entry(a, b, *, block_m, interpret=None):
        return jnp.zeros((a.shape[0], b.shape[1]), a.dtype)

    monkeypatch.setattr(tsm2l, "tsm2l_pallas", raw_entry)
    vios, info = kernel_verify.verify_kernel_config(
        "tsm2l", (256, 16, 8), {"block_m": 64}, F32)
    assert _rules(vios) == ["capture-empty"]
    assert info["launches"] == 0


# ---------------------------------------------------------------------------
# Audit integration
# ---------------------------------------------------------------------------

SMALL_SHAPES = {
    "tsm2r": ((2048, 512, 8),),
    "tsm2l": ((8192, 16, 16),),
    "tsmt": ((4096, 64, 8),),
}


def test_audit_kernel_dataflow_small_sweep_clean():
    checked, vios, meta = audit.audit_kernel_dataflow(
        shapes=SMALL_SHAPES, dtypes=(F32,), specs=(perf_model.V5E,),
        splits=("auto", 2))
    assert vios == [], "\n".join(str(v) for v in vios)
    assert checked > 0
    assert meta["cell_limit"] == kernel_verify.EXHAUSTIVE_CELL_LIMIT
    assert isinstance(meta["sampled"], list)


def test_audit_report_carries_kernel_dataflow_section():
    report = audit.run_audit(shapes=SMALL_SHAPES)
    sec = report["sections"]["kernel-dataflow"]
    assert sec["checked"] > 0 and sec["violations"] == []
    assert sec["cell_limit"] == kernel_verify.EXHAUSTIVE_CELL_LIMIT
    assert "sampled" in sec
