"""Fault tolerance: checkpoint roundtrip/atomicity/async, ABFT corruption
detection, watchdog, preemption, elastic data rebalance."""

import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data import pipeline
from repro.ft import abft, elastic, watchdog


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (512, 256)),
        "b": jnp.zeros((256,), jnp.bfloat16),
        "nested": {"m": jax.random.normal(k2, (512, 256)),
                   "step": jnp.int32(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_write=False)
    tree = _tree(jax.random.PRNGKey(0))
    ckpt.save(3, tree)
    restored, step = ckpt.restore()
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_async_and_retention(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep_n=2, async_write=True)
    tree = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4):
        ckpt.save(s, jax.tree.map(lambda x: x, tree))
    ckpt.wait()
    assert ckpt.all_steps() == [3, 4]
    _, step = ckpt.restore()
    assert step == 4


def test_checkpoint_ignores_torn_tmp(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_write=False)
    ckpt.save(1, _tree(jax.random.PRNGKey(2)))
    os.makedirs(tmp_path / "step_000000002.tmp")  # simulated torn write
    assert ckpt.latest_step() == 1
    restored, step = ckpt.restore()
    assert step == 1


def test_checkpoint_detects_corruption(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_write=False)
    ckpt.save(1, _tree(jax.random.PRNGKey(3)))
    d = tmp_path / "step_000000001"
    target = d / "arr_00000.npy"
    raw = bytearray(target.read_bytes())
    raw[-3] ^= 0xFF  # flip a payload bit
    target.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore()


def test_abft_detects_bitflip():
    params = {"w": jax.random.normal(jax.random.PRNGKey(4), (1024, 256)),
              "tiny": jnp.ones((4, 4))}  # below threshold: unprotected
    cs = abft.encode_tree(params, interpret=True)
    ok, _ = abft.verify_tree(params, cs, interpret=True)
    assert bool(ok)
    corrupted = {**params, "w": params["w"].at[123, 45].set(37.0)}
    ok2, devs = abft.verify_tree(corrupted, cs, interpret=True)
    assert not bool(ok2)


def test_abft_tolerates_fp_noise():
    params = {"w": jax.random.normal(jax.random.PRNGKey(5), (2048, 128))}
    cs = abft.encode_tree(params, interpret=True)
    jittered = {"w": params["w"] * (1 + 1e-7)}
    ok, _ = abft.verify_tree(jittered, cs, rtol=1e-3, interpret=True)
    assert bool(ok)


def test_abft_checksum_linearity_covers_allreduce():
    """checksum(sum_i g_i) == sum_i checksum(g_i): encoding local grads
    before the DP all-reduce and summing checksums alongside detects
    corruption introduced BY the collective itself."""
    g1 = jax.random.normal(jax.random.PRNGKey(6), (512, 64))
    g2 = jax.random.normal(jax.random.PRNGKey(7), (512, 64))
    c1 = abft.encode_leaf(g1, interpret=True)
    c2 = abft.encode_leaf(g2, interpret=True)
    c_sum = abft.encode_leaf(g1 + g2, interpret=True)
    np.testing.assert_allclose(np.asarray(c1 + c2), np.asarray(c_sum),
                               rtol=1e-4, atol=1e-3)


def test_watchdog_flags_straggler():
    events = []
    wd = watchdog.StepWatchdog(straggler_factor=1.5,
                               on_straggler=lambda dt, e: events.append(dt))
    for _ in range(5):
        wd.step_begin(); time.sleep(0.01); wd.step_end()
    wd.step_begin(); time.sleep(0.06); m = wd.step_end()
    assert m["straggler"] and len(events) == 1
    # EWMA not poisoned by the straggler
    assert wd.ewma < 0.03


def test_watchdog_context_manager_cancels_on_exception():
    hangs = []
    wd = watchdog.StepWatchdog(hang_timeout_s=0.05,
                               on_hang=lambda: hangs.append(1))
    with pytest.raises(RuntimeError):
        with wd:
            raise RuntimeError("step died")
    assert wd._timer is None  # timer cancelled, not leaked
    time.sleep(0.1)
    assert hangs == []  # a raising step must not fire on_hang later
    with wd:
        time.sleep(0.01)
    assert wd.last_metrics is not None
    assert wd.last_metrics["step_time_s"] >= 0.01


def test_watchdog_counts_faults():
    wd = watchdog.StepWatchdog()
    with wd:
        pass
    wd.note_fault()
    wd.note_fault()
    assert wd.fault_events == 2
    with wd:
        pass
    assert wd.last_metrics["fault_events"] == 2


def test_preemption_flag():
    h = watchdog.PreemptionHandler(signals=(signal.SIGUSR1,))
    assert not h.requested
    os.kill(os.getpid(), signal.SIGUSR1)
    time.sleep(0.05)
    assert h.requested
    h.restore()


def test_preemption_chains_previous_handler():
    chained = []
    prev = signal.signal(signal.SIGUSR1, lambda s, f: chained.append(s))
    try:
        h = watchdog.PreemptionHandler(signals=(signal.SIGUSR1,))
        os.kill(os.getpid(), signal.SIGUSR1)
        time.sleep(0.05)
        assert h.requested and chained == [signal.SIGUSR1]
        h.restore()
        # restore() put OUR lambda back, not the default
        assert signal.getsignal(signal.SIGUSR1) is not signal.SIG_DFL
    finally:
        signal.signal(signal.SIGUSR1, prev)


def test_rescale_plan_validates():
    with pytest.raises(ValueError, match=r"\[rescale-mesh\]"):
        elastic.rescale_plan(devices=list(range(3)), model_axis=2)
    with pytest.raises(ValueError, match=r"\[rescale-hosts\]"):
        elastic.rescale_plan(devices=list(range(2)), host_index=2,
                             host_count=2)
    with pytest.raises(ValueError, match=r"\[rescale-hosts\]"):
        elastic.rescale_plan(devices=list(range(2)), host_count=0)


def test_checkpoint_async_error_surfaces_and_clears(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_write=True)
    ckpt.save(1, {"w": jnp.ones((4, 4))})
    ckpt.wait()
    # simulate a disk failure inside the worker thread (chmod tricks don't
    # work under root): the failure must surface on the next wait()
    real_write = ckpt._write

    def failing_write(step, leaves, treedef):
        if step == 2:
            raise IOError("disk full")
        real_write(step, leaves, treedef)

    ckpt._write = failing_write
    ckpt.save(2, {"w": jnp.ones((4, 4))})
    with pytest.raises(RuntimeError, match=r"\[ckpt-async\].*step 2"):
        ckpt.wait()
    # the error cleared: the next save/wait cycle works again
    ckpt.save(3, {"w": jnp.ones((4, 4))})
    ckpt.wait()
    assert 3 in ckpt.all_steps()


def test_elastic_data_rebalance_preserves_stream():
    """Same global stream under 1 host and under 4 hosts."""
    base = pipeline.DataConfig(seed=9, seq_len=16, global_batch=8, vocab_size=32)
    full = pipeline.batch_for_step(base, 11)["tokens"]
    parts = []
    for h in range(4):
        cfg = pipeline.DataConfig(seed=9, seq_len=16, global_batch=8,
                                  vocab_size=32, host_index=h, host_count=4)
        parts.append(pipeline.batch_for_step(cfg, 11)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_checkpoint_restore_resharded(tmp_path):
    """Restore under a different 'device layout' (host numpy roundtrip is
    layout-free; device_put sharding equivalence is covered by the
    dry-run's mesh machinery)."""
    ckpt = Checkpointer(str(tmp_path), async_write=False)
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(1, tree)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    restored, _ = ckpt.restore(shardings={"w": sharding})
    np.testing.assert_array_equal(restored["w"], tree["w"])
