"""Attention substrate tests: chunked online-softmax vs naive oracle,
GQA grouping, SWA windows, MLA, decode equivalence, ring caches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import attention

TOL = dict(rtol=2e-4, atol=2e-5)


def naive_attention(q, k, v, causal=True, window=None, scale=None):
    b, sq, h, d = q.shape
    _, sk, hk, _ = k.shape
    g = h // hk
    scale = scale or d ** -0.5
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * scale
    qp, kp = jnp.arange(sq)[:, None], jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr).astype(q.dtype)


def _qkv(key, b, sq, sk, h, hk, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, h, d), dtype)
    k = jax.random.normal(k2, (b, sk, hk, d), dtype)
    v = jax.random.normal(k3, (b, sk, hk, d), dtype)
    return q, k, v


@pytest.mark.parametrize("h,hk", [(4, 4), (8, 2), (6, 1)])
def test_chunked_matches_naive_gqa(h, hk):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 64, 64, h, hk, 16)
    got = attention.chunked_attention(q, k, v, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(got, naive_attention(q, k, v), **TOL)


@pytest.mark.parametrize("qc,kc", [(8, 8), (16, 32), (64, 64), (13, 7)])
def test_chunk_size_invariance(qc, kc):
    """Chunk sizes (incl. non-divisors, which fall back) never change output."""
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 56, 56, 4, 2, 8)
    got = attention.chunked_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(got, naive_attention(q, k, v), **TOL)


def test_sliding_window():
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 64, 64, 4, 4, 8)
    got = attention.chunked_attention(q, k, v, window=16, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(got, naive_attention(q, k, v, window=16), **TOL)


def test_bidirectional():
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 32, 48, 4, 4, 8)
    got = attention.chunked_attention(q, k, v, causal=False, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(got, naive_attention(q, k, v, causal=False), **TOL)


def test_decode_matches_full():
    """decode_attention at position t == row t of full causal attention."""
    b, s, h, hk, d = 2, 24, 4, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(4), b, s, s, h, hk, d)
    full = naive_attention(q, k, v)
    for t in [0, 7, 23]:
        got = attention.decode_attention(q[:, t:t + 1], k, v, t + 1)
        np.testing.assert_allclose(got[:, 0], full[:, t], **TOL)


def test_decode_window_matches():
    b, s, h, d = 1, 32, 4, 8
    q, k, v = _qkv(jax.random.PRNGKey(5), b, s, s, h, h, d)
    full = naive_attention(q, k, v, window=8)
    for t in [10, 31]:
        got = attention.decode_attention(q[:, t:t + 1], k, v, t + 1, window=8)
        np.testing.assert_allclose(got[:, 0], full[:, t], **TOL)


def test_gqa_fwd_then_decode_equivalence():
    """Prefill(S) + decode(S..S+2) == full forward(S+3) last rows."""
    d_model, h, hk, hd = 32, 4, 2, 8
    cfg = dict(n_heads=h, n_kv=hk, head_dim=hd)
    key = jax.random.PRNGKey(6)
    params = attention.gqa_init(key, d_model, h, hk, hd, dtype=jnp.float32)
    s_total = 20
    x = jax.random.normal(jax.random.PRNGKey(7), (2, s_total, d_model))
    full, _ = attention.gqa_fwd(params, x, q_chunk=8, kv_chunk=8, **cfg)

    s0 = s_total - 3
    _, (k, v) = attention.gqa_fwd(params, x[:, :s0], q_chunk=8, kv_chunk=8, **cfg)
    ck = jnp.zeros((2, s_total, hk, hd)).at[:, :s0].set(k)
    cv = jnp.zeros((2, s_total, hk, hd)).at[:, :s0].set(v)
    for t in range(s0, s_total):
        out, ck, cv = attention.gqa_decode(params, x[:, t:t + 1], ck, cv, t, **cfg)
        np.testing.assert_allclose(out[:, 0], full[:, t], rtol=2e-3, atol=1e-4)


def test_ring_cache_decode_matches_window():
    """SWA ring cache (size=window) == windowed attention over full cache."""
    d_model, h, hd, w = 32, 4, 8, 8
    cfg = dict(n_heads=h, n_kv=h, head_dim=hd)
    params = attention.gqa_init(jax.random.PRNGKey(8), d_model, h, h, hd,
                                dtype=jnp.float32)
    s = 24
    x = jax.random.normal(jax.random.PRNGKey(9), (1, s, d_model))
    full, _ = attention.gqa_fwd(params, x, window=w, q_chunk=8, kv_chunk=8, **cfg)

    ring_k = jnp.zeros((1, w, h, hd))
    ring_v = jnp.zeros((1, w, h, hd))
    big_k = jnp.zeros((1, s, h, hd))
    big_v = jnp.zeros((1, s, h, hd))
    for t in range(s):
        out_r, ring_k, ring_v = attention.gqa_decode(
            params, x[:, t:t + 1], ring_k, ring_v, t, ring_window=w, **cfg)
        out_f, big_k, big_v = attention.gqa_decode(
            params, x[:, t:t + 1], big_k, big_v, t, window=w, **cfg)
        np.testing.assert_allclose(out_r, out_f, rtol=2e-3, atol=1e-4)
        np.testing.assert_allclose(out_r[:, 0], full[:, t], rtol=2e-3, atol=1e-4)


def test_mla_fwd_and_decode_equivalence():
    d_model, h = 32, 4
    mla_kw = dict(n_heads=h, nope_dim=8, rope_dim=4, v_dim=8)
    params = attention.mla_init(jax.random.PRNGKey(10), d_model, h,
                                q_lora=16, kv_lora=8, dtype=jnp.float32, **{
                                    k: v for k, v in mla_kw.items() if k != "n_heads"})
    s = 12
    x = jax.random.normal(jax.random.PRNGKey(11), (2, s, d_model))
    full, (c, kpe) = attention.mla_fwd(params, x, q_chunk=4, kv_chunk=4, **mla_kw)

    s0 = s - 3
    _, (c0, kpe0) = attention.mla_fwd(params, x[:, :s0], q_chunk=4, kv_chunk=4, **mla_kw)
    cc = jnp.zeros((2, s, 8)).at[:, :s0].set(c0)
    ckpe = jnp.zeros((2, s, 4)).at[:, :s0].set(kpe0)
    for t in range(s0, s):
        for absorb in (True, False):
            out, cc2, ckpe2 = attention.mla_decode(params, x[:, t:t + 1], cc, ckpe,
                                                   t, absorb=absorb, **mla_kw)
            np.testing.assert_allclose(out[:, 0], full[:, t], rtol=2e-3, atol=1e-4)
        cc, ckpe = cc2, ckpe2


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(4, 40), h=st.sampled_from([2, 4]), seed=st.integers(0, 999))
def test_property_causality(sq, h, seed):
    """Perturbing future tokens never changes past outputs."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q, k, v = _qkv(k1, 1, sq, sq, h, h, 8)
    out1 = attention.chunked_attention(q, k, v, q_chunk=8, kv_chunk=8)
    t = sq // 2
    k2v = k.at[:, t:].add(jax.random.normal(k2, k[:, t:].shape))
    v2v = v.at[:, t:].add(1.0)
    out2 = attention.chunked_attention(q, k2v, v2v, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(out1[:, :t], out2[:, :t], rtol=1e-5, atol=1e-5)
