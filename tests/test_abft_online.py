"""Online ABFT (GemmPolicy.abft): policy plumbing, dispatch-event
stamping, zero-overhead in "none" mode, and seeded-fault chaos -- every
GEMM kind, plus the split-K and int8 executor arms -- detection under
"verify", bit-exact repair under "correct"."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts
from repro.core import tsmm
from repro.ft import abft, inject


def _operands(kind, shape, key=0, dtype=jnp.float32):
    m, d1, d2 = shape
    ka, kb = jax.random.split(jax.random.PRNGKey(key))
    if kind == "tsmt":
        x = jax.random.uniform(ka, (m, d1), jnp.float32, -1, 1)
        y = jax.random.uniform(kb, (m, d2), jnp.float32, -1, 1)
    else:
        x = jax.random.uniform(ka, (m, d1), jnp.float32, -1, 1)
        y = jax.random.uniform(kb, (d1, d2), jnp.float32, -1, 1)
    return x.astype(dtype), y.astype(dtype)


def _call(kind, x, y):
    return tsmm.tsmm_t(x, y) if kind == "tsmt" else tsmm.tsmm(x, y)


def _max_cell(arr):
    r, c = np.unravel_index(np.argmax(np.abs(np.asarray(arr, np.float32))),
                            arr.shape)
    return int(r), int(c)


# -- policy plumbing --------------------------------------------------------

def test_policy_validates_abft():
    assert tsmm.GemmPolicy().abft == "none"
    for mode in ("none", "verify", "correct"):
        assert tsmm.GemmPolicy(abft=mode).abft == mode
    with pytest.raises(ValueError, match="abft"):
        tsmm.GemmPolicy(abft="retry")


def test_backward_policy_preserves_abft():
    for mode in ("none", "verify", "correct"):
        p = tsmm.GemmPolicy(abft=mode, quant="int8", split=2)
        bp = tsmm.backward_policy(p)
        assert bp.abft == mode
        assert not contracts.check_backward_policy(p, bp)


def test_policy_scope_carries_abft():
    with tsmm.policy(abft="correct"):
        assert tsmm.current_policy().abft == "correct"
    assert tsmm.current_policy().abft == "none"


# -- dispatch events --------------------------------------------------------

def test_abft_none_zero_overhead():
    x, y = _operands("tsm2r", (4096, 512, 8))
    with tsmm.record_dispatches() as log:
        with tsmm.policy(interpret=True):
            tsmm.tsmm(x, y)
    assert len(log) == 1 and log[0].abft == "none"


def test_abft_events_flag_exactly_one_guarded_dispatch():
    x, y = _operands("tsm2r", (4096, 512, 8))
    for mode in ("verify", "correct"):
        with tsmm.record_dispatches() as log:
            with tsmm.policy(interpret=True, abft=mode):
                tsmm.tsmm(x, y)
        # protected + the three checksum stages of abft_stage_shapes
        assert len(log) == 4
        flagged = [e for e in log if e.abft == mode]
        assert len(flagged) == 1 and flagged[0].kind == "tsm2r"
        assert all(e.abft == "none" for e in log if e is not flagged[0])


def test_injected_fault_stamped_on_event():
    x, y = _operands("tsm2r", (4096, 512, 8))
    f = inject.GemmFault(site=0, operand="out", row=3, col=2, bit=29)
    with tsmm.record_dispatches() as log:
        with tsmm.policy(interpret=True, abft="verify"):
            with inject.faults(f) as scope:
                tsmm.tsmm(x, y)
    assert scope.applied == [f]
    guarded = [e for e in log if e.abft == "verify"]
    assert guarded[0].faults == (f,)


# -- chaos: detect + correct per kind and executor arm ----------------------

CHAOS_ARMS = [
    ("tsm2r", (4096, 512, 8), {}),
    ("tsm2l", (8192, 16, 16), {}),
    ("tsmt", (100000, 16, 16), {}),
    ("tsm2r", (4096, 512, 8), {"split": 2}),       # split-K partials arm
    ("tsm2r", (4096, 512, 8), {"quant": "int8"}),  # quantized arm
]


@pytest.mark.parametrize("kind,shape,extra", CHAOS_ARMS,
                         ids=[f"{k}-{'-'.join(map(str, e.values())) or 'base'}"
                              for k, _, e in CHAOS_ARMS])
def test_chaos_detect_and_correct(kind, shape, extra):
    x, y = _operands(kind, shape)
    with tsmm.policy(interpret=True, **extra):
        oracle = np.asarray(_call(kind, x, y))
    # Fault the largest-|value| cell: its exponent region guarantees a
    # bit-29 flip lands far outside tolerance for every arm (including
    # int8, whose tolerance is quantization-scaled).
    r, c = _max_cell(oracle)
    fault = inject.GemmFault(site=0, operand="out", row=r, col=c, bit=29)

    # clean run under verify: bit-identical, no false positive
    with tsmm.policy(interpret=True, abft="verify", **extra):
        clean = np.asarray(_call(kind, x, y))
    np.testing.assert_array_equal(clean, oracle)

    # verify: detection = full NaN poison
    with tsmm.policy(interpret=True, abft="verify", **extra):
        with inject.faults(fault) as scope:
            poisoned = np.asarray(_call(kind, x, y))
    assert scope.applied == [fault]
    assert np.isnan(poisoned).all()

    # correct: bit-exact repair vs the fault-free oracle
    with tsmm.policy(interpret=True, abft="correct", **extra):
        with inject.faults(fault):
            fixed = np.asarray(_call(kind, x, y))
    np.testing.assert_array_equal(fixed, oracle)


@pytest.mark.parametrize("operand", ["a", "b"])
def test_operand_fault_detected(operand):
    x, y = _operands("tsm2r", (4096, 512, 8))
    f = inject.GemmFault(site=0, operand=operand, row=5, col=3, bit=29)
    with tsmm.policy(interpret=True, abft="verify"):
        with inject.faults(f):
            out = np.asarray(tsmm.tsmm(x, y))
    assert np.isnan(out).all()


def test_bf16_clean_and_corrected():
    x, y = _operands("tsm2r", (4096, 512, 8), dtype=jnp.bfloat16)
    with tsmm.policy(interpret=True):
        oracle = np.asarray(_call("tsm2r", x, y))
    with tsmm.policy(interpret=True, abft="verify"):
        clean = np.asarray(_call("tsm2r", x, y))
    np.testing.assert_array_equal(clean, oracle)
    r, c = _max_cell(oracle.astype(np.float32))
    fault = inject.GemmFault(site=0, operand="out", row=r, col=c, bit=13)
    with tsmm.policy(interpret=True, abft="correct"):
        with inject.faults(fault):
            fixed = np.asarray(_call("tsm2r", x, y))
    np.testing.assert_array_equal(fixed, oracle)


def test_grad_identity_on_clean_runs():
    x, y = _operands("tsm2r", (4096, 512, 8))

    def loss(x_, mode):
        with tsmm.policy(interpret=True, abft=mode):
            return jnp.sum(tsmm.tsmm(x_, y) ** 2)

    g_none = jax.grad(lambda x_: loss(x_, "none"))(x)
    for mode in ("verify", "correct"):
        g = jax.grad(lambda x_: loss(x_, mode))(x)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(g_none))


def test_jit_clean_path_identical():
    x, y = _operands("tsm2r", (4096, 512, 8))

    @jax.jit
    def guarded(x_, y_):
        with tsmm.policy(interpret=True, abft="correct"):
            return tsmm.tsmm(x_, y_)

    with tsmm.policy(interpret=True):
        oracle = np.asarray(tsmm.tsmm(x, y))
    np.testing.assert_array_equal(np.asarray(guarded(x, y)), oracle)


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
def test_mesh_arm_per_shard_guard():
    """Under shard_map the outer dispatch skips the wrap; the per-shard
    re-dispatch carries the mode, so each shard's GEMM is guarded and an
    injected per-shard output fault still poisons the (replicated)
    result."""
    from jax.sharding import Mesh
    devs = jax.devices()
    m = 2048 * len(devs)
    x, y = _operands("tsmt", (m, 64, 8))
    mesh = Mesh(np.array(devs), ("data",))
    with mesh, tsmm.policy(interpret=True, reduce="psum", abft="verify"):
        clean = np.asarray(tsmm.tsmm_t(x, y))
    with tsmm.policy(interpret=True):
        oracle = np.asarray(tsmm.tsmm_t(x, y))
    # psum reduction order differs from the single-device oracle: this
    # asserts the guard passes clean sharded runs through, not bit-equality
    np.testing.assert_allclose(clean, oracle, rtol=1e-4, atol=1e-3)
    # Site 1 is the first per-shard re-dispatch (site 0 = outer shard_map
    # invocation at the registry boundary).
    f = inject.GemmFault(site=1, operand="out", row=0, col=0, bit=29)
    with mesh, tsmm.policy(interpret=True, reduce="psum", abft="verify"):
        with inject.faults(f):
            out = np.asarray(tsmm.tsmm_t(x, y))
    assert np.isnan(out).any()


# -- tolerance + locate-and-correct unit behavior ---------------------------

def test_tolerance_robust_to_corrupted_amax():
    """A huge faulty cell must not inflate its own column's threshold past
    its own deviation (the int8 failure mode: eps=1/127 makes the scale
    factor O(10), so an amax taken from the corrupted output would mask
    the fault entirely)."""
    eps = abft.tolerance_eps(jnp.float32, "int8")
    amax = jnp.array([40.0, 45.0, 2.4e20, 42.0], jnp.float32)
    tol = np.asarray(abft.tolerance(4096, 512, eps, amax))
    assert tol[2] < 1e7  # capped near the clean columns' scale
    clean_tol = np.asarray(abft.tolerance(
        4096, 512, eps, jnp.array([40.0, 45.0, 41.0, 42.0], jnp.float32)))
    assert (tol[2] / clean_tol[2]) < 100.0


def test_offline_correct_leaf_repairs_single_row():
    w = jax.random.normal(jax.random.PRNGKey(11), (70000, 16))
    c = abft.encode_leaf(w, interpret=True)
    bad = w.at[123, 4].add(2.0)
    ok, fixed = abft.correct_leaf(bad, c, interpret=True)
    assert not bool(ok)
    np.testing.assert_allclose(np.asarray(fixed), np.asarray(w),
                               rtol=0, atol=1e-4)
    ok2, same = abft.correct_leaf(w, c, interpret=True)
    assert bool(ok2)
    np.testing.assert_array_equal(np.asarray(same), np.asarray(w))


def test_offline_tree_verify_and_correct():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(12), (70000, 8)),
            "tiny": jnp.ones((4, 4))}  # below threshold: no checksum
    cs = abft.encode_tree(tree, interpret=True)
    ok, same = abft.verify_and_correct_tree(tree, cs, interpret=True)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(same["w"]),
                                  np.asarray(tree["w"]))
    corrupted = {**tree, "w": tree["w"].at[7, 3].add(1.5)}
    ok2, fixed = abft.verify_and_correct_tree(corrupted, cs, interpret=True)
    assert not bool(ok2)
    np.testing.assert_allclose(np.asarray(fixed["w"]),
                               np.asarray(tree["w"]), rtol=0, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(fixed["tiny"]),
                                  np.asarray(tree["tiny"]))


def test_multi_row_fault_poisons_not_mends():
    """Two damaged rows cannot be explained by a single-row repair: the
    residual gate must reject the correction and poison instead of
    silently mis-repairing. The two faults hit different columns at
    comparable magnitudes (each column's own largest cell, distinct
    rows) -- two flips in ONE column where one deviation is orders
    smaller would be absorbed by f32 checksum rounding against the
    other, which no checksum scheme can see."""
    kind, shape = "tsm2r", (4096, 512, 8)
    x, y = _operands(kind, shape)
    with tsmm.policy(interpret=True):
        oracle = np.asarray(_call(kind, x, y))
    r0 = int(np.argmax(np.abs(oracle[:, 0])))
    col1 = np.abs(oracle[:, 1]).copy()
    col1[r0] = -np.inf  # force distinct rows: same-row damage is repairable
    r1 = int(np.argmax(col1))
    faults = (inject.GemmFault(site=0, operand="out", row=r0, col=0, bit=29),
              inject.GemmFault(site=0, operand="out", row=r1, col=1, bit=29))
    with tsmm.policy(interpret=True, abft="correct"):
        with inject.faults(*faults):
            out = np.asarray(_call(kind, x, y))
    assert np.isnan(out).all()


def test_abft_stage_shapes_contract():
    stages = contracts.abft_stage_shapes("tsm2r", (4096, 512, 8))
    assert stages == (("mmt", (4096, 512, 2)), ("mmt", (512, 8, 2)),
                      ("mmt", (4096, 8, 2)))
    stages_t = contracts.abft_stage_shapes("tsmt", (65536, 16, 16), s=3)
    assert stages_t == (("mm", (65536, 16, 3)), ("mmt", (65536, 3, 16)),
                       ("mmt", (16, 16, 3)))
    with pytest.raises(ValueError, match="s >= 2"):
        contracts.abft_stage_shapes("tsm2r", (4096, 512, 8), s=1)
    with pytest.raises(ValueError, match="unknown kind"):
        contracts.abft_stage_shapes("dense", (4096, 512, 8))


def test_abft_policy_contract_flags_drift():
    p = tsmm.GemmPolicy(abft="verify")
    drifted = dataclasses.replace(tsmm.backward_policy(p), abft="none")
    rules = [v.rule for v in contracts.check_backward_policy(p, drifted)]
    assert "abft-policy" in rules
