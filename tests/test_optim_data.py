"""Optimizer, PowerSGD, schedules, data pipeline, train-step integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.data import pipeline
from repro.optim import adamw, powersgd, schedule
from repro.train import train_step as ts


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_math():
    """Single-param AdamW against a hand-rolled numpy step."""
    cfg = adamw.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.0, grad_clip=0.0)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.5, 0.5, -1.0])}
    st_ = adamw.init(cfg, p)
    p1, st1, _ = adamw.update(cfg, p, g, st_)
    m = 0.1 * np.array([0.5, 0.5, -1.0])
    v = 0.01 * np.array([0.25, 0.25, 1.0])
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.array([1.0, -2.0, 3.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(p1["w"], want, rtol=1e-6)
    assert int(st1["step"]) == 1


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(lr=0.1, grad_clip=1.0, weight_decay=0.0)
    p = {"w": jnp.ones(4)}
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw.update(cfg, p, g, adamw.init(cfg, p))
    assert float(m["clip_coef"]) < 0.01
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_adamw_bf16_state():
    cfg = adamw.AdamWConfig(lr=0.1, state_dtype="bfloat16")
    p = {"w": jnp.ones((8, 8))}
    st_ = adamw.init(cfg, p)
    assert st_["moments"]["w"]["m"].dtype == jnp.bfloat16
    p1, st1, _ = adamw.update(cfg, p, {"w": jnp.ones((8, 8))}, st_)
    assert st1["moments"]["w"]["m"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(p1["w"])).all()


def test_schedule_shapes():
    sched = schedule.linear_warmup_cosine(1e-3, 10, 100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert float(sched(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(sched(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)
    mid = float(sched(jnp.int32(55)))
    assert 1e-4 < mid < 1e-3


# ---------------------------------------------------------------------------
# PowerSGD
# ---------------------------------------------------------------------------

def test_powersgd_exact_for_lowrank():
    """A rank-r gradient is reconstructed (near-)exactly at rank r."""
    cfg = powersgd.PowerSGDConfig(rank=4, min_size=0)
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (512, 4))
    v = jax.random.normal(jax.random.fold_in(key, 1), (300, 4))
    g = {"w": u @ v.T}
    st_ = powersgd.init(cfg, g, jax.random.PRNGKey(2))
    out, st1, metrics = powersgd.compress_tree(cfg, g, st_, interpret=True)
    # one power iteration on exact-rank input converges to machine-ish error
    rel = np.linalg.norm(out["w"] - g["w"]) / np.linalg.norm(g["w"])
    assert rel < 1e-3
    assert metrics["powersgd_compression"] > 30


def test_powersgd_error_feedback_accumulates():
    """EF invariant: err == g_with_ef - approx after each round."""
    cfg = powersgd.PowerSGDConfig(rank=2, min_size=0)
    g = {"w": jax.random.normal(jax.random.PRNGKey(3), (128, 96))}
    st_ = powersgd.init(cfg, g, jax.random.PRNGKey(4))
    out, st1, _ = powersgd.compress_tree(cfg, g, st_, interpret=True)
    resid = np.asarray(g["w"] + 0.0) - np.asarray(out["w"])
    np.testing.assert_allclose(np.asarray(st1["w"]["err"]), resid,
                               rtol=1e-4, atol=1e-4)
    # feeding zero gradients next: EF replays the residual
    zero = {"w": jnp.zeros_like(g["w"])}
    out2, st2, _ = powersgd.compress_tree(cfg, zero, st1, interpret=True)
    assert np.linalg.norm(out2["w"]) > 0.1 * np.linalg.norm(resid)


def test_powersgd_psum_mean_two_replicas():
    """Two replicas with different grads: the decompressed result
    approximates the mean gradient (protocol order: reduce P before
    orthonormalizing)."""
    cfg = powersgd.PowerSGDConfig(rank=8, min_size=0)
    k = jax.random.PRNGKey(8)
    u = jax.random.normal(k, (256, 8))
    v = jax.random.normal(jax.random.fold_in(k, 1), (8, 128))
    g1 = {"w": u @ v}
    g2 = {"w": 3.0 * (u @ v)}
    st1 = powersgd.init(cfg, g1, jax.random.PRNGKey(9))
    st2 = jax.tree.map(lambda x: x, st1, is_leaf=lambda x: x is None)

    # simulate the mean-psum: both replicas contribute
    stash = {}

    def psum_a(x):
        stash[x.shape] = x
        return x  # placeholder; replaced below by manual two-pass

    # run replica-coupled manually: P factors
    gm = {"w": (g1["w"] + g2["w"]) / 2}
    out_mean, _, _ = powersgd.compress_tree(cfg, gm, st1, interpret=True)
    rel = float(jnp.linalg.norm(out_mean["w"] - gm["w"])
                / jnp.linalg.norm(gm["w"]))
    assert rel < 1e-3   # rank-8 input, rank-8 compression => near-exact


@settings(max_examples=6, deadline=None)
@given(d1=st.integers(64, 200), d2=st.integers(48, 160), seed=st.integers(0, 99))
def test_powersgd_ef_time_average_unbiased(d1, d2, seed):
    """EF's guarantee: for a FIXED gradient, the time-average of what is
    actually applied converges toward g (deferred directions are eventually
    transmitted). Single-round error can transiently grow -- by design."""
    cfg = powersgd.PowerSGDConfig(rank=4, min_size=0)
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (d1, d2))}
    st_ = powersgd.init(cfg, g, jax.random.PRNGKey(seed + 1))
    total = jnp.zeros_like(g["w"])
    rel_1 = None
    for t in range(8):
        out, st_, _ = powersgd.compress_tree(cfg, g, st_, interpret=True)
        total = total + out["w"]
        if t == 0:
            rel_1 = float(jnp.linalg.norm(out["w"] - g["w"])
                          / jnp.linalg.norm(g["w"]))
    avg = total / 8
    rel_8 = float(jnp.linalg.norm(avg - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel_8 < rel_1 + 1e-6   # averaging never loses ground
    assert rel_8 < 0.9            # and recovers a large fraction of g


def test_powersgd_small_params_stay_dense():
    cfg = powersgd.PowerSGDConfig(rank=2, min_size=10 ** 6)
    g = {"w": jnp.ones((32, 32)), "b": jnp.ones(32)}
    st_ = powersgd.init(cfg, g, jax.random.PRNGKey(0))
    assert st_["w"] is None and st_["b"] is None
    out, _, m = powersgd.compress_tree(cfg, g, st_)
    np.testing.assert_allclose(out["w"], g["w"])
    assert m["powersgd_compression"] == pytest.approx(1.0)


def test_orthonormalize_rank_deficient_columns():
    """The degenerate-column guard: duplicate and zero columns come back
    as fresh orthonormal directions instead of normalized rounding noise
    (the pre-guard behavior silently broke P^T P = I, which is what makes
    ``approx = P Q^T`` a projection)."""
    key = jax.random.PRNGKey(11)
    m = jax.random.normal(key, (512, 6))
    m = m.at[:, 3].set(m[:, 1])          # exact duplicate
    m = m.at[:, 5].set(0.0)              # zero column
    q = powersgd._orthonormalize(m)
    eye_err = float(jnp.max(jnp.abs(q.T @ q - jnp.eye(6))))
    assert eye_err <= 1e-4, eye_err
    # healthy columns are untouched up to normalization (span preserved)
    col0 = m[:, 0] / jnp.linalg.norm(m[:, 0])
    np.testing.assert_allclose(np.asarray(q[:, 0]), np.asarray(col0),
                               atol=1e-5)
    # the reseed draws are fixed per column index: fully deterministic
    q2 = powersgd._orthonormalize(m)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))


def test_powersgd_orth_config():
    with pytest.raises(ValueError, match="orth"):
        powersgd.PowerSGDConfig(orth="householder")
    # orth="tsqr" runs the same protocol, just orthogonalizing on the
    # kernel paths: a rank-r gradient still reconstructs near-exactly
    cfg = powersgd.PowerSGDConfig(rank=4, min_size=0, orth="tsqr")
    key = jax.random.PRNGKey(12)
    u = jax.random.normal(key, (512, 4))
    v = jax.random.normal(jax.random.fold_in(key, 1), (300, 4))
    g = {"w": u @ v.T}
    st_ = powersgd.init(cfg, g, jax.random.PRNGKey(2))
    out, _, _ = powersgd.compress_tree(cfg, g, st_, interpret=True)
    rel = np.linalg.norm(out["w"] - g["w"]) / np.linalg.norm(g["w"])
    assert rel < 1e-3


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_host_sharded():
    cfg = pipeline.DataConfig(seed=7, seq_len=32, global_batch=8, vocab_size=64)
    b1 = pipeline.batch_for_step(cfg, 5)
    b2 = pipeline.batch_for_step(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # 2-host split concatenates to the 1-host global batch
    h0 = pipeline.batch_for_step(
        pipeline.DataConfig(seed=7, seq_len=32, global_batch=8, vocab_size=64,
                            host_index=0, host_count=2), 5)
    h1 = pipeline.batch_for_step(
        pipeline.DataConfig(seed=7, seq_len=32, global_batch=8, vocab_size=64,
                            host_index=1, host_count=2), 5)
    np.testing.assert_array_equal(np.concatenate([h0["tokens"], h1["tokens"]]),
                                  b1["tokens"])


def test_data_targets_are_shifted_stream():
    cfg = pipeline.DataConfig(seed=1, seq_len=16, global_batch=2, vocab_size=32)
    b = pipeline.batch_for_step(cfg, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_prefetcher_orders_and_resumes():
    cfg = pipeline.DataConfig(seed=3, seq_len=8, global_batch=2, vocab_size=16)
    pf = pipeline.Prefetcher(cfg, start_step=10)
    s0, b0 = pf.get()
    s1, b1 = pf.get()
    pf.close()
    assert (s0, s1) == (10, 11)
    np.testing.assert_array_equal(b0["tokens"],
                                  pipeline.batch_for_step(cfg, 10)["tokens"])


# ---------------------------------------------------------------------------
# Train-step integration (tiny arch, few steps, loss must drop)
# ---------------------------------------------------------------------------

def test_train_loop_loss_decreases():
    cfg = registry.get_config("llama3.2-3b", smoke=True)
    dcfg = pipeline.DataConfig(seed=0, seq_len=32, global_batch=8,
                               vocab_size=cfg.vocab_size)
    opt = adamw.AdamWConfig(lr=schedule.linear_warmup_cosine(3e-3, 10, 120),
                            weight_decay=0.0)
    step_fn = jax.jit(ts.make_train_step(cfg, opt))
    state = ts.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    hist = []
    for s in range(120):
        batch = jax.tree.map(jnp.asarray, pipeline.batch_for_step(dcfg, s))
        state, metrics = step_fn(state, batch)
        hist.append(float(metrics["loss"]))
    first5 = sum(hist[:5]) / 5
    last10 = sum(hist[-10:]) / 10
    assert last10 < first5 - 0.4, (first5, last10)


def test_train_step_microbatched_matches_full():
    """Grad accumulation is numerically consistent with the full batch."""
    cfg = registry.get_config("chatglm3-6b", smoke=True)
    opt = adamw.AdamWConfig(lr=1e-3, weight_decay=0.0, grad_clip=0.0)
    state = ts.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    dcfg = pipeline.DataConfig(seed=0, seq_len=16, global_batch=4,
                               vocab_size=cfg.vocab_size)
    batch = jax.tree.map(jnp.asarray, pipeline.batch_for_step(dcfg, 0))
    s_full, m_full = jax.jit(ts.make_train_step(cfg, opt))(state, batch)
    s_micro, m_micro = jax.jit(ts.make_train_step(cfg, opt, n_micro=2))(state, batch)
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                        s_full["params"], s_micro["params"])
    # AdamW's rsqrt amplifies tiny fp reorderings at step 1; bound by a
    # fraction of the lr-scale update instead of machine epsilon.
    assert max(jax.tree.leaves(diff)) < 5e-4
