"""Serving engine: batched prefill + decode with KV caches.

``make_serve_fns`` returns the two jit-able pure functions the dry-run
lowers (``prefill_step``, ``decode_step``) plus a host-side ``generate``
loop for the examples (greedy / temperature sampling).

Cache layout: contiguous per-layer tensors allocated once at
``max_len = prompt + max_new``; SWA archs get ring caches bounded by the
window (mixtral long_500k: 4096 slots instead of 524k); SSM archs carry
O(1) state. Continuous batching note: slot management across requests is
host-side (examples/serve_lm.py) -- the device functions are fixed-shape.
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

from repro.core import tsmm
from repro.kernels import quant as kquant
from repro.models import model

# Model params arrive in f32 unless quantized records say otherwise.
_WEIGHT_DTYPE = jnp.float32


def make_serve_fns(cfg, policy: "tsmm.GemmPolicy | None" = None, *,
                   sharded_projections: bool = False):
    """Build (prefill_step, decode_step) pure functions for jit.

    ``policy`` pins a GemmPolicy scope around the traced bodies (e.g.
    ``GemmPolicy(mode="dense")`` for an A/B arm, or ``spec=V5P`` on newer
    hardware). GEMM dispatch is trace-time, so the scope only needs to be
    live while jit traces these functions -- wrapping the bodies here means
    callers don't have to manage the scope around their own ``jax.jit``.

    ``sharded_projections=True`` scopes ``reduce="psum_scatter"`` on top:
    under a multi-device serving mesh, ``tsmm_t`` products inside the
    steps (ABFT checksum projections, weight-side custom-VJP paths) come
    back row-sharded over the DP axes instead of replicated -- the right
    layout when the consumer immediately re-shards (and a no-op
    everywhere else: off-mesh or for shapes that cannot scatter, dispatch
    degrades exactly like the default path). DP axes follow the launch
    mesh via ``tsmm.derive_dp_axes`` unless the policy pins ``dp_axes``.

    Pre-quantized weights (``kernels.quant.quantize_weights`` records:
    ``{"q8": int8, "q8_scale": f32}`` leaves with offline per-tile
    scales) are accepted directly: the step bodies dequantize at entry,
    inside the jit trace, so the *stored/transferred* params stay at 1
    byte/elem + the tiny scale sidecar while the model code sees plain
    f32 arrays. (XLA commonly fuses the dequant into the first consumer;
    the fully-fused path -- int8 tiles all the way into the Pallas GEMMs
    via ``GemmPolicy(quant="int8")`` -- re-quantizes activations on the
    fly and is the policy knob, not the storage format.)
    """
    def _scope():
        base = policy
        if sharded_projections:
            base = ((base if base is not None else tsmm.current_policy())
                    .with_(reduce="psum_scatter"))
        return (tsmm.policy(base) if base is not None
                else contextlib.nullcontext())

    def prefill_step(params, batch, cache):
        with _scope():
            params = kquant.dequantize_weights(params, _WEIGHT_DTYPE)
            return model.prefill(params, cfg, batch, cache)

    def decode_step(params, tokens, pos, cache):
        with _scope():
            params = kquant.dequantize_weights(params, _WEIGHT_DTYPE)
            return model.decode_step(params, cfg, tokens, pos, cache)

    return prefill_step, decode_step


def sample_token(key, logits, temperature: float = 0.0):
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def generate(params, cfg, prompts, max_new: int, *, key=None,
             temperature: float = 0.0, extras=None, policy=None,
             sharded_projections: bool = False):
    """prompts: (B, S) int32. Returns (B, max_new) generated tokens.

    Host loop over jitted single-token steps (the production engine would
    run this under an async scheduler; step functions are identical).
    ``policy`` threads a GemmPolicy into the jitted steps;
    ``sharded_projections`` is forwarded to :func:`make_serve_fns`.
    """
    prefill_step, decode_step = make_serve_fns(
        cfg, policy=policy, sharded_projections=sharded_projections)
    prefill_j = jax.jit(prefill_step)
    decode_j = jax.jit(decode_step)

    b, s0 = prompts.shape
    cache = model.init_cache(cfg, b, s0 + max_new)
    batch = {"tokens": prompts}
    if extras:
        batch.update(extras)
    logits, cache = prefill_j(params, batch, cache)
    key = key if key is not None else jax.random.PRNGKey(0)
    toks = []
    tok = sample_token(key, logits, temperature)[:, None]
    toks.append(tok)
    for i in range(1, max_new):
        logits, cache = decode_j(params, tok, s0 + i - 1, cache)
        key = jax.random.fold_in(key, i)
        tok = sample_token(key, logits, temperature)[:, None]
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)
