"""Measured-wall-clock autotuning for the TSM2X kernel parameters.

The paper's Algorithm 5 has two halves: pick (t1, t2, t3) from the analytic
performance model, then *profile* to correct it ("offline-profile t1").
``core.perf_model`` is the analytic half; this module is the measured half:

* :func:`autotune_shape` times real kernel invocations over the exact
  candidate grid the analytic argmin scores
  (``perf_model.{tsm2r,tsm2l,tsmt}_candidates``) and records the
  measured-best block params plus the model-vs-measured error.
* :class:`TuningTable` is the persistent (JSON-serializable) cache of those
  records, keyed by ``(kernel kind, shape bucket, dtype, spec name,
  executor)``. Hang it on a policy -- ``with tsmm.policy(tuning_table=tbl)``
  -- and ``kernels/ops.py`` consults the measured winners before falling
  back to ``choose_params_*``.
* :func:`calibrate` / :func:`fit_spec` fit the free model constants
  (``step_overhead``, ``dma_latency``, ``vmem_usable``) to minimize
  modeled-vs-measured error, so the analytic path improves even for shapes
  that are not in the table.

Shape bucketing (the scheme the table key uses, via :func:`bucket_dim`):
dims up to one lane tile (128) are kept exact -- skinny dims flip the
kernel choice sharply -- and larger dims round up to the next power of two.
A lookup for (20480, 20480, 16) therefore hits a record tuned at any shape
in the same (32768, 32768, 16) bucket.

Timing discipline: every measurement goes through :func:`jit_isolated`,
which gives each arm a *fresh* ``jax.jit`` wrapper traced inside its own
policy scope. Dispatch policy and block params are captured at trace time,
so a jitted callable shared across arms would silently reuse the first
arm's baked-in configuration (the A/B leakage bug; ROADMAP "each arm needs
its own jit cache"). ``benchmarks/common.py`` reuses the same harness.

Off-TPU the kernels run in Pallas interpret mode, where wall clock measures
the Python interpreter, not the hardware -- the numbers exercise the
mechanism (and CI does exactly that); authoritative tables must be
generated on a real TPU and committed (see README "Autotuning").
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Callable, Iterable

import jax
import jax.numpy as jnp

from repro.core import perf_model
from repro.kernels import compat, ops

__all__ = [
    "TABLE_SCHEMA",
    "TuningRecord",
    "TuningTable",
    "SpecFit",
    "Observation",
    "CalibrationResult",
    "bucket_dim",
    "bucket_shape",
    "record_key",
    "fit_key",
    "jit_isolated",
    "time_call",
    "autotune_shape",
    "build_table",
    "observations_from_table",
    "fit_spec",
    "calibrate",
]

# /2 added the split-reduction dimension ("splits" in record params) and
# the per-bucket "fits" block. Loaders accept every "repro-tsm2x-tuning/"
# schema: /1 records simply lack both (consumers default splits to 1 --
# the sequential kernel those tables actually measured -- and fitted_spec
# falls through to the caller's spec).
TABLE_SCHEMA = "repro-tsm2x-tuning/2"

KINDS = ("tsm2r", "tsm2l", "tsmt")


# ---------------------------------------------------------------------------
# Shape bucketing + keys
# ---------------------------------------------------------------------------

def bucket_dim(d: int, lane: int = 128) -> int:
    """Bucket one dim: exact up to a lane tile, next power of two above."""
    if d <= lane:
        return d
    return 1 << (d - 1).bit_length()


def bucket_shape(m: int, d1: int, d2: int, lane: int = 128) -> tuple[int, int, int]:
    return (bucket_dim(m, lane), bucket_dim(d1, lane), bucket_dim(d2, lane))


def record_key(kind: str, bucket: tuple[int, int, int], dtype: str,
               spec_name: str, executor: str) -> str:
    """Stable string form of the table key (also the on-disk JSON key)."""
    bm, b1, b2 = bucket
    return f"{kind}|{bm}x{b1}x{b2}|{dtype}|{spec_name}|{executor}"


# Wildcard cell for the table-wide (global) calibration fit.
GLOBAL_FIT = ("*", (0, 0, 0), "*")


def fit_key(kind: str, bucket: tuple[int, int, int], dtype: str,
            spec_name: str) -> str:
    """Key of one fitted-constants cell (no executor: the fit corrects the
    *model*, which is executor-blind)."""
    bm, b1, b2 = bucket
    return f"{kind}|{bm}x{b1}x{b2}|{dtype}|{spec_name}"


def _dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


def _params_tuple(params) -> tuple[tuple[str, int], ...]:
    return tuple(sorted(dict(params).items()))


# ---------------------------------------------------------------------------
# Table
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuningRecord:
    """One tuned entry: measured-best params for one (kind, bucket, dtype,
    spec, executor) cell, plus everything needed to audit the model."""

    kind: str                                   # "tsm2r" | "tsm2l" | "tsmt"
    bucket: tuple[int, int, int]                # bucketed (tall, d1, d2)
    dtype: str                                  # jnp dtype name
    spec_name: str                              # TPUSpec.name
    executor: str                               # "pallas-tpu" | "interpret"
    shape: tuple[int, int, int]                 # the shape actually measured
    params: tuple[tuple[str, int], ...]         # measured-best block params
    measured_us: float                          # wall time of those params
    model_us: float                             # model's prediction for them
    model_error: float                          # |model - measured|/measured
    model_pick: tuple[tuple[str, int], ...]     # the analytic argmin
    model_pick_measured_us: float               # its measured wall time

    @property
    def params_dict(self) -> dict[str, int]:
        return dict(self.params)

    @property
    def key(self) -> str:
        return record_key(self.kind, self.bucket, self.dtype, self.spec_name,
                          self.executor)

    @property
    def pick_matches(self) -> bool:
        """Did the analytic model already pick the measured winner?"""
        return self.params == self.model_pick


@dataclasses.dataclass(frozen=True)
class SpecFit:
    """Fitted model constants for one shape bucket (or the table-wide
    ``GLOBAL_FIT`` wildcard cell): the ``calibrate()`` output, stored so
    ``GemmPolicy.tuning_table`` consumers can run the analytic chooser
    under the constants measured NEAR the shape at hand instead of one
    global compromise (step overhead and DMA latency are strongly
    shape-regime-dependent -- a latency-bound tsm2l bucket and a streaming
    tsm2r bucket want very different corrections)."""

    kind: str                       # kernel kind, or "*" for the global fit
    bucket: tuple[int, int, int]    # bucketed shape; (0, 0, 0) for global
    dtype: str                      # jnp dtype name, or "*" for global
    spec_name: str                  # TPUSpec.name the fit corrects
    step_overhead: float
    dma_latency: float
    # vmem_usable raised by fit_spec when a measured winner would not fit
    # the modeled budget -- without carrying it, the table-driven analytic
    # fallback would re-prune configs calibration proved feasible. None on
    # fits saved before the field existed: leave the caller's budget alone.
    vmem_usable: float | None = None

    @property
    def key(self) -> str:
        return fit_key(self.kind, self.bucket, self.dtype, self.spec_name)


@dataclasses.dataclass(frozen=True)
class TuningTable:
    """Immutable, hashable set of tuning records (+ fitted model specs).

    Hashability matters: the table rides on ``GemmPolicy.tuning_table``,
    and policies flow through the kernels' ``custom_vjp`` nondiff args.
    ``add`` returns a new table (same-key records are replaced).

    ``fits`` carries per-bucket fitted model constants plus the global
    fit (``calibrate`` writes them); :meth:`fitted_spec` is the consumer
    view -- bucket-local fit first, global fit second, caller's spec as-is
    when the table has neither (v1 tables).
    """

    records: tuple[TuningRecord, ...] = ()
    fits: tuple[SpecFit, ...] = ()
    _index: dict | None = dataclasses.field(
        default=None, compare=False, repr=False)
    _fit_index: dict | None = dataclasses.field(
        default=None, compare=False, repr=False)

    def __post_init__(self):
        object.__setattr__(self, "_index", {r.key: r for r in self.records})
        object.__setattr__(self, "_fit_index",
                           {f.key: f for f in self.fits})

    @classmethod
    def from_records(cls, records: Iterable[TuningRecord],
                     fits: Iterable[SpecFit] = ()) -> "TuningTable":
        merged: dict[str, TuningRecord] = {}
        for r in records:
            merged[r.key] = r
        fmerged: dict[str, SpecFit] = {}
        for f in fits:
            fmerged[f.key] = f
        return cls(records=tuple(merged.values()),
                   fits=tuple(fmerged.values()))

    def add(self, record: TuningRecord) -> "TuningTable":
        return self.from_records((*self.records, record), self.fits)

    def with_fits(self, fits: Iterable[SpecFit]) -> "TuningTable":
        """New table with ``fits`` merged over the existing ones."""
        return self.from_records(self.records, (*self.fits, *fits))

    def lookup(self, kind: str, m: int, d1: int, d2: int, *, dtype,
               spec: str, executor: str) -> TuningRecord | None:
        key = record_key(kind, bucket_shape(m, d1, d2), _dtype_name(dtype),
                         spec, executor)
        return self._index.get(key)

    def fitted_spec(self, kind: str, m: int, d1: int, d2: int, *, dtype,
                    spec):
        """``spec`` with this shape-bucket's fitted constants applied --
        bucket-local cell first, the global wildcard second, unchanged
        when the table carries no fits at all."""
        fit = self._fit_index.get(
            fit_key(kind, bucket_shape(m, d1, d2), _dtype_name(dtype),
                    spec.name))
        if fit is None:
            fit = self._fit_index.get(fit_key(*GLOBAL_FIT, spec.name))
        if fit is None:
            return spec
        repl = {"step_overhead": fit.step_overhead,
                "dma_latency": fit.dma_latency}
        if fit.vmem_usable is not None:
            # the budget only ever widens: calibration proved configs past
            # the caller's budget feasible, never the reverse.
            repl["vmem_usable"] = max(fit.vmem_usable, spec.vmem_usable)
        return dataclasses.replace(spec, **repl)

    # -- JSON round trip ----------------------------------------------------

    def to_json(self) -> dict:
        return {
            "schema": TABLE_SCHEMA,
            "fits": [
                {
                    "kind": f.kind,
                    "bucket": list(f.bucket),
                    "dtype": f.dtype,
                    "spec": f.spec_name,
                    "step_overhead": f.step_overhead,
                    "dma_latency": f.dma_latency,
                    "vmem_usable": f.vmem_usable,
                }
                for f in self.fits
            ],
            "records": [
                {
                    "key": r.key,
                    "kind": r.kind,
                    "bucket": list(r.bucket),
                    "dtype": r.dtype,
                    "spec": r.spec_name,
                    "executor": r.executor,
                    "shape": list(r.shape),
                    "params": dict(r.params),
                    "measured_us": r.measured_us,
                    "model_us": r.model_us,
                    "model_error": r.model_error,
                    "model_pick": dict(r.model_pick),
                    "model_pick_measured_us": r.model_pick_measured_us,
                }
                for r in self.records
            ],
        }

    @classmethod
    def from_json(cls, data: dict) -> "TuningTable":
        schema = data.get("schema", "")
        if not schema.startswith("repro-tsm2x-tuning/"):
            raise ValueError(f"not a tuning table (schema={schema!r})")
        fits = tuple(
            SpecFit(
                kind=f["kind"],
                bucket=tuple(f["bucket"]),
                dtype=f["dtype"],
                spec_name=f["spec"],
                step_overhead=f["step_overhead"],
                dma_latency=f["dma_latency"],
                vmem_usable=f.get("vmem_usable"),  # absent pre-field
            )
            for f in data.get("fits", ()))  # absent in /1 tables
        return cls.from_records((
            TuningRecord(
                kind=d["kind"],
                bucket=tuple(d["bucket"]),
                dtype=d["dtype"],
                spec_name=d["spec"],
                executor=d["executor"],
                shape=tuple(d["shape"]),
                params=_params_tuple(d["params"]),
                measured_us=d["measured_us"],
                model_us=d["model_us"],
                model_error=d["model_error"],
                model_pick=_params_tuple(d["model_pick"]),
                model_pick_measured_us=d["model_pick_measured_us"],
            )
            for d in data["records"]), fits)

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path) -> "TuningTable":
        with open(path) as f:
            return cls.from_json(json.load(f))


# ---------------------------------------------------------------------------
# Timing harness (shared with benchmarks/common.py)
# ---------------------------------------------------------------------------

def time_call(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall time (seconds) of ``fn(*args)``, results synced."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    mid = len(ts) // 2
    # True median: even rep counts average the middle pair (upper-middle
    # alone would report the *worse* of two samples at reps=2).
    return ts[mid] if len(ts) % 2 else (ts[mid - 1] + ts[mid]) / 2


def jit_isolated(fn: Callable, *args, policy=None):
    """Fresh ``jax.jit`` wrapper, traced NOW under ``policy``.

    Returns ``(jitted_fn, dispatch_log)``. The trace call runs inside the
    policy scope and a ``record_dispatches`` spy, so (a) the arm owns its
    jit cache entry -- policy and block params are trace-time constants, a
    shared callable would silently keep the first arm's -- and (b) the
    caller can assert which executors the arm actually hit.

    ``fn`` is wrapped in a fresh function object first: jax's jit cache is
    keyed on the *wrapped callable's identity*, so ``jax.jit`` of the same
    function twice shares one cache -- re-jitting alone does not isolate an
    arm (the exact leakage this helper exists to prevent).
    """
    from repro.core import tsmm  # deferred: tsmm imports kernels.ops too

    def _fresh(*a):
        return fn(*a)

    f = jax.jit(_fresh)
    ctx = tsmm.policy(policy) if policy is not None else contextlib.nullcontext()
    with ctx:
        with tsmm.record_dispatches() as log:
            jax.block_until_ready(f(*args))
    return f, log


# ---------------------------------------------------------------------------
# Per-shape autotuning
# ---------------------------------------------------------------------------

def _kind_plan(kind: str, m: int, d1: int, d2: int, spec, dtype,
               explore_vmem: float = 1.0):
    """(candidates as param dicts, model-time fn, analytic pick) per kind.

    ``explore_vmem`` > 1 enumerates the *measured* search space under a
    relaxed VMEM budget (``vmem_usable * explore_vmem``, capped at 1.0).
    Without it the autotuner could only ever confirm the model's own
    feasibility filter -- a winner the model's budget would have pruned
    could never be observed, leaving ``fit_spec``'s vmem_usable correction
    unreachable. Over-budget candidates that fail to compile on real
    hardware are skipped by the measurement loop. The analytic pick always
    uses the strict budget.
    """
    explored = spec
    if explore_vmem > 1.0:
        explored = dataclasses.replace(
            spec, vmem_usable=min(spec.vmem_usable * explore_vmem, 1.0))
    if kind == "tsm2r":
        cands = [{"block_m": bm, "block_k": bk, "splits": s}
                 for bm, bk, s in perf_model.tsm2r_candidates(m, d1, d2,
                                                             explored, dtype)]

        def model(p):
            return perf_model.tsm2r_model_time(
                m, d1, d2, p["block_m"], p["block_k"], spec, dtype,
                splits=p.get("splits", 1))

        bm, bk, s = perf_model.choose_params_tsm2r(m, d1, d2, spec, dtype)
        pick = {"block_m": bm, "block_k": bk, "splits": s}
    elif kind == "tsm2l":
        cands = [{"block_m": bm}
                 for bm in perf_model.tsm2l_candidates(m, d1, d2,
                                                      explored, dtype)]

        def model(p):
            return perf_model.tsm2l_model_time(
                m, d1, d2, p["block_m"], spec, dtype)

        pick = {"block_m": perf_model.choose_params_tsm2l(m, d1, d2, spec, dtype)}
    elif kind == "tsmt":
        cands = [{"block_m": bm, "block_a": ba, "splits": s}
                 for bm, ba, s in perf_model.tsmt_candidates(m, d1, d2,
                                                            explored, dtype)]

        def model(p):
            return perf_model.tsmt_model_time(
                m, d1, d2, p["block_m"], p["block_a"], spec, dtype,
                splits=p.get("splits", 1))

        bm, ba, s = perf_model.choose_params_tsmt(m, d1, d2, spec, dtype)
        pick = {"block_m": bm, "block_a": ba, "splits": s}
    else:
        raise ValueError(f"unknown kernel kind {kind!r}: valid kinds are "
                         f"{', '.join(KINDS)}")
    if pick not in cands:  # tiny shape / tight budget: measure the fallback
        cands = [*cands, pick]
    return cands, model, pick


def _call_for(kind: str, params: dict):
    if kind == "tsm2r":
        return lambda a, b: ops.tsm2r(a, b, **params)
    if kind == "tsm2l":
        return lambda a, b: ops.tsm2l(a, b, **params)
    return lambda x, y: ops.tsmt(x, y, **params)


def _operands(kind: str, m: int, d1: int, d2: int, dtype, seed: int = 0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    if kind == "tsmt":  # X[m, a], Y[m, b]
        shapes = ((m, d1), (m, d2))
    else:               # A[m, k], B[k, n]
        shapes = ((m, d1), (d1, d2))
    return tuple(
        jax.random.uniform(kk, s, jnp.float32, -1, 1).astype(dtype)
        for kk, s in zip((k1, k2), shapes))


def _resolved_executor(policy) -> str:
    interpret = (compat.auto_interpret() if policy.interpret is None
                 else policy.interpret)
    return "interpret" if interpret else "pallas-tpu"


def autotune_shape(kind: str, m: int, d1: int, d2: int, *,
                   dtype=jnp.float32, policy=None, reps: int = 3,
                   warmup: int = 1,
                   explore_vmem: float = 1.25) -> TuningRecord:
    """Measure every candidate config for one shape; return the record.

    ``(d1, d2)`` are ``(k, n)`` for tsm2r/tsm2l and ``(a, b)`` for tsmt.
    Each candidate is timed through its own freshly-jitted wrapper under
    ``policy`` (or the current scope), so arms cannot leak cache entries.
    ``explore_vmem`` relaxes the VMEM feasibility filter for the measured
    search (see ``_kind_plan``); candidates that fail to compile/run are
    skipped, so probing past the modeled budget is safe.
    """
    from repro.core import tsmm

    pol = policy if policy is not None else tsmm.current_policy()
    cands, model, pick = _kind_plan(kind, m, d1, d2, pol.spec, dtype,
                                    explore_vmem)
    operands = _operands(kind, m, d1, d2, dtype)

    measured: list[tuple[float, dict]] = []
    for params in cands:
        try:
            f, _ = jit_isolated(_call_for(kind, params), *operands,
                                policy=pol)
            t = time_call(f, *operands, reps=reps, warmup=warmup)
        except Exception:  # over-budget explore candidate: Mosaic rejects it
            if params == pick:
                raise  # the strict-budget pick must always run
            continue
        measured.append((t, params))
    best_t, best_p = min(measured, key=lambda r: r[0])
    pick_t = next((t for t, p in measured if p == pick), float("nan"))
    model_s = model(best_p)
    return TuningRecord(
        kind=kind,
        bucket=bucket_shape(m, d1, d2),
        dtype=_dtype_name(dtype),
        spec_name=pol.spec.name,
        executor=_resolved_executor(pol),
        shape=(m, d1, d2),
        params=_params_tuple(best_p),
        measured_us=best_t * 1e6,
        model_us=model_s * 1e6,
        model_error=abs(model_s - best_t) / best_t,
        model_pick=_params_tuple(pick),
        model_pick_measured_us=pick_t * 1e6,
    )


def build_table(shapes: Iterable[tuple[str, int, int, int]], *,
                dtype=jnp.float32, policy=None, reps: int = 3,
                warmup: int = 1, explore_vmem: float = 1.25) -> TuningTable:
    """Autotune ``(kind, m, d1, d2)`` shapes into one TuningTable.

    Shapes that land in the same table bucket are merged by keeping the
    faster measured winner -- with a warning, since the extra measurement
    was wasted and the caller probably wanted distinct buckets.
    """
    import warnings

    by_key: dict[str, TuningRecord] = {}
    for kind, m, d1, d2 in shapes:
        rec = autotune_shape(kind, m, d1, d2, dtype=dtype, policy=policy,
                             reps=reps, warmup=warmup,
                             explore_vmem=explore_vmem)
        prev = by_key.get(rec.key)
        if prev is not None:
            warnings.warn(
                f"autotune shapes {prev.shape} and {rec.shape} share table "
                f"bucket {rec.key}; keeping the faster winner", stacklevel=2)
            if prev.measured_us <= rec.measured_us:
                continue
        by_key[rec.key] = rec
    return TuningTable(records=tuple(by_key.values()))


# ---------------------------------------------------------------------------
# Model calibration: fit the free TPUSpec constants to measurements
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Observation:
    """One (shape, params) -> measured-seconds data point."""

    kind: str
    m: int
    d1: int
    d2: int
    dtype: str
    params: tuple[tuple[str, int], ...]
    measured_s: float

    def model_s(self, spec) -> float:
        p = dict(self.params)
        if self.kind == "tsm2r":
            return perf_model.tsm2r_model_time(
                self.m, self.d1, self.d2, p["block_m"], p["block_k"],
                spec, self.dtype, splits=p.get("splits", 1))
        if self.kind == "tsm2l":
            return perf_model.tsm2l_model_time(
                self.m, self.d1, self.d2, p["block_m"], spec, self.dtype)
        return perf_model.tsmt_model_time(
            self.m, self.d1, self.d2, p["block_m"], p["block_a"],
            spec, self.dtype, splits=p.get("splits", 1))

    def vmem_bytes(self) -> int:
        p = dict(self.params)
        if self.kind == "tsm2r":
            return perf_model.tsm2r_vmem_usage(
                p["block_m"], p["block_k"], self.d2, self.dtype)
        if self.kind == "tsm2l":
            return perf_model.tsm2l_vmem_usage(
                p["block_m"], self.d1, self.d2, self.dtype)
        return perf_model.tsmt_vmem_usage(
            p["block_m"], p["block_a"], self.d2, self.dtype)


def observations_from_table(table: TuningTable) -> list[Observation]:
    """Both timings each record holds (measured winner + the analytic
    pick) become calibration points."""
    obs = []
    for r in table.records:
        m, d1, d2 = r.shape
        obs.append(Observation(r.kind, m, d1, d2, r.dtype, r.params,
                               r.measured_us / 1e6))
        if (r.model_pick != r.params
                and r.model_pick_measured_us == r.model_pick_measured_us):
            obs.append(Observation(r.kind, m, d1, d2, r.dtype, r.model_pick,
                                   r.model_pick_measured_us / 1e6))
    return obs


def _mean_log_err(spec, observations) -> float:
    import math
    tot = 0.0
    for o in observations:
        tot += abs(math.log(max(o.model_s(spec), 1e-12)
                            / max(o.measured_s, 1e-12)))
    return tot / max(len(observations), 1)


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    spec: perf_model.TPUSpec       # the fitted spec
    error_before: float            # mean |log(model/measured)| pre-fit
    error_after: float             # ... post-fit
    table: TuningTable | None = None


# Coordinate-descent grids: coarse powers of two first, then refinement.
_FIT_GRIDS = (
    tuple(2.0 ** i for i in range(-5, 6)),
    (0.5, 0.7, 0.85, 1.0, 1.2, 1.5, 2.0),
    (0.9, 0.95, 1.0, 1.05, 1.1),
)


def fit_spec(spec: perf_model.TPUSpec, observations: list[Observation], *,
             fit: tuple[str, ...] = ("step_overhead", "dma_latency"),
             ) -> CalibrationResult:
    """Fit free model constants against measurements (pure, no timing).

    ``step_overhead`` and ``dma_latency`` enter the modeled time linearly
    and are fit by coordinate descent on multiplicative scales, minimizing
    the mean absolute log model/measured ratio. ``vmem_usable`` bounds
    feasibility rather than time, so it is only ever *raised* -- minimally,
    when a measured winner would not fit the modeled budget (i.e. the model
    was pruning configs the hardware happily runs).
    """
    before = _mean_log_err(spec, observations)
    cur = spec
    if observations:
        for grid in _FIT_GRIDS:
            for name in fit:
                base = getattr(cur, name)
                best_v, best_e = base, _mean_log_err(cur, observations)
                for mult in grid:
                    trial = dataclasses.replace(cur, **{name: base * mult})
                    e = _mean_log_err(trial, observations)
                    if e < best_e - 1e-15:
                        best_v, best_e = base * mult, e
                cur = dataclasses.replace(cur, **{name: best_v})
        need = max((o.vmem_bytes() / cur.vmem_bytes for o in observations),
                   default=0.0)
        if need > cur.vmem_usable:
            cur = dataclasses.replace(cur, vmem_usable=min(need, 1.0))
    return CalibrationResult(spec=cur, error_before=before,
                             error_after=_mean_log_err(cur, observations))


DEFAULT_CALIBRATION_SHAPES = (
    ("tsm2r", 2048, 512, 8),
    ("tsm2r", 4096, 1024, 16),
    ("tsm2l", 8192, 16, 16),
    ("tsmt", 4096, 64, 8),
)


def calibrate(shapes=DEFAULT_CALIBRATION_SHAPES, *, spec=None,
              dtype=jnp.float32, policy=None, reps: int = 3,
              warmup: int = 1, explore_vmem: float = 1.25,
              base_table: TuningTable | None = None) -> CalibrationResult:
    """Measure + fit in one step: the ``calibrate(spec)`` entry point.

    Autotunes ``shapes`` under ``policy`` (or the current scope), then fits
    the free constants of ``spec`` (default: the policy's spec) to the
    measurements -- once globally over every observation, and once per
    shape bucket. Both land on the returned table
    (``TuningTable.fits``), so consumers hanging the table on
    ``GemmPolicy.tuning_table`` get bucket-local model constants for
    off-table shapes in a measured bucket (``kernels/ops`` prefers the
    bucket-local fit; the global fit is the fallback cell). Returns the
    globally fitted spec, before/after error, and the table.

    ``base_table`` makes a *partial re-calibration* incremental: the
    returned table carries the base records merged under the fresh ones
    (same-bucket records are replaced by the new measurement), while the
    ``fits`` are ONLY this run's -- stale per-bucket ``SpecFit`` cells from
    the base age out rather than silently steering the analytic chooser
    with constants an older run (other machine load, other jax version,
    other interpret/hardware mode) measured. Fitted constants must come
    from one coherent measurement pass; records are per-bucket facts and
    merge safely.
    """
    from repro.core import tsmm

    pol = policy if policy is not None else tsmm.current_policy()
    if spec is not None and spec is not pol.spec:
        pol = pol.with_(spec=spec)
    table = build_table(shapes, dtype=dtype, policy=pol, reps=reps,
                        warmup=warmup, explore_vmem=explore_vmem)
    obs = observations_from_table(table)
    fitted = fit_spec(pol.spec, obs)
    fits = [SpecFit(*GLOBAL_FIT, pol.spec.name,
                    fitted.spec.step_overhead, fitted.spec.dma_latency,
                    fitted.spec.vmem_usable)]
    groups: dict[tuple, list[Observation]] = {}
    for o in obs:
        key = (o.kind, bucket_shape(o.m, o.d1, o.d2), _dtype_name(o.dtype))
        groups.setdefault(key, []).append(o)
    for (kind, bucket, dt), group in groups.items():
        local = fit_spec(pol.spec, group)
        fits.append(SpecFit(kind, bucket, dt, pol.spec.name,
                            local.spec.step_overhead,
                            local.spec.dma_latency,
                            local.spec.vmem_usable))
    if base_table is not None:
        # base fits intentionally dropped (see docstring); records merge
        # with this run's measurements winning shared buckets.
        table = TuningTable.from_records(
            (*base_table.records, *table.records))
    return dataclasses.replace(fitted, table=table.with_fits(fits))
