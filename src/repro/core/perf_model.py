"""TPU port of the TSM2X analytic performance model (paper Section 3.1.6-3.1.9).

The paper drives kernel-parameter selection (t1, t2, t3) from an analytic
model built on three ingredients: (a) a compute-vs-memory-bound classifier
``t2_threshold = PeakPerf / PeakBand * bytes_per_elem``, (b) occupancy /
Little's-law utilization terms, and (c) a gradient-descent search over the
parameter space (Algorithm 5).

On TPU the same decision structure survives with different hardware terms:

* ``t1`` (threads per block / B-tile rows)  -> ``block_k``: rows of B staged
  per VMEM window, which is also the A-tile reduction depth per grid step.
* ``t2`` (C columns per thread in flight)   -> ``block_n``: output columns
  held in the VMEM accumulator (for the paper's n <= 32 this is just n).
* ``t3`` (A elements prefetched per thread) -> ``block_m``: A-tile rows per
  DMA; Mosaic's automatic double-buffering replaces the hand-rolled
  nextA/nextB register prefetch of Algorithm 4.
* occupancy / warp latency -> grid-cell parallelism and DMA pipeline depth.

The search (``choose_params_*``) is a discrete argmax over the modeled time
instead of continuous gradient descent: the TPU parameter space is small and
hardware-quantized (sublane 8 x lane 128 tiles), so enumerate-and-score is
exact where GD was approximate.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

import jax.numpy as jnp

from repro.analysis import contracts

Bound = Literal["memory", "compute", "latency"]


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """Hardware constants. Defaults: TPU v5e (task-spec numbers)."""

    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12
    peak_flops_f32: float = 197e12 / 4  # MXU f32 path ~ 1/4 of bf16
    hbm_bw: float = 819e9
    ici_bw_per_link: float = 50e9
    vmem_bytes: int = 128 * 2**20
    # Fraction of VMEM the pipeliner may use for in-flight windows
    # (double-buffered in + out + scratch accumulator + compiler headroom).
    vmem_usable: float = 0.5
    # DMA issue-to-first-byte latency (s); TPU HBM round trip ~ O(1us).
    dma_latency: float = 1e-6
    # Per-grid-step fixed overhead of the Mosaic pipeline (s).
    step_overhead: float = 2e-7
    # MXU native tile (systolic array is 128x128; sublane granularity 8).
    lane: int = 128
    sublane: int = 8
    # Independent compute cores the grid's PARALLEL cells can occupy.
    # The peak_flops/hbm_bw numbers above are whole-chip: a grid whose
    # parallel dimensions collapse below n_cores leaves cores idle and
    # only reaches a cores_busy/n_cores fraction of both peaks (each core
    # owns its slice of the HBM ports). v5e has a single TensorCore;
    # v5p is a megacore (2 TensorCores behind one grid).
    n_cores: int = 1

    def peak_flops(self, dtype) -> float:
        return self.peak_flops_bf16 if jnp.dtype(dtype).itemsize <= 2 else self.peak_flops_f32


V5E = TPUSpec()

# TPU v5p: the paper's core observation -- the winning variant flips with
# hardware generation -- needs at least two generations on file. v5p's
# flops/byte ridge (459/2765 ~ 166) sits well below v5e's (197/0.819 ~ 241),
# so the same shape can change bound class between the two.
V5P = TPUSpec(
    name="tpu_v5p",
    peak_flops_bf16=459e12,
    peak_flops_f32=459e12 / 4,
    hbm_bw=2765e9,
    ici_bw_per_link=100e9,
    n_cores=2,  # megacore: Mosaic splits parallel grid dims across 2 cores
)

SPECS: dict[str, TPUSpec] = {
    "tpu_v5e": V5E,
    "v5e": V5E,
    "tpu_v5p": V5P,
    "v5p": V5P,
}


def get_spec(name: str) -> TPUSpec:
    """Look up a hardware spec by name (``GemmPolicy(spec=...)`` plumbing)."""
    try:
        return SPECS[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown TPU spec {name!r}: known specs are "
            f"{sorted(SPECS)}") from None


def bytes_per_elem(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def t2_threshold(spec: TPUSpec = V5E, dtype=jnp.bfloat16) -> float:
    """Paper eq. (Section 3.1.8): boundary value of t2 (here: of n).

    n below the threshold => the TSM2 problem is memory-bound. On v5e/bf16
    this is ~481, so every paper shape (n <= 32) is memory-bound: the
    kernel's whole job is streaming A at HBM speed.
    """
    return spec.peak_flops(dtype) / spec.hbm_bw * bytes_per_elem(dtype)


def arithmetic_intensity(m: int, k: int, n: int, dtype=jnp.bfloat16) -> float:
    """FLOPs per HBM byte moved, assuming each operand moves exactly once."""
    flops = 2.0 * m * k * n
    bts = (m * k + k * n + m * n) * bytes_per_elem(dtype)
    return flops / bts


def classify(m: int, k: int, n: int, spec: TPUSpec = V5E, dtype=jnp.bfloat16) -> Bound:
    """Paper Section 1: the three regimes of tall-and-skinny GEMM.

    * m ~ k >> n, n below threshold  -> memory-bound (TSM2R main case)
    * m ~ k >> n, n above threshold  -> compute-bound
    * m >> k ~ n (k tiny)            -> latency-bound (TSM2L case): the
      per-grid-cell reduction is too shallow to hide DMA latency.
    """
    ridge = spec.peak_flops(dtype) / spec.hbm_bw  # flops per byte at the roofline ridge
    # Latency test: with k tiny, even a maximal A tile gives a pipeline only
    # a few steps deep; per-cell work ~ bm*k*n flops vs ~us-scale latency.
    if k <= 4 * spec.lane and k <= 4 * n * spec.sublane:
        return "latency"
    if arithmetic_intensity(m, k, n, dtype) < ridge:
        return "memory"
    return "compute"


# ---------------------------------------------------------------------------
# Modeled execution time (the napkin math behind parameter choice)
# ---------------------------------------------------------------------------

def _roundup(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def occupancy(parallel_cells: int, spec: TPUSpec = V5E) -> float:
    """Fraction of the chip's cores the grid's parallel cells can keep busy.

    ``min(n_cores, cells) / n_cores``: the TPU analogue of the paper's
    occupancy term (Section 3.1.9 -- warps resident per SM). Sequential
    ("arbitrary") grid dims contribute nothing; a kernel whose parallel
    dims collapse to one cell runs on one core of an n_cores chip and sees
    1/n_cores of both compute and HBM peaks. This is the term that makes
    split-reduction worth anything: splitting the reduction multiplies
    ``parallel_cells`` by S at the cost of the partials round trip.
    """
    return min(spec.n_cores, max(parallel_cells, 1)) / spec.n_cores


def split_partials_bytes(splits: int, rows: int, cols: int) -> int:
    """Extra HBM traffic of an S-way split reduction: the (S, rows, cols)
    f32 partials are written once and read once by the tree-reduce
    epilogue (S=1 writes the output directly: zero extra traffic)."""
    if splits <= 1:
        return 0
    return 2 * splits * rows * _roundup(cols, 128) * 4


def tsm2r_vmem_usage(bm: int, bk: int, n: int, dtype) -> int:
    """VMEM bytes for one grid cell, double-buffered in-streams + acc + out.

    Alias of ``analysis.contracts.tsm2r_footprint`` -- the footprint math
    lives in the contract layer so the model, the dispatcher and the
    auditor can never disagree on it (likewise the two aliases below).
    """
    return contracts.tsm2r_footprint(bm, bk, n, dtype)


def tsm2r_model_time(m: int, k: int, n: int, bm: int, bk: int,
                     spec: TPUSpec = V5E, dtype=jnp.bfloat16, *,
                     splits: int = 1) -> float:
    """Modeled wall time of the TSM2R kernel on ``spec``.

    Memory term: A moves once; B's (bk, n) window is re-fetched once per
    m-block (the paper's n/t1 re-load factor becomes m/bm here); C written
    once. Compute term: MXU time at n/lane utilization (skinny n wastes MXU
    columns -- irrelevant while memory-bound, harmful past the threshold).
    Latency term: pipeline prologue + per-step overhead; deep grids amortize.

    ``splits`` > 1 models the split-reduction variant: the k sweep is cut
    into S independent parallel slices (grid parallel cells x S, occupancy
    up on multi-core chips) at the cost of the (S, m, n) f32 partials
    round trip (``split_partials_bytes``) -- the TSM paper's leap-based
    global-reduce trade, discretized.
    """
    b = bytes_per_elem(dtype)
    gm, gk = math.ceil(m / bm), math.ceil(k / (splits * bk))
    steps = gm * gk * splits
    a_bytes = m * k * b
    b_bytes = k * _roundup(n, 128) * b * gm     # refetched per m-block
    c_bytes = m * _roundup(n, 128) * b
    c_bytes += split_partials_bytes(splits, m, n)
    occ = occupancy(gm * splits, spec)
    t_mem = (a_bytes + b_bytes + c_bytes) / (spec.hbm_bw * occ)
    # MXU: (bm, bk) x (bk, n) per step; effective peak scales with n/lane.
    mxu_eff = min(n, spec.lane) / spec.lane
    t_comp = 2.0 * m * k * max(n, 1) / (
        spec.peak_flops(dtype) * max(mxu_eff, 1e-3) * occ)
    t_lat = spec.dma_latency + steps * spec.step_overhead
    return max(t_mem, t_comp) + t_lat


def tsm2l_vmem_usage(bm: int, k: int, n: int, dtype) -> int:
    """VMEM bytes for one TSM2L grid cell (contract-layer alias)."""
    return contracts.tsm2l_footprint(bm, k, n, dtype)


def tsmt_vmem_usage(bm: int, ba: int, bdim: int, dtype) -> int:
    """VMEM bytes for one TSMT grid cell (contract-layer alias)."""
    return contracts.tsmt_footprint(bm, ba, bdim, dtype)


def tsm2l_model_time(m: int, k: int, n: int, bm: int,
                     spec: TPUSpec = V5E, dtype=jnp.bfloat16) -> float:
    """TSM2L: whole B in VMEM, one pass over A, grid over m only.

    The tcf trade of the paper (fewer, fatter threads) appears as the
    bm-vs-grid-depth term: tiny bm => many shallow steps => per-step
    overhead dominates (latency-bound); huge bm => too few cells to overlap
    DMA with compute across steps.
    """
    b = bytes_per_elem(dtype)
    steps = math.ceil(m / bm)
    t_mem = (m * k + k * n + m * _roundup(n, 128)) * b / spec.hbm_bw
    mxu_eff = min(n, spec.lane) / spec.lane * min(k, spec.lane) / spec.lane
    t_comp = 2.0 * m * k * n / (spec.peak_flops(dtype) * max(mxu_eff, 1e-3))
    # Pipeline needs >= 2 steps to overlap at all; penalize degenerate grids.
    overlap_penalty = 2.0 if steps < 2 else 1.0
    t_lat = spec.dma_latency * overlap_penalty + steps * spec.step_overhead
    return max(t_mem, t_comp) + t_lat


def tsmt_model_time(m: int, a: int, bdim: int, bm: int, ba: int,
                    spec: TPUSpec = V5E, dtype=jnp.bfloat16, *,
                    splits: int = 1) -> float:
    """Modeled TSMT wall time; ``splits`` models the split-reduction
    variant (the m sweep cut into S parallel slices emitting (S, a, bdim)
    f32 partials). This is THE occupancy-starved kernel of the framework:
    with PowerSGD/ABFT shapes (a, bdim <= 16) the parallel grid collapses
    to ``ceil(a/ba) == 1`` cell, so on an n_cores > 1 chip the whole
    reduction runs on one core unless S > 1 re-widens the grid.
    """
    b = bytes_per_elem(dtype)
    ga, gm = math.ceil(a / ba), math.ceil(m / (splits * bm))
    x_bytes = m * a * b
    y_bytes = m * _roundup(bdim, 128) * b * ga   # Y refetched per a-block
    out_bytes = (a * _roundup(bdim, 128) * b
                 + split_partials_bytes(splits, a, bdim))
    occ = occupancy(ga * splits, spec)
    t_mem = (x_bytes + y_bytes + out_bytes) / (spec.hbm_bw * occ)
    mxu_eff = min(bdim, spec.lane) / spec.lane
    t_comp = 2.0 * m * a * bdim / (
        spec.peak_flops(dtype) * max(mxu_eff, 1e-3) * occ)
    t_lat = spec.dma_latency + ga * gm * splits * spec.step_overhead
    return max(t_mem, t_comp) + t_lat


# ---------------------------------------------------------------------------
# Parameter choice (paper Algorithm 5, discrete TPU analogue)
# ---------------------------------------------------------------------------

_BM_CANDIDATES = (256, 512, 1024, 2048, 4096)
_BK_CANDIDATES = (128, 256, 512, 1024, 2048)
_BM_L_CANDIDATES = (256, 512, 1024, 2048, 4096, 8192, 16384)
_BA_CANDIDATES = (128, 256, 512, 1024)
# Split-reduction factors (S partial accumulators over the reduction axis).
# S=1 is the sequential kernel; the grids below only admit S > 1 when the
# reduction still has >= one full block per slice (deeper splits would be
# pure padding). tsm2l has no reduction grid axis (k is resident), so it
# has no split dimension.
SPLIT_CANDIDATES = (1, 2, 4, 8, 16)

_TIE_EPS = 1e-12


def _pick_best(scored, tie_key):
    """Argmin of modeled time; ties (within _TIE_EPS) break by ``tie_key``.

    The documented rule, applied uniformly to all three choosers: ties
    break toward *deeper* pipelines along the streamed/reduction axis
    (smaller reduction-axis block => more grid steps => better DMA overlap),
    and residual ties toward fewer re-fetches of the stationary operand
    (larger parallel-axis block).
    """
    best_t = min(t for t, _ in scored)
    tied = [p for t, p in scored if t <= best_t + _TIE_EPS]
    return min(tied, key=tie_key)


def tsm2r_candidates(m: int, k: int, n: int, spec: TPUSpec = V5E,
                     dtype=jnp.bfloat16) -> list[tuple[int, int, int]]:
    """All VMEM-feasible (block_m, block_k, splits) candidates for TSM2R.

    This is the grid both the analytic argmin (``choose_params_tsm2r``) and
    the measured-time autotuner (``core.autotune``) search over, so the two
    halves of Algorithm 5 score exactly the same parameter space. The
    feasibility filter IS ``analysis.contracts.feasible`` (VMEM budget,
    quantized-dim caps, split whole-slice feasibility -- per-cell VMEM is
    split-invariant), so the model can never score a block the kernel
    contracts reject.
    """
    return [(bm, bk, s)
            for bm in _BM_CANDIDATES
            for bk in _BK_CANDIDATES
            for s in SPLIT_CANDIDATES
            if contracts.feasible(
                "tsm2r", (m, k, n),
                {"block_m": bm, "block_k": bk, "splits": s}, dtype, spec)]


def tsm2l_candidates(m: int, k: int, n: int, spec: TPUSpec = V5E,
                     dtype=jnp.bfloat16) -> list[int]:
    """All VMEM-feasible block_m candidates for TSM2L (filter:
    ``analysis.contracts.feasible``)."""
    return [bm for bm in _BM_L_CANDIDATES
            if contracts.feasible("tsm2l", (m, k, n), {"block_m": bm},
                                  dtype, spec)]


def tsmt_candidates(m: int, a: int, bdim: int, spec: TPUSpec = V5E,
                    dtype=jnp.bfloat16) -> list[tuple[int, int, int]]:
    """All VMEM-feasible (block_m, block_a, splits) candidates for TSMT.

    m is the reduction here, so S slices the m sweep; S > 1 requires at
    least one full (bm) block per slice. Filter:
    ``analysis.contracts.feasible``.
    """
    return [(bm, ba, s)
            for bm in _BM_CANDIDATES
            for ba in _BA_CANDIDATES
            for s in SPLIT_CANDIDATES
            if contracts.feasible(
                "tsmt", (m, a, bdim),
                {"block_m": bm, "block_a": ba, "splits": s}, dtype, spec)]


def choose_params_tsm2r(m: int, k: int, n: int, spec: TPUSpec = V5E,
                        dtype=jnp.bfloat16) -> tuple[int, int, int]:
    """Pick (block_m, block_k, splits) minimizing modeled time under the
    VMEM budget.

    Same contract as the paper's Algorithm 5 (choose t2/t3 per bound class,
    then offline-profile t1): we enumerate the hardware-quantized candidate
    grid and take the argmin of the modeled time; ties break toward NOT
    splitting (S=1 -- partials cost nothing only when modeled equal), then
    toward deeper k-pipelines (smaller block_k -- better DMA overlap),
    residual ties toward larger block_m (fewer B-window re-fetches).
    """
    cands = tsm2r_candidates(m, k, n, spec, dtype)
    if not cands:  # tiny problem: single block (dtype-aware row quantum)
        return (min(_roundup(m, contracts.min_sublane(spec, dtype)), 256),
                min(_roundup(k, spec.lane), 128), 1)
    scored = [(tsm2r_model_time(m, k, n, bm, bk, spec, dtype, splits=s),
               (bm, bk, s))
              for bm, bk, s in cands]
    return _pick_best(scored, lambda p: (p[2], p[1], -p[0]))


def choose_params_tsm2l(m: int, k: int, n: int, spec: TPUSpec = V5E,
                        dtype=jnp.bfloat16) -> int:
    """Pick block_m (the tcf analogue) for TSM2L.

    Ties break toward deeper m-pipelines (smaller block_m), per the same
    rule as ``choose_params_tsm2r``.
    """
    cands = tsm2l_candidates(m, k, n, spec, dtype)
    if not cands:
        return 256
    scored = [(tsm2l_model_time(m, k, n, bm, spec, dtype), bm) for bm in cands]
    return _pick_best(scored, lambda bm: bm)


def choose_params_tsmt(m: int, a: int, bdim: int, spec: TPUSpec = V5E,
                       dtype=jnp.bfloat16) -> tuple[int, int, int]:
    """Pick (block_m, block_a, splits) for the transposed kernel.

    Ties break toward not splitting (S=1), then deeper reduction pipelines
    (smaller block_m -- m is the streamed reduction here), residual ties
    toward larger block_a (fewer Y-window re-fetches) -- the same rule as
    the other choosers.
    """
    cands = tsmt_candidates(m, a, bdim, spec, dtype)
    if not cands:  # tiny problem: single block (dtype-aware row quantum)
        return (min(_roundup(m, contracts.min_sublane(spec, dtype)), 256),
                min(_roundup(a, spec.lane), 128), 1)
    scored = [(tsmt_model_time(m, a, bdim, bm, ba, spec, dtype, splits=s),
               (bm, ba, s))
              for bm, ba, s in cands]
    return _pick_best(scored, lambda p: (p[2], p[0], -p[1]))


# ---------------------------------------------------------------------------
# Utilization estimates (paper Fig. 7/11 metric, modeled for v5e)
# ---------------------------------------------------------------------------

def modeled_bandwidth_utilization(m: int, k: int, n: int, bm: int, bk: int,
                                  spec: TPUSpec = V5E, dtype=jnp.bfloat16,
                                  *, splits: int = 1) -> float:
    """Fraction of peak HBM bandwidth the kernel sustains (modeled).

    util = minimal-bytes / (modeled_time * peak_bw): 1.0 means A/B/C each
    move once at full stream rate -- the paper's definition of success for
    the memory-bound regime. Pass the chooser's ``splits`` so the
    utilization describes the same kernel as the modeled time.
    """
    b = bytes_per_elem(dtype)
    min_bytes = (m * k + k * n + m * n) * b
    t = tsm2r_model_time(m, k, n, bm, bk, spec, dtype, splits=splits)
    return min(1.0, min_bytes / (t * spec.hbm_bw))


def modeled_compute_utilization(m: int, k: int, n: int, bm: int, bk: int,
                                spec: TPUSpec = V5E, dtype=jnp.bfloat16,
                                *, splits: int = 1) -> float:
    flops = 2.0 * m * k * n
    t = tsm2r_model_time(m, k, n, bm, bk, spec, dtype, splits=splits)
    return min(1.0, flops / (t * spec.peak_flops(dtype)))
