"""Shape-dispatched tall-and-skinny matmul: the framework's public GEMM entry.

``tsmm(a, b)`` inspects shapes against the perf model (paper Section 3.1.8's
bound classifier) and routes to:

* TSM2R  when m ~ k >> n (skinny right operand, memory-bound stream of A),
* TSM2L  when m >> k ~ n (tiny contraction, latency-regime),
* XLA ``dot_general`` otherwise (regular shapes belong on the stock MXU
  path -- the paper's observation that cuBLAS already wins there).

``tsmm_t(x, y)`` is the transposed entry (X^T Y over a huge m).

Dispatch is static (shapes are trace-time constants under jit), so choosing
a path never introduces control flow into the compiled graph.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import perf_model
from repro.kernels import ops

# A dim is "skinny" when this much smaller than its partner.
SKINNY_RATIO = 16
# Largest skinny dim we route to the custom kernels (past this the MXU
# path's compute-bound efficiency beats the streaming formulation).
MAX_SKINNY = 256
# Smallest tall dim worth a custom kernel launch.
MIN_TALL = 2048


def classify_gemm(m: int, k: int, n: int) -> str:
    """Return one of 'tsm2r' | 'tsm2l' | 'tsmt_hint' | 'dense'."""
    if m >= MIN_TALL and n <= MAX_SKINNY and m >= SKINNY_RATIO * n:
        if k <= MAX_SKINNY:          # m >> k ~ n: tiny contraction
            return "tsm2l"
        if k >= SKINNY_RATIO * n:    # m ~ k >> n
            return "tsm2r"
    return "dense"


def tsmm(a: jnp.ndarray, b: jnp.ndarray, *, interpret: bool | None = None,
         force: str | None = None) -> jnp.ndarray:
    """A[m,k] @ B[k,n] via the best path for the shape."""
    m, k = a.shape
    n = b.shape[1]
    kind = force or classify_gemm(m, k, n)
    if kind == "tsm2r":
        return ops.tsm2r(a, b, interpret=interpret)
    if kind == "tsm2l":
        return ops.tsm2l(a, b, interpret=interpret)
    return lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32).astype(a.dtype)


def tsmm_t(x: jnp.ndarray, y: jnp.ndarray, *, interpret: bool | None = None,
           force: str | None = None) -> jnp.ndarray:
    """X[m,a]^T @ Y[m,b] via TSMT when m is huge and a, b small-ish."""
    m, a_dim = x.shape
    b_dim = y.shape[1]
    use_kernel = force == "tsmt" or (
        force is None and m >= MIN_TALL and b_dim <= 512
        and m >= SKINNY_RATIO * max(a_dim, b_dim) // 4
    )
    if use_kernel:
        return ops.tsmt(x, y, interpret=interpret)
    return lax.dot_general(x, y, (((0,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32).astype(x.dtype)


def bound_class(m: int, k: int, n: int, dtype=jnp.bfloat16) -> perf_model.Bound:
    return perf_model.classify(m, k, n, perf_model.V5E, dtype)
