"""Shape-dispatched tall-and-skinny matmul behind a scoped ``GemmPolicy``.

``tsmm(a, b)`` inspects shapes against the perf model (paper Section 3.1.8's
bound classifier) and routes to:

* TSM2R  when m ~ k >> n (skinny right operand, memory-bound stream of A),
* TSM2L  when m >> k ~ n (tiny contraction, latency-regime),
* XLA ``dot_general`` otherwise (regular shapes belong on the stock MXU
  path -- the paper's observation that cuBLAS already wins there).

``tsmm_t(x, y)`` is the transposed entry (X^T Y over a huge m). Both accept
N-d batched lhs operands: ``tsmm`` collapses the leading dims of a
``(..., m, k)`` lhs into the tall dim, ``tsmm_t`` collapses them into the
reduction, so call sites (``layers.dense``, PowerSGD, ABFT) never hand-roll
reshapes.

Every knob that used to live in env vars and per-call kwargs is owned by an
explicit, lexically scoped :class:`GemmPolicy`:

    with tsmm.policy(mode="dense"):          # A/B arm: stock XLA everywhere
        loss = train_step(state, batch)
    with tsmm.policy(spec=perf_model.V5P, interpret=False):
        out = serve_step(params, batch)

Dispatch is static (shapes and the policy are trace-time constants under
jit), so a jitted caller bakes the scoped policy into its cache entry --
entering a different scope does NOT retroactively change already-compiled
functions; A/B arms need separate jit caches exactly as before.

Behind the policy sits a pluggable backend registry mapping a classified
shape to an executor:

* ``pallas-tpu``  -- the Mosaic kernels (interpret auto-detected off-TPU),
* ``interpret``   -- the same kernels pinned to interpret mode,
* ``dense-xla``   -- plain ``dot_general``,
* ``shard_map``   -- wraps the dispatch per-shard over the data-parallel
  mesh axes, so per-device shapes stay tall-and-skinny under DP. This
  replaces the old hard guard that sent every call under a multi-chip
  ``with mesh:`` scope to the dense path: when the tall dim divides the DP
  axes and the per-shard shape still classifies tall-skinny, the kernels
  now run per shard (``tsmm_t`` reduces the per-shard partial products
  per ``GemmPolicy.reduce``: psum by default, stacked partials on
  ``reduce="none"``),
* ``shard_map-scatter`` -- the sharded-*output* variant for ``tsmm_t``:
  per-shard partials are combined with ``psum_scatter`` instead of a full
  ``psum``, so the (small) ``a x b`` product comes back row-sharded over
  the DP axes instead of replicated. Selected automatically for ``mmt``
  dispatch when the policy asks ``reduce="psum_scatter"`` and the output
  rows divide the DP shard count; this is the path for consumers that
  keep the product sharded (PowerSGD factors, ZeRO-sharded optimizer
  grads) and removes the structural all-gather between the kernel and
  those consumers.

``register_executor`` adds new backends; ``GemmPolicy.executor`` pins one.
Every executor invocation passes through the deterministic fault-injection
tap (``ft/inject.py``), and ``GemmPolicy.abft`` wraps kernel-kind results
in an online Huang-Abraham checksum verify/locate/correct guard whose
checksum GEMMs dispatch right back through this module (see the policy
docstring and ``ft/abft.py``).

DP axes are no longer a hard-coded convention: with
``GemmPolicy.dp_axes=None`` the dispatcher derives them from the ambient
mesh via :func:`derive_dp_axes` (conventional DP names first, then any
axis not named like a model/pipeline axis; a single-axis mesh is always
DP). An explicit ``dp_axes=(...)`` still overrides.

Both entries are differentiable: the ops they dispatch to carry custom_vjp
rules that take the policy through their nondiff args, so the backward
re-enters this dispatcher under the *caller's* scope (the VJP of one
tall-skinny class lands in another).

Legacy env vars still work as process-default aliases (deprecated):
``REPRO_TSMM=off`` constructs the process default with ``mode="dense"`` and
``REPRO_BF16_PARAM_GRADS=1`` with ``param_dtype_grads=True``. They are read
once at import (never inside traced code); ``refresh_default_policy()``
re-reads them.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
import os
import warnings

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro.core import perf_model
# inject sits below every layer (jax + stdlib only, no repro imports), so
# the dispatcher can route each executor invocation through its fault tap.
from repro.ft import inject as _inject
from repro.kernels import compat, ops

__all__ = [
    "GemmPolicy",
    "policy",
    "current_policy",
    "default_policy",
    "refresh_default_policy",
    "backward_policy",
    "classify_gemm",
    "classify_gemm_t",
    "tsmm",
    "tsmm_t",
    "bound_class",
    "derive_dp_axes",
    "register_executor",
    "unregister_executor",
    "executors",
    "executor_reduce_contract",
    "record_dispatches",
    "DispatchEvent",
    "LaunchMeta",
    "note_launch",
    "enabled",
]

# Classifier threshold defaults. These only seed the GemmPolicy fields
# below -- dispatch always reads the policy, never these constants.
SKINNY_RATIO = 16
MAX_SKINNY = 256
MIN_TALL = 2048
MAX_SKINNY_T = 512
SKINNY_RATIO_T = SKINNY_RATIO // 4

# The repo-wide *convention* for which mesh axes carry the batch. These are
# no longer the only names the dispatcher understands: they seed
# ``derive_dp_axes``, which reads the ambient mesh (see below). A policy can
# still pin axes per scope via GemmPolicy.dp_axes.
DP_AXIS_NAMES = ("pod", "data")

# Names treated as data-parallel when deriving dp axes from a mesh, in
# addition to DP_AXIS_NAMES, and names that mark an axis as model/pipeline
# parallel (never DP). Anything in neither set is DP only when no
# conventional DP name is present on the mesh.
_DP_NAME_HINTS = DP_AXIS_NAMES + ("dp", "batch", "replica", "replicas")
_MODEL_NAME_HINTS = frozenset({
    "model", "tensor", "tp", "mp", "expert", "experts", "ep",
    "pipe", "pipeline", "stage", "pp", "seq", "sequence", "sp",
})

_MM_KINDS = ("auto", "dense", "tsm2r", "tsm2l")
_MMT_KINDS = ("auto", "dense", "tsmt")
_ALL_MODES = ("auto", "dense", "tsm2r", "tsm2l", "tsmt")
_SHARD_MAP_MODES = ("auto", "never", "require", "local")
_REDUCE_MODES = ("psum", "psum_scatter", "none")
_QUANT_MODES = ("none", "int8")
_ABFT_MODES = ("none", "verify", "correct")


@dataclasses.dataclass(frozen=True)
class GemmPolicy:
    """Everything the GEMM dispatcher is allowed to decide from.

    Threshold fields (derivations against ``core/perf_model``, v5e/bf16):

    * ``min_tall`` = 2048: below ~2048 tall rows the kernel's fixed costs
      (``TPUSpec.dma_latency`` ~ 1us of pipeline prologue plus per-step
      overhead) rival the whole modeled stream time
      (2048 x 256 x 2 B / 819 GB/s ~ 1.3us) -- launching a custom kernel
      cannot win.
    * ``max_skinny`` = 256 (= 2 MXU lane tiles): past two 128-lane tiles of
      output columns the generic MXU path's efficiency (n/128 per pass) is
      high enough that the streaming formulation's bandwidth advantage is
      gone.
    * ``skinny_ratio`` = 16: a dim counts as skinny only when >= 16x smaller
      than its partner; at milder aspect ratios the problem sits near the
      roofline ridge where the stock path already streams close to peak.
    * ``max_skinny_t`` = 512: the TSMT kernel keeps its (block_a, b) f32
      accumulator as a single unblocked VMEM tile, and 512 is
      ``t2_threshold(V5E, bf16)`` ~ 481 -- the paper's memory/compute
      boundary -- rounded up to the next lane multiple: past it the problem
      is compute-bound and belongs on the MXU path.
    * ``skinny_ratio_t`` = ``skinny_ratio // 4`` = 4: the transposed entry
      stays profitable at 4x milder aspect ratios because BOTH operands
      stream over the same tall m exactly once (there is no per-m-block
      B re-fetch term in ``tsmt_model_time``).

    ``mode`` pins dispatch: "auto" classifies; "dense" forces the XLA path
    everywhere; a kind name ("tsm2r"/"tsm2l" for ``tsmm``, "tsmt" for
    ``tsmm_t``) forces that kernel for its own entry and leaves the other
    entry on auto (so VJP re-dispatch stays shape-correct).

    ``interpret``: tri-state Pallas interpret flag (None = auto-detect:
    interpret off-TPU). ``spec``: the hardware model driving block-size
    choice (see ``perf_model.SPECS``). ``param_dtype_grads``: emit parameter
    gradients in the parameter dtype instead of f32 (halves per-device grad
    memory under pure-DP/ZeRO-1; accumulation inside each dot stays f32).

    ``shard_map``: "auto" wraps dispatch per-shard under a >1-device mesh
    context when the tall dim divides the DP axes and the per-shard shape
    still classifies tall-skinny (dense fallback otherwise, exactly the old
    guard); "never" restores the old always-dense-under-mesh behavior;
    "require" raises instead of falling back (tests/benchmarks); "local"
    ignores the mesh context entirely and dispatches on the shapes as seen
    -- what the shard_map executor sets for its per-shard bodies, and what
    call sites inside their *own* shard_map should scope.
    ``dp_axes``: mesh axis names carrying the batch; None = derive from
    the ambient mesh (:func:`derive_dp_axes` -- conventional DP names
    first, then non-model-named axes; shared with
    ``distributed.sharding``). An explicit tuple is filtered against the
    mesh's axis names but otherwise taken as-is.
    ``executor``: pin a registered backend by name, bypassing selection.

    ``reduce``: how ``tsmm_t``'s per-shard partial products combine under
    the shard_map executors (it has no effect outside a multi-chip mesh
    scope, and none on the ``tsmm`` entry, whose shards never reduce):

    * "psum" (default) -- full all-reduce; output replicated. The drop-in
      semantics every caller had before this knob existed.
    * "psum_scatter" -- reduce-scatter; the global (a, b) output is
      row-sharded over the DP axes. Same global shape and values as
      "psum", different layout: consumers that immediately re-shard or
      only touch their own rows (PowerSGD factors, ZeRO-1 optimizer
      shards) skip the all-gather half of the all-reduce. Falls back to
      dense-xla when the output rows don't divide the shard count
      (shard_map="require" raises instead).
    * "none" -- no collective: shards return their *partial* products,
      stacked, so the global output is (shards * a, b). For callers that
      run their own reduction schedule. Never auto-selected over
      "psum"-shaped consumers' objections: you only get it by setting it.

    Backward passes re-dispatch with the *matching* collective
    (``backward_policy`` keeps ``reduce`` -- a psum_scatter scope keeps
    its weight-gradient ``tsmm_t``s sharded too), except "none", which
    downgrades to "psum" so cotangent shapes stay equal to primal shapes
    (custom_vjp requires it).

    ``tuning_table``: a ``core.autotune.TuningTable`` of measured-best
    block params (None = pure analytic choice). When set, ``kernels/ops``
    consults the measured winner for the shape's bucket before falling
    back to ``perf_model.choose_params_*`` (run under the table's
    bucket-local fitted spec when one exists); explicit per-call block
    kwargs still win over both. Must stay hashable (policies flow through
    ``custom_vjp`` nondiff args), which TuningTable is; typed loosely here
    to keep the dispatcher import-cycle-free.

    ``split``: the split-reduction (split-K) knob for the kernels whose
    reduction axis is gridded (``tsm2r``, ``tsmt``; ``tsm2l`` keeps its
    whole contraction VMEM-resident and has nothing to split):

    * "auto" (default) -- the split factor S is tuned like a block size:
      measured winner from the tuning table, else the occupancy-aware
      analytic argmin (``perf_model.choose_params_*``, which only ever
      prefers S > 1 when the grid's parallel cells under-occupy
      ``spec.n_cores``).
    * an int -- pin exactly that S for every dispatched kernel in scope
      (1 = sequential). Shape-specific, so :func:`backward_policy` strips
      it back to "auto" -- the cotangent GEMMs have different shapes.
    * "never" -- force the sequential kernels everywhere, table and model
      notwithstanding (the A/B control arm). Scope-wide caller intent, so
      the backward *preserves* it.

    Split partials are summed inside the op's epilogue, so under the
    shard_map executors each shard splits its own slice locally and the
    psum/psum_scatter/none contract on the cross-shard reduction is
    unchanged -- ``reduce=`` and ``split`` compose freely.

    ``quant``: low-precision operand storage for the Pallas kernel paths
    (``kernels/quant.py``):

    * "none" (default) -- operands stream at their own dtype; nothing
      changes anywhere.
    * "int8" -- operands are symmetrically quantized per resolved kernel
      row block (tall operand; the small operand gets one per-tensor
      scale), streamed as int8 tiles, and dequantized in the f32
      accumulate epilogue; outputs return in the caller's dtype. Block
      resolution, tuning-table lookups and contract checks all run
      against the int8 *effective dtype* (1 byte/elem HBM pricing, 32-row
      sublane tiles), so autotuned grids are measured for what actually
      launches. Only the kernel executors quantize: "dense-xla" ignores
      the knob (a dense fallback is exact, never silently low-precision),
      and split partials are dequantized before they leave the kernel so
      the reduce tree and shard_map collectives are unchanged. Scope-wide
      numeric intent, so :func:`backward_policy` preserves it -- cotangent
      GEMMs under an int8 scope quantize too (expect looser gradient
      tolerances, as with any quantization-aware setup).

    ``abft``: online algorithm-based fault tolerance for the kernel-kind
    dispatches (``ft/abft.py`` owns the math; this knob owns the wiring):

    * "none" (default) -- no checksums, zero overhead: the wrap is never
      entered and the dispatch path is byte-identical to before the knob
      existed.
    * "verify" -- every tsm2r/tsm2l/tsmt result is checked against
      Huang-Abraham weighted column checksums computed *through this same
      dispatcher* (checksum linearity: the checksum of the output equals
      the GEMM of the operand checksum), with a shape/dtype-derived
      tolerance (``ft.abft.tolerance``). A detected silent data
      corruption poisons the full output with NaN -- trace-safe, no host
      callback -- so any non-finite guard downstream (the train loop's
      ``step_ok``) sees it.
    * "correct" -- additionally localizes a single faulty output row from
      the ramp/plain checksum-deviation ratio and repairs it in place
      (bit-flip faults repair bit-exactly via a nearest-single-bit-flip
      snap); faults the localization cannot explain (multi-row damage,
      non-finite wreckage) fall back to the NaN poison.

    The checksum GEMMs dispatch with ``abft="none"`` (no recursion), f32
    operands, and the scope's executor pin stripped. Dense-kind dispatches
    are not wrapped (the stock XLA path is not the SDC surface this guards)
    and neither are the *outer* shard_map events -- the per-shard
    re-dispatch inherits ``abft`` through the inner policy, so each shard
    verifies/corrects its own local GEMM. Scope-wide integrity intent, so
    :func:`backward_policy` preserves it (contracts ``abft-policy`` rule):
    cotangent GEMMs under a verify scope are verified too.
    """

    mode: str = "auto"
    spec: perf_model.TPUSpec = perf_model.V5E
    skinny_ratio: int = SKINNY_RATIO
    max_skinny: int = MAX_SKINNY
    min_tall: int = MIN_TALL
    max_skinny_t: int = MAX_SKINNY_T
    skinny_ratio_t: int = SKINNY_RATIO_T
    interpret: bool | None = None
    param_dtype_grads: bool = False
    shard_map: str = "auto"
    dp_axes: tuple[str, ...] | None = None
    executor: str | None = None
    tuning_table: object | None = None
    reduce: str = "psum"
    split: str | int = "auto"
    quant: str = "none"
    abft: str = "none"
    # Trace-time contract assertion: when set, kernels/ops re-checks every
    # resolved launch configuration against analysis.contracts (the same
    # predicates the perf model's candidate filter and the offline auditor
    # use) and raises ValueError on a violation instead of launching.
    # Preserved by backward_policy (it is scope-wide intent, like a dense
    # pin); off by default -- the predicates are cheap but the mode exists
    # for CI, tests and debugging, not for the hot path.
    verify_contracts: bool = False

    def __post_init__(self):
        s = self.split
        if not (s in ("auto", "never")
                or (isinstance(s, int) and not isinstance(s, bool)
                    and s >= 1)):
            raise ValueError(
                f"unknown GemmPolicy split {self.split!r}: valid values are "
                "'auto', 'never', or a positive int split factor")
        if self.mode not in _ALL_MODES:
            raise ValueError(
                f"unknown GemmPolicy mode {self.mode!r}: valid modes are "
                f"{', '.join(_ALL_MODES)}")
        if self.shard_map not in _SHARD_MAP_MODES:
            raise ValueError(
                f"unknown GemmPolicy shard_map {self.shard_map!r}: valid "
                f"values are {', '.join(_SHARD_MAP_MODES)}")
        if self.reduce not in _REDUCE_MODES:
            raise ValueError(
                f"unknown GemmPolicy reduce {self.reduce!r}: valid "
                f"values are {', '.join(_REDUCE_MODES)}")
        if self.quant not in _QUANT_MODES:
            raise ValueError(
                f"unknown GemmPolicy quant {self.quant!r}: valid "
                f"values are {', '.join(_QUANT_MODES)}")
        if self.abft not in _ABFT_MODES:
            raise ValueError(
                f"unknown GemmPolicy abft {self.abft!r}: valid "
                f"values are {', '.join(_ABFT_MODES)}")

    def with_(self, **overrides) -> "GemmPolicy":
        return dataclasses.replace(self, **overrides)


# ---------------------------------------------------------------------------
# Process default (legacy env-var aliases) + lexical scoping
# ---------------------------------------------------------------------------

def _policy_from_env() -> GemmPolicy:
    """Build the process-default policy from the deprecated env vars.

    Called at import and from ``refresh_default_policy()`` only -- never
    from traced code, so flipping an env var mid-process does nothing until
    an explicit refresh (and even then only affects future traces).
    """
    kw = {}
    raw = os.environ.get("REPRO_TSMM")
    if raw is not None:
        warnings.warn(
            "REPRO_TSMM is deprecated; use `with tsmm.policy(mode=...)` or "
            "tsmm.refresh_default_policy() after changing it",
            DeprecationWarning, stacklevel=3)
        if raw.lower() in ("off", "0", "false"):
            kw["mode"] = "dense"
    raw = os.environ.get("REPRO_BF16_PARAM_GRADS")
    if raw is not None:
        warnings.warn(
            "REPRO_BF16_PARAM_GRADS is deprecated; use "
            "`with tsmm.policy(param_dtype_grads=True)`",
            DeprecationWarning, stacklevel=3)
        if raw == "1":
            kw["param_dtype_grads"] = True
    return GemmPolicy(**kw)


_DEFAULT_POLICY = _policy_from_env()
_POLICY_VAR: contextvars.ContextVar[GemmPolicy | None] = \
    contextvars.ContextVar("repro_gemm_policy", default=None)


def default_policy() -> GemmPolicy:
    """The process-default policy (env-var aliases applied)."""
    return _DEFAULT_POLICY


def refresh_default_policy() -> GemmPolicy:
    """Re-read the legacy env vars into the process default (tests/tools)."""
    global _DEFAULT_POLICY
    _DEFAULT_POLICY = _policy_from_env()
    return _DEFAULT_POLICY


def current_policy() -> GemmPolicy:
    """The innermost active ``with tsmm.policy(...)`` scope, else the
    process default."""
    return _POLICY_VAR.get() or _DEFAULT_POLICY


@contextlib.contextmanager
def policy(base: GemmPolicy | None = None, /, **overrides):
    """Scope a dispatch policy: ``with tsmm.policy(mode="dense"): ...``.

    ``base`` (positional) starts from an explicit GemmPolicy instead of the
    current scope; keyword overrides are applied on top via
    ``dataclasses.replace``. Scopes nest and restore on exit (also across
    exceptions). The policy is captured at *trace* time: jit-compiled
    callers keep the policy they were traced under.
    """
    p = base if base is not None else current_policy()
    if overrides:
        p = dataclasses.replace(p, **overrides)
    token = _POLICY_VAR.set(p)
    try:
        yield p
    finally:
        _POLICY_VAR.reset(token)


def backward_policy(p: GemmPolicy) -> GemmPolicy:
    """Policy for VJP re-dispatch: keep the caller's scope (spec,
    thresholds, interpret, a full-dense pin, the ``reduce`` collective)
    but drop a forward-kind force and any executor pin -- cotangent shapes
    classify for themselves, and a pinned ``shard_map`` executor must not
    recurse per-shard. ``reduce="none"`` downgrades to "psum": a stacked-
    partials gradient would change the cotangent's shape, which custom_vjp
    forbids; "psum_scatter" is kept, so weight-gradient ``tsmm_t``s in the
    backward land sharded without an extra all-gather. An *int* ``split``
    pin is stripped to "auto" (it was chosen for the forward shape; the
    cotangent GEMMs pick their own), while "never" is preserved -- it is
    scope-wide intent, like a dense pin. ``quant`` is likewise preserved
    (``dataclasses.replace`` carries it): an int8 scope keeps its
    cotangent GEMMs quantizable, per the contracts ``backward-quant``
    rule. ``abft`` is preserved the same way (contracts ``abft-policy``
    rule): integrity intent is scope-wide, so cotangent GEMMs under a
    verify/correct scope get their own checksums."""
    mode = p.mode if p.mode in ("auto", "dense") else "auto"
    reduce_ = "psum" if p.reduce == "none" else p.reduce
    split = "auto" if isinstance(p.split, int) else p.split
    if (mode == p.mode and p.executor is None and reduce_ == p.reduce
            and split == p.split):
        return p
    return dataclasses.replace(p, mode=mode, executor=None, reduce=reduce_,
                               split=split)


def enabled() -> bool:
    """Deprecated alias: True unless the current policy pins the dense
    path (the old ``REPRO_TSMM=off`` check)."""
    return current_policy().mode != "dense"


# ---------------------------------------------------------------------------
# Shape classification (thresholds owned by the policy)
# ---------------------------------------------------------------------------

def classify_gemm(m: int, k: int, n: int,
                  policy: GemmPolicy | None = None) -> str:
    """Return one of 'tsm2r' | 'tsm2l' | 'dense'."""
    p = policy if policy is not None else current_policy()
    if m >= p.min_tall and n <= p.max_skinny and m >= p.skinny_ratio * n:
        if k <= p.max_skinny:              # m >> k ~ n: tiny contraction
            return "tsm2l"
        if k >= p.skinny_ratio * n:        # m ~ k >> n
            return "tsm2r"
    return "dense"


def classify_gemm_t(m: int, a_dim: int, b_dim: int,
                    policy: GemmPolicy | None = None) -> str:
    """Transposed-entry classifier: 'tsmt' | 'dense' for X[m,a]^T Y[m,b].

    Thresholds (``max_skinny_t``, ``skinny_ratio_t``) are policy fields;
    see the GemmPolicy docstring for their perf-model derivation.
    """
    p = policy if policy is not None else current_policy()
    if (m >= p.min_tall and b_dim <= p.max_skinny_t
            and m >= p.skinny_ratio_t * max(a_dim, b_dim)):
        return "tsmt"
    return "dense"


# ---------------------------------------------------------------------------
# Dispatch spy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LaunchMeta:
    """One kernel launch a dispatch resolved to, as derived from the pure
    grid contract (``analysis.contracts.launch_grid``) by the op impls at
    trace time. ``kind`` includes "reduce" for the split-partials epilogue;
    ``splits`` is the *resolved* S (1 for the sequential kernels). The
    dataflow verifier proves this derivation equals what ``pallas_call``
    actually captures (its ``launch-meta-drift`` rule), so spy assertions
    on these fields are assertions about the real launch."""

    kind: str                           # "tsm2r"|"tsm2l"|"tsmt"|"reduce"
    grid: tuple[int, ...]
    dimension_semantics: tuple[str, ...]
    splits: int = 1


@dataclasses.dataclass(frozen=True)
class DispatchEvent:
    """One routing decision: which entry, classified kind, chosen executor,
    and the (tall, minor, minor) shape it was made for. Emitted at trace
    time -- a cached jit call emits nothing. ``split`` records the policy's
    split knob at dispatch ("auto" | "never" | a pinned int); ``quant``
    records the quantization knob ("none" | "int8") so spies can assert a
    quantized scope actually reached a quantized launch; ``launches``
    carries one :class:`LaunchMeta` per Pallas launch the executor's trace
    noted (via :func:`note_launch`) -- the resolved grid, semantics and S,
    so spies can assert grid shape, not just routing. Dense/XLA arms note
    nothing; the outer event of a shard_map dispatch is also empty (the
    per-shard re-dispatch events carry their own launches).

    ``abft`` records whether THIS dispatch's result is wrapped by the
    online checksum guard ("none" | "verify" | "correct"): the protected
    GEMM of an abft scope carries the mode, while the checksum GEMMs the
    wrap itself dispatches carry "none" -- so a spy asserts exactly one
    guarded event per protected call. ``faults`` carries the
    ``ft.inject.GemmFault``s the injection tap actually applied inside
    this dispatch (empty outside an injection scope), letting chaos tests
    assert the planned fault landed where the plan said."""

    entry: str       # "mm" (A @ B) | "mmt" (X^T Y)
    kind: str        # "tsm2r" | "tsm2l" | "tsmt" | "dense"
    executor: str    # registry key
    shape: tuple[int, int, int]
    split: str | int = "auto"
    quant: str = "none"
    launches: tuple = ()       # of LaunchMeta
    abft: str = "none"
    faults: tuple = ()         # of ft.inject.GemmFault


_LISTENERS: list = []

# Stack of per-dispatch LaunchMeta collectors: the public entries push one
# around their executor invocation (only while spies listen); the ops impls
# report resolved launches into the innermost frame via note_launch.
_LAUNCH_NOTES: list = []

# Parallel stack of per-dispatch applied-fault collectors: _run_executor
# reports the GemmFaults the injection tap landed into the innermost frame
# so the emitted DispatchEvent carries them.
_FAULT_NOTES: list = []


def note_launch(kind: str, grid, dimension_semantics, splits: int = 1
                ) -> None:
    """Record one resolved kernel launch onto the current dispatch's event
    (no-op outside a listened-to dispatch). Called by ``kernels/ops.py``
    with ``analysis.contracts.launch_grid`` output."""
    if _LAUNCH_NOTES:
        _LAUNCH_NOTES[-1].append(LaunchMeta(
            kind, tuple(grid), tuple(dimension_semantics), splits))


def _notify(entry: str, kind: str, executor: str, shape,
            split: str | int = "auto", quant: str = "none",
            launches: tuple = (), abft: str = "none",
            faults: tuple = ()) -> None:
    if _LISTENERS:
        ev = DispatchEvent(entry, kind, executor, tuple(shape), split,
                           quant, launches, abft, faults)
        for cb in tuple(_LISTENERS):
            cb(ev)


def _run_executor(ex, entry, kind, a, b, p):
    """Invoke a registered executor through the fault-injection tap
    (``ft.inject.tap_executor``): outside an injection scope this is
    exactly ``ex(...)``; inside one, the plan's bit flips for this
    trace-order site apply and the applied faults land on the innermost
    dispatch's event (when a spy is listening)."""
    out, applied = _inject.tap_executor(ex, entry, kind, a, b, p)
    if applied and _FAULT_NOTES:
        _FAULT_NOTES[-1].extend(applied)
    return out


def _dispatch(entry: str, kind: str, executor: str, shape, policy, run,
              abft: str = "none"):
    """Run the chosen executor, then emit the spy event carrying whatever
    launches the run noted. Without listeners this is just ``run()`` --
    note_launch collectors only exist while a spy is attached. ``abft``
    is the guard mode stamped on the event: the caller passes the policy's
    mode only for the dispatch the online wrap actually protects."""
    if not _LISTENERS:
        return run()
    notes: list = []
    fault_notes: list = []
    _LAUNCH_NOTES.append(notes)
    _FAULT_NOTES.append(fault_notes)
    try:
        out = run()
    finally:
        _FAULT_NOTES.pop()
        _LAUNCH_NOTES.pop()
        _notify(entry, kind, executor, shape, policy.split, policy.quant,
                tuple(notes), abft, tuple(fault_notes))
    return out


@contextlib.contextmanager
def record_dispatches():
    """Collect DispatchEvents for every routing decision in the scope --
    including per-shard re-dispatch inside the shard_map executor."""
    log: list[DispatchEvent] = []
    _LISTENERS.append(log.append)
    try:
        yield log
    finally:
        _LISTENERS.remove(log.append)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------
#
# An executor is ``fn(entry, kind, a, b, policy) -> array``. The dispatcher
# hands kernel executors 2-D operands (N-d lhs already collapsed); only
# "dense-xla" may receive the original N-d lhs for the "mm" entry (its
# dot_general contracts the trailing dim without a reshape, which matters
# under GSPMD).

_EXECUTORS: dict = {}
# name -> the tuple of GemmPolicy.reduce modes the executor implements for
# the "mmt" entry (its *reduce contract*). Selection refuses to hand a
# pinned executor an mmt dispatch whose scope asks a reduce mode outside
# the contract -- the caller's layout request must fail loudly, not be
# silently rewritten (see _select_executor).
_EXECUTOR_CONTRACTS: dict = {}


def register_executor(name: str, fn, *, reduce: tuple[str, ...] | None = None,
                      overwrite: bool = False):
    """Register a backend. Returns ``fn`` (usable as a decorator factory).

    ``reduce`` declares the executor's reduce contract: the
    ``GemmPolicy.reduce`` modes it implements for ``tsmm_t`` dispatch
    (e.g. ``("psum", "none")``). ``None`` -- the back-compat default --
    declares all modes, which is right for executors that never touch a
    collective (dense, single-chip kernels: every reduce mode degenerates
    to the same single-shard product). New executors in this repo must
    declare explicitly; ``analysis/lint.py`` rule RA004 enforces it.
    """
    if name in _EXECUTORS and not overwrite:
        raise ValueError(f"executor {name!r} already registered "
                         "(pass overwrite=True to replace)")
    if reduce is not None:
        bad = [r for r in reduce if r not in _REDUCE_MODES]
        if bad:
            raise ValueError(
                f"executor {name!r} declares unknown reduce modes {bad}: "
                f"valid values are {', '.join(_REDUCE_MODES)}")
    _EXECUTORS[name] = fn
    _EXECUTOR_CONTRACTS[name] = (tuple(_REDUCE_MODES) if reduce is None
                                 else tuple(reduce))
    return fn


def unregister_executor(name: str) -> None:
    """Remove a registered backend (built-ins included -- caveat emptor)."""
    _EXECUTORS.pop(name, None)
    _EXECUTOR_CONTRACTS.pop(name, None)


def executors() -> dict:
    """Snapshot of the registry (name -> executor)."""
    return dict(_EXECUTORS)


def executor_reduce_contract(name: str) -> tuple[str, ...]:
    """The reduce modes executor ``name`` declared at registration."""
    if name not in _EXECUTOR_CONTRACTS:
        raise ValueError(f"executor {name!r} is not registered")
    return _EXECUTOR_CONTRACTS[name]


def _exec_dense_xla(entry, kind, a, b, p):
    del kind, p
    if entry == "mm":
        out = lax.dot_general(a, b, (((a.ndim - 1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    else:
        out = lax.dot_general(a, b, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return out.astype(a.dtype)


def _exec_pallas(entry, kind, a, b, p):
    if kind == "tsm2r":
        return ops.tsm2r(a, b, policy=p)
    if kind == "tsm2l":
        return ops.tsm2l(a, b, policy=p)
    if kind == "tsmt":
        return ops.tsmt(a, b, policy=p)
    return _exec_dense_xla(entry, kind, a, b, p)


def _exec_interpret(entry, kind, a, b, p):
    return _exec_pallas(entry, kind, a, b,
                        dataclasses.replace(p, interpret=True))


def derive_dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel axes of ``mesh``, derived from its axis *names*.

    Rules, in order (mesh axis order is preserved in the result):

    1. axes named by the DP convention (``DP_AXIS_NAMES`` plus
       "dp"/"batch"/"replica(s)") are DP when any is present;
    2. otherwise every axis whose name does not hint model/pipeline
       parallelism ("model", "tensor", "tp", "expert", "pipe", "stage",
       "seq", ...) counts as DP -- including a single-axis mesh with a
       novel name, which is pure DP.

    A model-named axis is NEVER derived as DP, even alone: a pure
    tensor-parallel ``("model",)`` mesh keeps the dense fallback (GSPMD
    partitions the dense dot along the model axis correctly; sharding the
    batch over it would be a silently wrong layout).

    Works on Mesh and AbstractMesh (only ``axis_names`` is read). May
    return () -- e.g. a pure ("model", "pipe") mesh has no DP axes, and
    the dispatcher then falls back to dense exactly like the old guard.
    """
    names = tuple(mesh.axis_names)
    conv = tuple(a for a in names if a in _DP_NAME_HINTS)
    if conv:
        return conv
    return tuple(a for a in names if a not in _MODEL_NAME_HINTS)


def _dp_axes(mesh, p: GemmPolicy) -> tuple[str, ...]:
    if p.dp_axes is not None:
        return tuple(a for a in p.dp_axes if a in mesh.axis_names)
    return derive_dp_axes(mesh)


def _axes_size(mesh, axes) -> int:
    sizes = compat.mesh_axis_sizes(mesh)
    size = 1
    for a in axes:
        size *= sizes[a]
    return size


def _shard_map_env(p: GemmPolicy):
    """(mesh, dp axes, inner per-shard policy) for the shard_map executors.

    The inner policy dispatches on local shapes (``shard_map="local"``)
    and drops the executor pin so per-shard re-dispatch cannot recurse.
    """
    mesh = compat.get_context_mesh()
    if mesh is None:
        raise RuntimeError("shard_map executor requires an active "
                           "`with mesh:` scope")
    dp = _dp_axes(mesh, p)
    if not dp:
        raise RuntimeError(
            f"shard_map executor found no data-parallel axes on mesh "
            f"{mesh.axis_names} (policy dp_axes={p.dp_axes}; derived axes "
            f"follow tsmm.derive_dp_axes)")
    inner = dataclasses.replace(p, shard_map="local", executor=None)
    return mesh, dp, inner


def _exec_shard_map(entry, kind, a, b, p):
    """Per-shard dispatch over the DP axes of the context mesh.

    ``mm``: the tall dim shards, B replicates; each shard re-enters the
    dispatcher on its local (still tall-skinny) shape. ``mmt``: both
    operands shard over the tall reduction; per-shard partial products
    combine per ``p.reduce`` -- psum'd to a replicated output (default),
    or returned as stacked partials (``reduce="none"``: global output is
    (shards * a, b), the caller owns the reduction). The scatter variant
    lives in its own executor (``shard_map-scatter``).
    """
    del kind
    mesh, dp, inner = _shard_map_env(p)
    if entry == "mm":
        f = compat.shard_map(
            lambda a_s, b_s: tsmm(a_s, b_s, policy=inner),
            mesh=mesh,
            in_specs=(PartitionSpec(dp, None), PartitionSpec(None, None)),
            out_specs=PartitionSpec(dp, None))
        return f(a, b)
    if p.reduce == "psum_scatter":
        # Auto-selection never lands here with a scatter scope; only an
        # explicit executor="shard_map" pin can. Refuse rather than psum:
        # the caller asked for a row-sharded layout and must not silently
        # get a replicated one.
        raise RuntimeError(
            "GemmPolicy pins executor='shard_map' but reduce="
            "'psum_scatter': the sharded-output layout lives on the "
            "'shard_map-scatter' executor -- pin that instead, or drop "
            "the pin and let selection match the collective")
    if p.reduce == "none":
        f = compat.shard_map(
            lambda x_s, y_s: tsmm_t(x_s, y_s, policy=inner),
            mesh=mesh,
            in_specs=(PartitionSpec(dp, None), PartitionSpec(dp, None)),
            out_specs=PartitionSpec(dp, None))
        return f(a, b)
    f = compat.shard_map(
        lambda x_s, y_s: lax.psum(tsmm_t(x_s, y_s, policy=inner), dp),
        mesh=mesh,
        in_specs=(PartitionSpec(dp, None), PartitionSpec(dp, None)),
        out_specs=PartitionSpec(None, None))
    return f(a, b)


def _exec_shard_map_scatter(entry, kind, a, b, p):
    """Sharded-output ``tsmm_t``: per-shard partials reduce-scatter over
    the DP axes, so the global (a, b) product comes back row-sharded
    instead of replicated -- same values as the psum path, minus the
    all-gather half of the all-reduce the consumer was about to undo.
    ``mm`` has no cross-shard reduction to scatter, so this executor is
    mmt-only (pinning it via ``GemmPolicy.executor`` around a ``tsmm``
    call raises).
    """
    del kind
    if entry != "mmt":
        raise RuntimeError(
            "the shard_map-scatter executor only applies to tsmm_t (its "
            "output is the cross-shard reduction being scattered); tsmm "
            "has nothing to scatter -- use the shard_map executor")
    if p.reduce != "psum_scatter":
        # Only reachable via an explicit executor pin (selection matches
        # executors to the collective): a psum/none scope pinned onto the
        # scatter executor would silently change the output layout (or,
        # for "none", the shape) the caller's reduce= asked for.
        raise RuntimeError(
            f"GemmPolicy pins executor='shard_map-scatter' but reduce="
            f"{p.reduce!r}: the scatter executor implements exactly "
            "reduce='psum_scatter' -- set that, or drop the pin")
    mesh, dp, inner = _shard_map_env(p)
    shards = _axes_size(mesh, dp)
    if a.shape[1] % shards != 0:
        raise RuntimeError(
            f"psum_scatter output rows ({a.shape[1]}) do not divide the "
            f"{shards} shards of dp axes {dp}; auto-selection falls back "
            "to dense for this shape -- only an explicit executor pin "
            "reaches this error")
    f = compat.shard_map(
        lambda x_s, y_s: compat.psum_scatter(
            tsmm_t(x_s, y_s, policy=inner), dp),
        mesh=mesh,
        in_specs=(PartitionSpec(dp, None), PartitionSpec(dp, None)),
        out_specs=PartitionSpec(dp, None))
    return f(a, b)


# Single-chip executors implement every reduce mode trivially (one shard:
# psum == psum_scatter == none); the shard_map pair splits the collective
# modes between them -- that split is exactly what the contracts encode.
register_executor("dense-xla", _exec_dense_xla,
                  reduce=("psum", "psum_scatter", "none"))
register_executor("pallas-tpu", _exec_pallas,
                  reduce=("psum", "psum_scatter", "none"))
register_executor("interpret", _exec_interpret,
                  reduce=("psum", "psum_scatter", "none"))
register_executor("shard_map", _exec_shard_map, reduce=("psum", "none"))
register_executor("shard_map-scatter", _exec_shard_map_scatter,
                  reduce=("psum_scatter",))


# ---------------------------------------------------------------------------
# Executor selection
# ---------------------------------------------------------------------------

def _select_executor(entry: str, kind: str, m_tall: int, d1: int, d2: int,
                     p: GemmPolicy, forced: bool) -> str:
    if p.executor is not None:
        if p.executor not in _EXECUTORS:
            raise ValueError(
                f"GemmPolicy.executor {p.executor!r} is not registered: "
                f"known executors are {sorted(_EXECUTORS)}")
        if entry == "mmt":
            # Enforce the executor's declared reduce contract at selection
            # time (mmt only: mm shards never reduce, so every contract is
            # vacuously satisfied there). A pinned executor must refuse a
            # collective outside its contract rather than silently change
            # the output layout the scope's reduce= asked for. The executor
            # bodies keep their own guards as defense in depth.
            contract = _EXECUTOR_CONTRACTS.get(p.executor,
                                               tuple(_REDUCE_MODES))
            if p.reduce not in contract:
                compatible = sorted(n for n, c in _EXECUTOR_CONTRACTS.items()
                                    if p.reduce in c)
                raise RuntimeError(
                    f"GemmPolicy pins executor={p.executor!r}, whose "
                    f"declared reduce contract is {contract}, but the scope "
                    f"asks reduce={p.reduce!r}: a pinned executor must not "
                    "silently change the output layout the collective asked "
                    f"for. Executors declaring {p.reduce!r}: {compatible} "
                    "-- pin one of those, or drop the pin and let selection "
                    "match the collective.")
        return p.executor
    if kind == "dense":
        return "dense-xla"
    mesh = compat.get_context_mesh()
    if (mesh is not None and mesh.size > 1 and not forced
            and p.shard_map != "local"):
        # pallas_call has no GSPMD partitioning rule: under a multi-chip
        # mesh the kernels only run per-shard (shard_map) or not at all.
        # A forced kind or a shard_map="local" scope bypasses this branch
        # -- call sites inside their own shard_map manage partitioning
        # themselves (the shard_map executor's bodies do exactly that).
        if p.shard_map == "never":
            return "dense-xla"
        dp = _dp_axes(mesh, p)
        shards = _axes_size(mesh, dp) if dp else 0
        ok = bool(dp) and m_tall % shards == 0
        if ok:
            local = (classify_gemm(m_tall // shards, d1, d2, p)
                     if entry == "mm"
                     else classify_gemm_t(m_tall // shards, d1, d2, p))
            ok = local != "dense"
        scatter = entry == "mmt" and p.reduce == "psum_scatter"
        if ok and scatter:
            # The scatter dim is the OUTPUT's leading dim (d1, the rows of
            # X^T Y); when it doesn't tile over the shards the sharded
            # output cannot exist -- dense fallback, not a silent psum
            # (callers asking for sharded layout must not silently get a
            # replicated one).
            ok = d1 % shards == 0
        if ok:
            return "shard_map-scatter" if scatter else "shard_map"
        if p.shard_map == "require":
            raise RuntimeError(
                f"GemmPolicy(shard_map='require') but shape "
                f"({m_tall}, {d1}, {d2}) cannot shard over dp axes "
                f"{dp or '(none)'} of mesh "
                f"{compat.mesh_axis_sizes(mesh)}"
                + (" with reduce='psum_scatter'" if scatter else ""))
        return "dense-xla"
    if p.interpret:
        return "interpret"
    return "pallas-tpu"


def _forced_kind(entry: str, mode: str | None, force: str | None,
                 p: GemmPolicy) -> str | None:
    """Resolve per-call mode/force plus the policy mode into a pinned kind
    (or None for auto). Per-call values are validated strictly; a policy
    mode pinning the *other* entry's kind degrades to auto here so VJP
    re-dispatch under a force-kind scope stays shape-correct."""
    valid = _MM_KINDS if entry == "mm" else _MMT_KINDS
    if mode is not None and force is not None and mode != force:
        raise ValueError("pass only one of mode= / force= (force is the "
                         "deprecated alias)")
    req = mode if mode is not None else force
    if req is not None:
        if req not in valid:
            raise ValueError(
                f"unknown kind {req!r} for {'tsmm' if entry == 'mm' else 'tsmm_t'}: "
                f"valid kinds are {', '.join(valid)}")
        return None if req == "auto" else req
    if p.mode != "auto" and p.mode in valid:
        return p.mode
    return None


def _resolve_policy(policy_: GemmPolicy | None,
                    interpret: bool | None) -> GemmPolicy:
    p = policy_ if policy_ is not None else current_policy()
    if interpret is not None and interpret != p.interpret:
        p = dataclasses.replace(p, interpret=interpret)
    return p


# ---------------------------------------------------------------------------
# Online ABFT (GemmPolicy.abft): checksum wrap around the kernel dispatches
# ---------------------------------------------------------------------------

_ABFT_KINDS = ("tsm2r", "tsm2l", "tsmt")
# The OUTER shard_map dispatch is not wrapped: its per-shard re-dispatch
# inherits abft through _shard_map_env's inner policy, so every shard
# verifies/corrects its local GEMM (a global checksum would need its own
# cross-shard collective and would break the reduce="none" stacked layout).
_ABFT_SKIP_EXECUTORS = ("shard_map", "shard_map-scatter")


def _abft_wraps(kind: str, executor: str, p: GemmPolicy) -> bool:
    """Does the online checksum guard wrap this dispatch?"""
    return (p.abft != "none" and kind in _ABFT_KINDS
            and executor not in _ABFT_SKIP_EXECUTORS)


def _abft_guard(entry: str, x, y, out, p: GemmPolicy):
    """Huang-Abraham checksum verify/correct for one protected dispatch.

    Computes the output's weighted column checksums two ways -- directly
    from ``out``, and by pushing the checksum vector through the operands
    (linearity: ``e^T (A B) == (e^T A) B``) -- and hands both to
    ``ft.abft.locate_and_correct``. All checksum GEMMs re-enter this
    dispatcher under a neutralized policy (``abft="none"`` so the wrap
    cannot recurse, f32 ``quant="none"`` operands so the reference is
    exact, executor pin and shape-specific split pin stripped so the
    checksum shapes classify for themselves) -- so the encode itself runs
    on the paper's kernels, which is the whole point of online ABFT at
    tall-skinny shapes. Operands/outputs pass through ``stop_gradient``:
    the guard adds no backward cost, and on a clean (fault-free) run the
    returned value is exactly ``out`` -- bit-identical, gradient-identical.

    ``entry="mm"`` expects the collapsed 2-D views: x=(m, k), y=(k, n),
    out=(m, n); checksum rows = m, reduction = k. ``entry="mmt"``:
    x=(m, a), y=(m, b), out=(a, b); checksum rows = a, reduction = m.
    """
    from repro.ft import abft as _abft  # deferred: ft.abft imports tsmm

    pc = dataclasses.replace(
        p, abft="none", mode="auto", executor=None, quant="none",
        split="auto" if isinstance(p.split, int) else p.split)
    xs = lax.stop_gradient(x).astype(jnp.float32)
    ys = lax.stop_gradient(y).astype(jnp.float32)
    os_ = lax.stop_gradient(out).astype(jnp.float32)
    ref_row = None
    if entry == "mm":
        rows, red = x.shape[0], x.shape[1]
        e = _abft.checksum_weights(rows)
        u = tsmm_t(xs, e, policy=pc)               # (k, s) = A^T e
        c_ref = tsmm_t(ys, u, policy=pc)           # (n, s) = B^T (A^T e)
        if p.abft == "correct":
            # Dense recompute of ONE localized output row -- the snap
            # reference accurate at the value's own scale (see
            # ft.abft.locate_and_correct); a (1, k) @ (k, n) dot, so its
            # cost is a rounding error on the wrap itself.
            def ref_row(i):
                r = lax.dynamic_slice_in_dim(xs, i, 1, axis=0)
                return lax.dot_general(
                    r, ys, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)[0]
    else:
        rows, red = out.shape[0], x.shape[0]
        e = _abft.checksum_weights(rows)
        v = tsmm(xs, e, policy=pc)                 # (m, s) = X e
        c_ref = tsmm_t(v, ys, policy=pc).T         # (b, s) = ((X e)^T Y)^T
        if p.abft == "correct":
            def ref_row(i):
                col = lax.dynamic_slice_in_dim(xs, i, 1, axis=1)
                return lax.dot_general(
                    col, ys, (((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)[0]
    c_out = tsmm_t(os_, e, policy=pc)              # (cols, s) = out^T e
    return _abft.locate_and_correct(
        out, c_out, c_ref, rows=rows, reduction=red, mode=p.abft,
        eps=_abft.tolerance_eps(out.dtype, p.quant), ref_row=ref_row)


# ---------------------------------------------------------------------------
# Public entries
# ---------------------------------------------------------------------------

def tsmm(a: jnp.ndarray, b: jnp.ndarray, *, mode: str | None = None,
         policy: GemmPolicy | None = None, interpret: bool | None = None,
         force: str | None = None) -> jnp.ndarray:
    """``A[..., m, k] @ B[k, n]`` via the best path for the shape.

    Leading dims of ``a`` collapse into the tall dim for kernel dispatch
    (classification sees ``prod(a.shape[:-1])``); the dense path contracts
    the trailing dim in place, reshape-free. Differentiable. ``mode``
    overrides classification per call ("auto"/"dense"/"tsm2r"/"tsm2l";
    unknown kinds raise); ``force`` and ``interpret`` are deprecated
    aliases for ``mode`` and the policy's interpret field.
    """
    p = _resolve_policy(policy, interpret)
    if a.ndim < 2 or b.ndim != 2:
        raise ValueError(
            f"tsmm expects a (..., m, k) lhs and a (k, n) rhs; got "
            f"{a.shape} @ {b.shape}")
    k = a.shape[-1]
    if b.shape[0] != k:
        raise ValueError(f"tsmm contraction mismatch: {a.shape} @ {b.shape}")
    n = b.shape[1]
    m_tall = math.prod(a.shape[:-1])
    forced = _forced_kind("mm", mode, force, p)
    kind = forced if forced is not None else classify_gemm(m_tall, k, n, p)
    name = _select_executor("mm", kind, m_tall, k, n, p, forced is not None)

    def run():
        ex = _EXECUTORS[name]
        if a.ndim > 2 and name != "dense-xla":
            out = _run_executor(ex, "mm", kind, a.reshape(m_tall, k), b, p)
            return out.reshape(*a.shape[:-1], n)
        return _run_executor(ex, "mm", kind, a, b, p)

    guard = _abft_wraps(kind, name, p)
    out = _dispatch("mm", kind, name, (m_tall, k, n), p, run,
                    abft=p.abft if guard else "none")
    if guard:
        a2 = a.reshape(m_tall, k) if a.ndim > 2 else a
        o2 = out.reshape(m_tall, n) if a.ndim > 2 else out
        o2 = _abft_guard("mm", a2, b, o2, p)
        out = o2.reshape(*a.shape[:-1], n) if a.ndim > 2 else o2
    return out


def tsmm_t(x: jnp.ndarray, y: jnp.ndarray, *, mode: str | None = None,
           policy: GemmPolicy | None = None, interpret: bool | None = None,
           force: str | None = None) -> jnp.ndarray:
    """``X[..., m, a]^T @ Y[..., m, b] -> (a, b)`` via TSMT when the
    reduction is huge and a, b small-ish.

    Leading dims (shared by both operands) collapse into the reduction, so
    batched cotangents reduce in one pass. Differentiable. ``mode`` accepts
    "auto"/"dense"/"tsmt" (unknown kinds raise).
    """
    p = _resolve_policy(policy, interpret)
    if x.ndim < 2 or x.ndim != y.ndim or x.shape[:-1] != y.shape[:-1]:
        raise ValueError(
            f"tsmm_t expects (..., m, a) and (..., m, b) with identical "
            f"leading dims; got {x.shape} and {y.shape}")
    a_dim, b_dim = x.shape[-1], y.shape[-1]
    m_tall = math.prod(x.shape[:-1])
    if x.ndim > 2:
        x = x.reshape(m_tall, a_dim)
        y = y.reshape(m_tall, b_dim)
    forced = _forced_kind("mmt", mode, force, p)
    kind = (forced if forced is not None
            else classify_gemm_t(m_tall, a_dim, b_dim, p))
    name = _select_executor("mmt", kind, m_tall, a_dim, b_dim, p,
                            forced is not None)
    guard = _abft_wraps(kind, name, p)
    out = _dispatch("mmt", kind, name, (m_tall, a_dim, b_dim), p,
                    lambda: _run_executor(_EXECUTORS[name], "mmt", kind,
                                          x, y, p),
                    abft=p.abft if guard else "none")
    if guard:
        out = _abft_guard("mmt", x, y, out, p)
    return out


def bound_class(m: int, k: int, n: int, dtype=jnp.bfloat16,
                policy: GemmPolicy | None = None) -> perf_model.Bound:
    p = policy if policy is not None else current_policy()
    return perf_model.classify(m, k, n, p.spec, dtype)
