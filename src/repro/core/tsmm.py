"""Shape-dispatched tall-and-skinny matmul: the framework's public GEMM entry.

``tsmm(a, b)`` inspects shapes against the perf model (paper Section 3.1.8's
bound classifier) and routes to:

* TSM2R  when m ~ k >> n (skinny right operand, memory-bound stream of A),
* TSM2L  when m >> k ~ n (tiny contraction, latency-regime),
* XLA ``dot_general`` otherwise (regular shapes belong on the stock MXU
  path -- the paper's observation that cuBLAS already wins there).

``tsmm_t(x, y)`` is the transposed entry (X^T Y over a huge m).

Dispatch is static (shapes are trace-time constants under jit), so choosing
a path never introduces control flow into the compiled graph.

Both entries are differentiable: the ops they dispatch to carry custom_vjp
rules whose backwards re-enter this dispatcher (the VJP of one tall-skinny
class lands in another), and the dense fallback is a plain ``dot_general``.
``REPRO_TSMM=off`` (also ``0``/``false``) forces every call onto the dense
path -- the A/B escape hatch for benchmarking the kernels against stock XLA
without touching call sites.
"""

from __future__ import annotations

import os

import jax.numpy as jnp
from jax import lax

from repro.core import perf_model
from repro.kernels import ops

# A dim is "skinny" when this much smaller than its partner.
SKINNY_RATIO = 16
# Largest skinny dim we route to the custom kernels (past this the MXU
# path's compute-bound efficiency beats the streaming formulation).
MAX_SKINNY = 256
# Smallest tall dim worth a custom kernel launch.
MIN_TALL = 2048


def enabled() -> bool:
    """False when REPRO_TSMM=off|0|false: every call takes the dense path.

    Read at trace time, NOT at execution time: a jitted caller bakes the
    choice into its cache entry, so flipping the env var does not affect
    already-compiled functions. Each A/B arm needs a fresh process or a
    ``jax.clear_caches()`` between runs.
    """
    return os.environ.get("REPRO_TSMM", "on").lower() not in ("off", "0", "false")


def _spmd_mesh_active() -> bool:
    """True inside a ``with mesh:`` scope spanning more than one device.

    The Mosaic ``pallas_call`` custom call has no GSPMD partitioning rule,
    so routing a global-jit SPMD computation into the kernels would at
    best replicate the streamed operand per chip. Until a shard_map
    wrapper lands (ROADMAP open item), kernel dispatch under a multi-chip
    mesh context defers to the dense path, which GSPMD partitions fine.
    ``force=`` still overrides (used by shard_map call sites that manage
    their own partitioning).
    """
    try:
        from jax._src import mesh as _mesh_mod
        m = _mesh_mod.thread_resources.env.physical_mesh
        return bool(m.axis_names) and m.size > 1
    except Exception:
        return False


def classify_gemm(m: int, k: int, n: int) -> str:
    """Return one of 'tsm2r' | 'tsm2l' | 'dense'."""
    if m >= MIN_TALL and n <= MAX_SKINNY and m >= SKINNY_RATIO * n:
        if k <= MAX_SKINNY:          # m >> k ~ n: tiny contraction
            return "tsm2l"
        if k >= SKINNY_RATIO * n:    # m ~ k >> n
            return "tsm2r"
    return "dense"


def classify_gemm_t(m: int, a_dim: int, b_dim: int) -> str:
    """Transposed-entry classifier: 'tsmt' | 'dense' for X[m,a]^T Y[m,b]."""
    if (m >= MIN_TALL and b_dim <= 512
            and m >= SKINNY_RATIO * max(a_dim, b_dim) // 4):
        return "tsmt"
    return "dense"


def tsmm(a: jnp.ndarray, b: jnp.ndarray, *, interpret: bool | None = None,
         force: str | None = None) -> jnp.ndarray:
    """A[m,k] @ B[k,n] via the best path for the shape. Differentiable."""
    m, k = a.shape
    n = b.shape[1]
    kind = force or (classify_gemm(m, k, n)
                     if enabled() and not _spmd_mesh_active() else "dense")
    if kind == "tsm2r":
        return ops.tsm2r(a, b, interpret=interpret)
    if kind == "tsm2l":
        return ops.tsm2l(a, b, interpret=interpret)
    return lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32).astype(a.dtype)


def tsmm_t(x: jnp.ndarray, y: jnp.ndarray, *, interpret: bool | None = None,
           force: str | None = None) -> jnp.ndarray:
    """X[m,a]^T @ Y[m,b] via TSMT when m is huge and a, b small-ish.
    Differentiable."""
    m, a_dim = x.shape
    b_dim = y.shape[1]
    kind = force or (classify_gemm_t(m, a_dim, b_dim)
                     if enabled() and not _spmd_mesh_active() else "dense")
    if kind == "tsmt":
        return ops.tsmt(x, y, interpret=interpret)
    return lax.dot_general(x, y, (((0,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32).astype(x.dtype)


def bound_class(m: int, k: int, n: int, dtype=jnp.bfloat16) -> perf_model.Bound:
    return perf_model.classify(m, k, n, perf_model.V5E, dtype)
