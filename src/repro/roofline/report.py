"""Assemble EXPERIMENTS.md roofline/dry-run tables from dryrun JSONs.

    PYTHONPATH=src python -m repro.roofline.report [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath):
    cells = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        d = json.load(open(f))
        cells.append(d)
    return cells


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(cells, mesh="both"):
    rows = ["| arch | shape | mesh | status | compile | mem/dev | fits 16G |",
            "|---|---|---|---|---|---|---|"]
    for d in cells:
        if mesh != "both" and d.get("mesh") != mesh:
            continue
        if d.get("status") != "ok":
            rows.append(f"| {d['arch']} | {d['shape']} | {d.get('mesh', '?')} |"
                        f" FAILED | | | |")
            continue
        mem = d["memory"]
        memgb = (f"{mem['total_bytes']/2**30:.1f} GiB"
                 if isinstance(mem, dict) and "total_bytes" in mem else "n/a")
        fits = mem.get("fits_16gb_hbm", "n/a") if isinstance(mem, dict) else "n/a"
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok "
            f"| {d['compile_s']}s | {memgb} | {fits} |")
    return "\n".join(rows)


def roofline_table(cells):
    rows = ["| arch | shape | compute | memory | collective | dominant "
            "| 6ND/HLO | coll.bytes/chip |",
            "|---|---|---|---|---|---|---|---|"]
    for d in cells:
        if d.get("status") != "ok" or d.get("mesh") != "16x16":
            continue
        r = d["roofline"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['collective_bytes']:.2e} |")
    return "\n".join(rows)


def pick_hillclimb(cells):
    """worst roofline fraction / most collective-bound / most representative."""
    singles = [d for d in cells if d.get("status") == "ok"
               and d.get("mesh") == "16x16"]

    def frac(d):  # useful fraction of the bound resource
        r = d["roofline"]
        tot = max(r["compute_s"], r["memory_s"], r["collective_s"])
        ideal = r["compute_s"] if r["dominant"] == "compute" else r["memory_s"]
        return ideal / max(tot, 1e-12)

    worst = min(singles, key=frac)
    coll = max(singles, key=lambda d: d["roofline"]["collective_s"]
               / max(d["roofline"]["memory_s"], 1e-12))
    return worst, coll


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    cells = load(args.dir)
    print("## Dry-run\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single pod, 256 chips)\n")
    print(roofline_table(cells))
    worst, coll = pick_hillclimb(cells)
    print(f"\nworst-fraction cell: {worst['arch']} x {worst['shape']}")
    print(f"most collective-bound: {coll['arch']} x {coll['shape']}")


if __name__ == "__main__":
    main()
