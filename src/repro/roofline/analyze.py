"""Roofline analysis from compiled artifacts (no TPU wall clock needed).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs  / (chips * peak_FLOP/s)
    memory     = HLO_bytes  / (chips * HBM_bw)
    collective = wire_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()`` (whole-program totals,
already per-partition under SPMD). Collective bytes are parsed from the
post-SPMD optimized HLO (``compiled.as_text()``): every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute occurrence is
converted to ring-algorithm wire bytes per device:

    all-reduce       2 * (g-1)/g * result_bytes
    all-gather           (g-1)/g * result_bytes       (result = gathered)
    reduce-scatter       (g-1)   * result_bytes       (result = shard)
    all-to-all           (g-1)/g * result_bytes
    collective-permute             result_bytes

with g = replica-group size parsed from the op. Collectives inside while
loops (layer scans, decode loops) are multiplied by the loop trip count,
recovered from the loop-condition constant (best-effort; the
cross-validation against hand-counted collectives for a 2-layer model is in
tests/test_roofline.py).
"""

from __future__ import annotations

import dataclasses
import re

V5E = {
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,       # per link; v5e: 4 links/chip usable
    "hbm_per_chip": 16 * 2**30,
}

_COLL_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "u8": 1}


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    counts: dict = dataclasses.field(default_factory=dict)
    by_kind_bytes: dict = dataclasses.field(default_factory=dict)


def _result_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _wire_bytes(kind: str, rbytes: float, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * rbytes
    if kind == "all-gather":
        return (g - 1) / g * rbytes
    if kind == "reduce-scatter":
        return (g - 1) * rbytes
    if kind == "all-to-all":
        return (g - 1) / g * rbytes
    return rbytes  # collective-permute


def _split_computations(hlo: str) -> dict[str, str]:
    """computation name -> body text."""
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$", line)
        if m is None:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(", line) \
                if line.rstrip().endswith("{") else None
        if m and line.rstrip().endswith("{"):
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name, cur_lines = m.group(1), []
        elif line.strip() == "}" and cur_name:
            comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
        elif cur_name:
            cur_lines.append(line)
    if cur_name:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


_WHILE_RE = re.compile(
    # The while operand may be a tuple-typed value with nested parens
    # ("while((s32[], f32[...]) %tuple)"), so match lazily up to the
    # "condition=/body=" attributes instead of assuming a flat "(...)"
    # operand. Attribute order varies across backends; accept both.
    r"while\(.*?\),\s*"
    r"(?:condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
    r"|body=%?([\w\.\-]+),\s*condition=%?([\w\.\-]+))")
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')


def _loop_multipliers(hlo: str, comps: dict[str, str]) -> dict[str, float]:
    """computation name -> execution multiplier from enclosing while loops."""
    mult = {name: 1.0 for name in comps}
    edges = []
    for name, body in comps.items():
        for line in body.splitlines():
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond = m.group(1) or m.group(4)
            wbody = m.group(2) or m.group(3)
            # Prefer the compiler's own trip count when annotated
            # (backend_config={"known_trip_count":{"n":...}}), else recover
            # it from the loop-condition constant.
            kt = _KNOWN_TRIP_RE.search(line)
            trip = float(kt.group(1)) if kt else _trip_count(comps.get(cond, ""))
            edges.append((name, wbody, trip))
    # propagate multipliers (loops can nest; iterate to fixpoint, few passes)
    for _ in range(8):
        changed = False
        for parent, child, trip in edges:
            want = mult.get(parent, 1.0) * trip
            if child in mult and abs(mult[child] - want) > 1e-9:
                mult[child] = want
                changed = True
        if not changed:
            break
    # calls / fusions inherit parent multiplier only through while edges;
    # other called computations keep 1.0 x their own parents -- handled by
    # the call graph pass below.
    call_re = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
    for _ in range(8):
        changed = False
        for name, body in comps.items():
            for m in call_re.finditer(body):
                child = m.group(1)
                want = mult.get(name, 1.0)
                if child in mult and mult[child] < want:
                    mult[child] = want
                    changed = True
        if not changed:
            break
    return mult


def _trip_count(cond_body: str) -> float:
    """Best-effort: the largest s32/u32 constant compared in the condition."""
    consts = [int(x) for x in
              re.findall(r"[su]32\[\]\s+constant\((\d+)\)", cond_body)]
    return float(max(consts)) if consts else 1.0


_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([\d,]*)\]")
_PARAM_SIG_RE = re.compile(r"%?([\w\.\-]+)\s*:\s*([a-z0-9]+)\[([\d,]*)\]")
# Operands may carry inline type annotations depending on the backend:
# "dot(%a, %b)" or "dot(f32[32,64]{1,0} %a, f32[64,64]{1,0} %b)". When the
# lhs annotation is present its dims are captured directly (group 3);
# otherwise the lhs name (group 4) is resolved against the symbol table.
_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^\n]*?\bdot\(\s*"
    r"(?:[a-z0-9]+\[([\d,]*)\](?:\{[\d,]*\})?\s+)?%?([\w\.\-]+),"
    r"[^\n]*?lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


def _symbols(comp_body: str, comp_header: str = "") -> dict:
    """name -> (dtype, elems) for every instruction + signature params."""
    syms = {}
    for m in _PARAM_SIG_RE.finditer(comp_header):
        syms[m.group(1)] = (m.group(2), _shape_elems(m.group(3)))
    for line in comp_body.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            syms[m.group(1)] = (m.group(2), _shape_elems(m.group(3)))
    return syms


def xla_cost_dict(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: always a flat dict.

    Across JAX versions/backends ``cost_analysis()`` returns a dict, a
    one-element list of dicts (one per partition), or raises on backends
    without an implementation. Missing keys default to 0.0 so downstream
    arithmetic never KeyErrors.
    """
    try:
        cost = compiled.cost_analysis()
    except Exception:
        cost = None
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    if not isinstance(cost, dict):
        cost = {}
    out = dict(cost)
    out.setdefault("flops", 0.0)
    out.setdefault("bytes accessed", 0.0)
    return out


def hlo_cost(hlo: str) -> dict:
    """Loop-aware FLOPs/bytes from optimized HLO text.

    ``compiled.cost_analysis()`` counts while-loop bodies ONCE -- for a
    scanned 80-layer model that under-reports by ~2 orders of magnitude
    (measured: qwen2 train 6ND/HLO = 432 before this pass). Here every
    computation's cost is multiplied by its loop trip count (propagated
    through nested whiles and call edges):

    * FLOPs: 2 * prod(result dims) * prod(lhs contracting dims) per dot.
    * Bytes: sum of (operand + result) bytes of every *top-level*
      instruction (fusion bodies are internal -- their traffic happens at
      the fusion boundary, which IS the top-level instruction).
    """
    comps = _split_computations(hlo)
    mults = _loop_multipliers(hlo, comps)
    headers = {}
    for line in hlo.splitlines():
        if line.rstrip().endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*(\([^{]*\))", line)
            if m:
                headers[m.group(1)] = m.group(2)
    # computations that are fusion bodies (called via calls=) are internal
    fusion_bodies = set()
    for body in comps.values():
        for m in re.finditer(r"calls=%?([\w\.\-]+)", body):
            fusion_bodies.add(m.group(1))

    flops = 0.0
    byts = 0.0
    for name, body in comps.items():
        k = mults.get(name, 1.0)
        syms = _symbols(body, headers.get(name, ""))
        # FLOPs from dots (fusion bodies included -- dots fused on CPU
        # still execute; multiplier inherited via call edges).
        for m in _DOT_RE.finditer(body):
            res_elems = _shape_elems(m.group(2))
            contracted = 1
            if m.group(3) is not None:
                lhs_dims = m.group(3)
            else:
                lhs_dims_m = re.search(
                    r"%" + re.escape(m.group(4)) + r"\s*=\s*[a-z0-9]+\[([\d,]*)\]",
                    body) or re.search(
                    re.escape(m.group(4)) + r"\s*:\s*[a-z0-9]+\[([\d,]*)\]",
                    headers.get(name, ""))
                lhs_dims = lhs_dims_m.group(1) if lhs_dims_m else ""
            if lhs_dims and m.group(5).strip():
                dims = [int(x) for x in lhs_dims.split(",") if x]
                for ci in (int(x) for x in m.group(5).split(",") if x):
                    if ci < len(dims):
                        contracted *= dims[ci]
            flops += 2.0 * res_elems * max(contracted, 1) * k
        if name in fusion_bodies:
            continue
        # Bytes at top level
        for line in body.splitlines():
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            res_bytes = _shape_elems(mi.group(3)) * _DTYPE_BYTES.get(mi.group(2), 4)
            op_bytes = 0
            paren = line.find("(")
            if paren > 0:
                for om in _OPERANDS_RE.finditer(line[paren:]):
                    s = syms.get(om.group(1))
                    if s:
                        op_bytes += s[1] * _DTYPE_BYTES.get(s[0], 4)
            byts += (res_bytes + op_bytes) * k
    return {"flops": flops, "bytes accessed": byts}


def parse_collectives(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    mults = _loop_multipliers(hlo, comps)
    stats = CollectiveStats()
    for name, body in comps.items():
        k = mults.get(name, 1.0)
        for m in _COLL_RE.finditer(body):
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            rb = _result_bytes(dtype, dims)
            tail = body[m.end():m.end() + 400]
            gm = _GROUPS_RE.search(tail)
            if gm:
                g = len([x for x in gm.group(1).split(",") if x.strip() != ""])
            else:
                gm2 = _GROUPS_V2_RE.search(tail)
                g = int(gm2.group(2)) if gm2 else 2
            wb = _wire_bytes(kind, rb, g) * k
            stats.wire_bytes += wb
            stats.counts[kind] = stats.counts.get(kind, 0) + 1
            stats.by_kind_bytes[kind] = stats.by_kind_bytes.get(kind, 0.0) + wb
    return stats


def roofline_terms(cost: dict, coll: CollectiveStats, n_chips: int,
                   links: int = 4, hw=V5E) -> dict:
    """cost: loop-aware hlo_cost() dict (per partition under SPMD)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / hw["peak_flops_bf16"]
    t_memory = byts / hw["hbm_bw"]
    t_coll = coll.wire_bytes / (hw["ici_bw"] * links)
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    return {
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "hlo_flops": flops, "hlo_bytes": byts,
        "collective_bytes": coll.wire_bytes,
        "collective_counts": coll.counts,
        "collective_by_kind": coll.by_kind_bytes,
    }


def model_flops(cfg, shape, n_tokens: int | None = None) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode: D = batch tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens      # forward only
    tokens = shape.global_batch       # one new token per sequence
    return 2.0 * n * tokens
