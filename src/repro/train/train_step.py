"""Training step: loss -> grad -> clip -> AdamW, with grad-accumulation
microbatching (lax.scan) and optional PowerSGD-compressed DP reduction.

The returned ``train_step`` is a pure function suitable for jit/pjit AOT
lowering (the dry-run compiles exactly this).

Fault-tolerance hooks: every step's metrics carry ``step_ok`` (loss and
grad norm both finite -- the device-side half of the launcher's
fault-or-retry decision; an ABFT NaN-poison from ``GemmPolicy.abft``
trips it just like a numeric blowup), and ``host_snapshot`` /
``restore_snapshot`` give the rollback loop a cheap last-known-good copy
of the state without a checkpoint round-trip.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.models import losses, model
from repro.optim import adamw


def make_loss_fn(cfg, z_loss: float = 1e-4, loss_chunk: int = 512,
                 param_shardings=None):
    def loss_fn(params, batch):
        if param_shardings is not None:
            # Pins the *cotangent* sharding too (wsc transposes to itself):
            # without this the layer-scan backward accumulates parameter
            # grads in a replicated while-loop carry (~34 GiB/device for a
            # 3B model -- measured, see EXPERIMENTS.md §Perf iteration 0).
            params = jax.tree.map(jax.lax.with_sharding_constraint,
                                  params, param_shardings)
        hidden, metrics = model.forward_hidden(params, cfg, batch)
        loss, lm = losses.chunked_lm_loss(model.unembed_fn(params, cfg),
                                          hidden, batch, chunk=loss_chunk,
                                          z_loss=z_loss)
        if "moe_balance_loss" in metrics:
            # balance term is diagnostic-weighted; DeepSeek-style bias
            # balancing happens outside the gradient (router_bias update).
            loss = loss + 1e-2 * metrics["moe_balance_loss"] / cfg.n_layers
        return loss, {**lm, **metrics}
    return loss_fn


def _microbatch_grads(loss_fn, params, batch, n_micro: int, acc_shardings=None,
                      mesh=None):
    """Grad accumulation via scan: peak activation memory / n_micro.

    ``acc_shardings``: param-shaped pytree of NamedSharding pinned onto the
    f32 accumulator -- without it GSPMD replicates the scan carry (12.8 GB
    for a 3B model; measured in the dry-run iteration log).

    ``mesh``: when given, the reshaped (micro, batch, ...) tensors are
    pinned to P(None, dp, ...). Without the pin GSPMD splits the data axis
    across BOTH the micro and batch dims of the reshape, so every micro
    step silently processes n_micro x the intended per-device tokens
    (measured: 65536-token layer bodies where 16384 were intended).
    """
    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        y = x.reshape(n_micro, b // n_micro, *x.shape[1:])
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.distributed.sharding import dp_axes
            spec = P(None, dp_axes(mesh), *([None] * (y.ndim - 2)))
            y = jax.lax.with_sharding_constraint(y, NamedSharding(mesh, spec))
        return y

    def pin(tree):
        if acc_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, acc_shardings)

    micro = jax.tree.map(reshape, batch)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def body(carry, mb):
        acc, _ = carry
        (loss, aux), g = grad_fn(params, mb)
        acc = pin(jax.tree.map(lambda a, b_: a + b_.astype(a.dtype), acc, g))
        return (acc, loss), aux

    zeros = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
    (gsum, last_loss), auxs = jax.lax.scan(body, (zeros, jnp.float32(0)), micro)
    grads = jax.tree.map(lambda g: g / n_micro, gsum)
    aux = jax.tree.map(lambda x: x.mean(), auxs)
    return last_loss, grads, aux


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, *, n_micro: int = 0,
                    grad_transform=None, acc_shardings=None, mesh=None,
                    opt_update_specs=None):
    """grad_transform: optional (grads, extra_state) -> (grads, extra_state,
    metrics) hook -- PowerSGD plugs in here. ``acc_shardings`` (param-shaped
    NamedSharding tree) pins both the grad-accumulator carry and the
    backward's parameter-cotangent accumulator; ``mesh`` pins the
    microbatch split to the dp axes."""
    loss_fn = make_loss_fn(cfg, param_shardings=acc_shardings)

    def train_step(state, batch):
        params, opt_state, extra = state["params"], state["opt"], state.get("extra")
        if n_micro and n_micro > 1:
            loss, grads, aux = _microbatch_grads(loss_fn, params, batch, n_micro,
                                                 acc_shardings, mesh)
        else:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        if opt_update_specs is not None:
            # ZeRO-1: slice grads onto the update shards right after the
            # backward -- XLA fuses the DP all-reduce + slice into a
            # reduce-scatter, and the f32 update math stays sharded.
            from repro.distributed.sharding import maybe_wsc_spec
            grads = jax.tree.map(maybe_wsc_spec, grads, opt_update_specs)
        gmetrics = {}
        if grad_transform is not None:
            grads, extra, gmetrics = grad_transform(grads, extra)
        params, opt_state, om = adamw.update(opt_cfg, params, grads, opt_state,
                                             update_specs=opt_update_specs)
        metrics = {"loss": loss, **aux, **om, **gmetrics}
        # Device-side step-fault flag: non-finite loss or grad norm means
        # the state transition this step produced is untrustworthy (SDC
        # NaN-poison, overflow, data damage) -- the launcher rolls back
        # instead of checkpointing it.
        metrics["step_ok"] = jnp.isfinite(loss) & jnp.isfinite(
            jnp.asarray(om.get("grad_norm", jnp.float32(0.0))))
        new_state = {"params": params, "opt": opt_state}
        if extra is not None:
            new_state["extra"] = extra
        return new_state, metrics

    return train_step


def init_train_state(key, cfg, opt_cfg: adamw.AdamWConfig, extra=None):
    params = model.init(key, cfg)
    state = {"params": params, "opt": adamw.init(opt_cfg, params)}
    if extra is not None:
        state["extra"] = extra
    return state


def host_snapshot(state):
    """Deep host-numpy copy of the train state for in-memory rollback.

    ``np.asarray`` on a jax array is a device->host copy, so the snapshot
    is immune to later donation/aliasing of the live buffers. Cheaper than
    a checkpoint (no serialization, no fsync) -- this is the first line of
    the retry ladder; the Checkpointer is the escalation."""
    return jax.tree.map(lambda x: np.array(np.asarray(x)), state)


def restore_snapshot(snapshot, like=None, device=None):
    """Rebuild device arrays from a :func:`host_snapshot`.

    ``like``: optional live state pytree whose shardings the restored
    arrays should follow (multi-device rollback); ``device``: explicit
    placement. With neither, default placement applies."""
    def put(path_x):
        return jax.device_put(path_x, device)

    if like is not None:
        return jax.tree.map(
            lambda x, l: jax.device_put(x, getattr(l, "sharding", None)),
            snapshot, like)
    return jax.tree.map(put, snapshot)
