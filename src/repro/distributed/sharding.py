"""GSPMD partition rules: param-path regex -> PartitionSpec.

Axis roles:
* ``dp``   -- batch data parallelism: ('pod','data') on the multi-pod mesh,
  ('data',) on a single pod.
* ``model``-- tensor/expert parallelism.
* FSDP    -- for huge archs (param_count > FSDP_THRESHOLD) weight matrices
  additionally shard their *input* dim over 'data' (ZeRO-3-style); the
  optimizer moments inherit param specs, so ZeRO-1 comes for free.

All rules are divisibility-guarded: a dim that doesn't divide its mesh axis
falls back to replication (e.g. hubert's vocab=504 on model=16). Specs are
right-aligned: rules describe the trailing dims; leading scan/stack axes
(layers, groups) are padded with None.

KV-cache layout: kv-head counts (8) are below the model-axis size (16), so
decode caches shard their *sequence* dim over 'model' -- sequence
parallelism for long-context decode; GSPMD turns the masked softmax over
the sharded axis into the two-pass collective combine
(distributed/collectives.py holds the explicit shard_map variant used for
§Perf comparison).
"""

from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import tsmm
from repro.kernels import compat

FSDP_THRESHOLD = 30e9


def abstract_mesh(axis_sizes, axis_names):
    """Device-free mesh for spec logic; AbstractMesh signature drifted
    across JAX versions, so construction goes through the compat layer."""
    return compat.abstract_mesh(axis_sizes, axis_names)


def dp_axes(mesh: Mesh):
    """Data-parallel axes of ``mesh``. Shares one derivation with the GEMM
    dispatcher (``tsmm.derive_dp_axes``): conventional names
    ('pod'/'data'/'dp'/'batch'/'replica') when present, otherwise any
    non-model-named axis; a single-axis mesh is always DP. Batch specs,
    PowerSGD reductions, and the shard_map executors therefore agree on
    which axes carry the batch without the ("pod", "data") names being
    hard-coded anywhere."""
    return tsmm.derive_dp_axes(mesh)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _guard(mesh: Mesh, spec: P, shape) -> P:
    """Replicate any dim that doesn't divide its assigned axis."""
    out = []
    offset = len(shape) - len(spec)
    padded = (None,) * offset + tuple(spec)
    for dim, axis in zip(shape, padded):
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def param_rules(cfg, mesh: Mesh, fsdp: bool | None = None):
    """Ordered (regex, trailing-dims PartitionSpec) rules."""
    if fsdp is None:
        fsdp = cfg.param_count() > FSDP_THRESHOLD
    d = "data" if (fsdp and "data" in mesh.axis_names) else None
    return [
        # embeddings / heads
        (r"(embed|lm_head)/table$", P("model", d)),
        (r"frame_proj/w$", P(None, "model")),
        # attention projections
        (r"attn/wq$", P(d, "model")),
        (r"attn/wk$", P(d, "model")),
        (r"attn/wv$", P(d, "model")),
        (r"attn/wo$", P("model", d)),
        (r"attn/b[qkv]$", P("model")),
        # MLA
        (r"attn/wdq$", P(d, None)),
        (r"attn/wuq$", P(None, "model")),
        (r"attn/wdkv$", P(d, None)),
        (r"attn/wukv$", P(None, "model")),
        (r"attn/wkr$", P(d, None)),
        # cross-attn image projections
        (r"kv_proj_[kv]$", P(None, "model")),
        # MoE routed experts: expert dim over model (EP). Mixtral's E=8 < 16
        # fails the divisibility guard on 'model' and falls through to
        # TP-within-expert via the d_ff dim (second rule set).
        (r"experts/w_gate$", P("model", d, None)),
        (r"experts/w_up$", P("model", d, None)),
        (r"experts/w_down$", P("model", None, d)),
        (r"router_w$", P(None, None)),
        # dense MLPs (swiglu / gelu) incl. MoE shared expert
        (r"(ffn|shared)/w_gate$", P(d, "model")),
        (r"(ffn|shared)/w_up$", P(d, "model")),
        (r"(ffn|shared)/w_down$", P("model", d)),
        (r"ffn/b_up$", P("model")),
        # Mamba2
        (r"mixer/in_proj$", P(d, "model")),
        (r"mixer/out_proj$", P("model", d)),
        (r"mixer/conv_w$", P(None, "model")),
        (r"mixer/conv_b$", P("model")),
        # RWKV6
        (r"time_mix/w[rkvg]$", P(d, "model")),
        (r"time_mix/wo$", P("model", d)),
        (r"channel_mix/wk$", P(d, "model")),
        (r"channel_mix/wv$", P("model", d)),
        (r"channel_mix/wr$", P(d, None)),
        # default: replicate (norms, biases, gates, LoRAs, scalars)
        (r".*", P()),
    ]


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def make_param_specs(cfg, params_shape, mesh: Mesh, fsdp: bool | None = None,
                     strategy: str = "tp"):
    """params_shape: pytree of ShapeDtypeStruct (or arrays). Returns specs.

    strategy='tp'  -- tensor/expert parallelism over 'model' (+FSDP for
                      huge archs): the framework default.
    strategy='dp'  -- replicate params; batch shards over EVERY mesh axis
                      and the optimizer state is ZeRO-1 sharded over the
                      whole mesh. Right for small archs where 16-way TP
                      pays ~2 all-reduces/layer for no memory need
                      (§Perf hillclimb).
    """
    if strategy == "dp":
        return jax.tree.map(lambda _: P(), params_shape)
    rules = param_rules(cfg, mesh, fsdp)

    def assign(path, leaf):
        ps = path_str(path)
        for pat, spec in rules:
            if re.search(pat, ps):
                # Mixtral fallback: EP spec replicated by the guard on E=8
                # => TP-within-expert on d_ff instead.
                g = _guard(mesh, spec, leaf.shape)
                if (re.search(r"experts/w_(gate|up)$", ps)
                        and g[len(leaf.shape) - 3] is None):
                    g = _guard(mesh, P(None, None, "model"), leaf.shape)
                if (re.search(r"experts/w_down$", ps)
                        and g[len(leaf.shape) - 3] is None):
                    g = _guard(mesh, P(None, "model", None), leaf.shape)
                return g
        return P()

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def make_opt_specs(param_specs, *, mesh: Mesh | None = None,
                   params_shape=None, zero1: bool = False):
    """Optimizer state mirrors params; step counter replicated.

    ``zero1=True``: moments additionally shard their largest divisible dim
    over the WHOLE mesh (ZeRO-1) -- used with strategy='dp' where params
    are replicated but 8 bytes/param of moments must not be.
    """
    if not zero1:
        return {
            "step": P(),
            "moments": jax.tree.map(lambda s: {"m": s, "v": s}, param_specs,
                                    is_leaf=lambda x: isinstance(x, P)),
        }
    assert mesh is not None and params_shape is not None
    all_axes = tuple(mesh.axis_names)
    world = mesh.size

    def one(spec, shp):
        dims = list(shp.shape)
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if dims[i] % world == 0:
                out = [None] * len(dims)
                out[i] = all_axes
                return {"m": P(*out), "v": P(*out)}
        return {"m": spec, "v": spec}

    return {
        "step": P(),
        "moments": jax.tree.map(one, param_specs, params_shape,
                                is_leaf=lambda x: isinstance(x, P)),
    }


def batch_specs(cfg, mesh: Mesh, batch_shape, strategy: str = "tp"):
    """Input batch: shard leading batch dim over dp (guarded); under
    strategy='dp' the batch shards over every mesh axis."""
    dp = tuple(mesh.axis_names) if strategy == "dp" else dp_axes(mesh)

    def assign(_, leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and leaf.shape[0] % _axis_size(mesh, dp) == 0:
            spec[0] = dp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, batch_shape)


def cache_specs(cfg, mesh: Mesh, cache_shape):
    """KV caches: batch over dp; heads over model if divisible else
    sequence over model (SP); SSM states: heads over model."""
    dp = dp_axes(mesh)
    dp_n = _axis_size(mesh, dp)
    tp_n = _axis_size(mesh, "model")

    def assign(path, leaf):
        ps = path_str(path)
        shape = leaf.shape
        name = ps.rsplit("/", 1)[-1]
        spec = [None] * len(shape)
        # find the batch dim: first dim matching known layouts
        if name in ("k", "v"):           # (..., B, S, Hk, Hd)
            b_ax = len(shape) - 4
            if shape[b_ax] % dp_n == 0:
                spec[b_ax] = dp
            if shape[-2] % tp_n == 0:
                spec[-2] = "model"
            elif shape[-3] % tp_n == 0:
                spec[-3] = "model"       # sequence-parallel cache
        elif name in ("c", "kpe"):       # MLA latent: (..., B, S, D)
            b_ax = len(shape) - 3
            if shape[b_ax] % dp_n == 0:
                spec[b_ax] = dp
            if shape[-2] % tp_n == 0:
                spec[-2] = "model"       # sequence-parallel latent cache
        elif name == "ssm":              # (..., B, H, N, P)
            b_ax = len(shape) - 4
            if shape[b_ax] % dp_n == 0:
                spec[b_ax] = dp
            if shape[-3] % tp_n == 0:
                spec[-3] = "model"
        elif name == "wkv":              # (..., B, H, D, D)
            b_ax = len(shape) - 4
            if shape[b_ax] % dp_n == 0:
                spec[b_ax] = dp
            if shape[-3] % tp_n == 0:
                spec[-3] = "model"
        elif name == "conv":             # (..., B, W-1, C)
            b_ax = len(shape) - 3
            if shape[b_ax] % dp_n == 0:
                spec[b_ax] = dp
            if shape[-1] % tp_n == 0:
                spec[-1] = "model"
        elif name in ("tm_prev", "cm_prev"):  # (..., B, 1, d)
            b_ax = len(shape) - 3
            if shape[b_ax] % dp_n == 0:
                spec[b_ax] = dp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def _context_mesh():
    """The `with mesh:` context mesh, or None (abstract mesh is empty under
    plain `with mesh:` -- the compat shim reads the physical thread
    resources through the public interpreters API)."""
    return compat.get_context_mesh()


def maybe_wsc_spec(x, spec):
    """maybe_wsc with an explicit PartitionSpec."""
    return maybe_wsc(x, *tuple(spec))


def maybe_wsc(x, *spec):
    """with_sharding_constraint that (a) degrades to identity outside a
    mesh context (smoke tests / single-device runs), and (b) drops axis
    names the current mesh doesn't have (e.g. 'pod' on a single pod) and
    dims that don't divide their axis."""
    mesh = _context_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def filt(entry, dim):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in names)
            entry = kept if kept else None
        elif entry not in names:
            entry = None
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        return entry

    full = list(spec) + [None] * (x.ndim - len(spec))
    try:
        return jax.lax.with_sharding_constraint(
            x, P(*(filt(s, d) for s, d in zip(full, x.shape))))
    except Exception:
        return x


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
