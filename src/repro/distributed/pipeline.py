"""Pipeline parallelism: a GPipe schedule expressed as a single pjit program.

For >4-pod scaling the layer stack splits into S stages whose parameters
shard over a mesh axis (leading stage dim); microbatches flow through a
rotating activation buffer. Each schedule tick runs every stage in parallel
(``vmap`` over the stage dim => per-stage compute lands on that stage's
shard) and rotates the buffer one stage forward -- under GSPMD the rotation
of a stage-sharded buffer lowers to a ``collective-permute`` between
neighboring shards, exactly the point-to-point a hand-written pipeline
would issue.

Schedule: plain GPipe, M microbatches over S stages in M + S - 1 ticks
(bubble fraction (S-1)/(M+S-1)); outputs collect as microbatches drain.

This module is deliberately self-contained (works on any mesh axis or none
at all -- on one device it degenerates to a correct sequential schedule,
which is what tests/test_pipeline.py verifies against).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import maybe_wsc


def pipeline_apply(stage_fn, stage_params, microbatches, *,
                   stage_axis: str | None = "model"):
    """Run ``microbatches`` (M, B, ...) through S pipeline stages.

    ``stage_fn(params_s, x) -> x`` is one stage's computation;
    ``stage_params`` is a pytree stacked on a leading S dim (sharded over
    ``stage_axis``). Returns (M, B, ...) outputs.
    """
    s = jax.tree.leaves(stage_params)[0].shape[0]
    m = microbatches.shape[0]
    ticks = m + s - 1

    def pin(x):
        return maybe_wsc(x, stage_axis) if stage_axis else x

    buf = pin(jnp.zeros((s,) + microbatches.shape[1:], microbatches.dtype))

    def tick(carry, t):
        buf, outs = carry
        # feed: microbatch t enters stage 0 (zeros after the last one)
        feed = lax.dynamic_index_in_dim(
            microbatches, jnp.minimum(t, m - 1), keepdims=False)
        feed = jnp.where(t < m, feed, jnp.zeros_like(feed))
        buf = buf.at[0].set(feed)
        # all stages compute in parallel on their resident microbatch
        buf = pin(jax.vmap(stage_fn)(stage_params, buf))
        # drain: stage S-1's result is microbatch t-(S-1)'s output
        out_idx = t - (s - 1)
        outs = lax.cond(
            out_idx >= 0,
            lambda o: lax.dynamic_update_index_in_dim(
                o, buf[s - 1], jnp.maximum(out_idx, 0), 0),
            lambda o: o, outs)
        # rotate one stage forward (collective-permute when sharded)
        buf = pin(jnp.roll(buf, 1, axis=0))
        return (buf, outs), None

    outs0 = jnp.zeros_like(microbatches)
    (_, outs), _ = lax.scan(tick, (buf, outs0), jnp.arange(ticks))
    return outs


def split_stages(layer_params, n_stages: int):
    """Reshape (L, ...) stacked layer params into (S, L/S, ...)."""
    def one(x):
        n_layers = x.shape[0]
        assert n_layers % n_stages == 0, (n_layers, n_stages)
        return x.reshape(n_stages, n_layers // n_stages, *x.shape[1:])
    return jax.tree.map(one, layer_params)


def make_stage_fn(layer_fn):
    """Wrap a per-layer fn into a per-stage fn (scan over the stage's
    layers)."""
    def stage_fn(params_stage, x):
        def body(h, lp):
            return layer_fn(lp, h), None
        out, _ = lax.scan(body, x, params_stage)
        return out
    return stage_fn
