"""Deterministic, resumable, per-host-sharded synthetic data pipeline.

Production properties this reproduces without external storage:

* **Determinism**: batch for global step s is a pure function of
  (seed, step) -- restarts and elastic rescales replay identical data.
* **Host sharding**: each host materializes only its slice of the global
  batch (``host_index/host_count``); the global batch is the concatenation
  in host order, invariant to host count (elastic-safe).
* **Background prefetch**: a worker thread keeps ``prefetch_depth`` batches
  ready so step N+1's data is on host while step N computes (the data-side
  analogue of the kernel's DMA double-buffering).
* **Resume**: state is just the step counter; ``Checkpointer`` stores it.

The token stream is a mixture of structured generators (repeats, arithmetic
sequences, markov-ish jumps) so models have non-trivial learnable signal --
losses fall measurably within a few hundred steps (examples/train_lm.py).
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    seq_len: int = 128
    global_batch: int = 8
    vocab_size: int = 256
    host_index: int = 0
    host_count: int = 1
    prefetch_depth: int = 2
    mode: str = "tokens"       # tokens | frames
    frame_dim: int = 0
    vision_seq: int = 0
    vision_dim: int = 0


def _example(rng: np.random.Generator, cfg: DataConfig) -> np.ndarray:
    """One structured pseudo-document of seq_len+1 tokens."""
    n = cfg.seq_len + 1
    kind = rng.integers(0, 3)
    v = cfg.vocab_size
    if kind == 0:       # repeated phrase
        phrase = rng.integers(0, v, rng.integers(3, 12))
        reps = int(np.ceil(n / len(phrase)))
        return np.tile(phrase, reps)[:n]
    if kind == 1:       # arithmetic mod-vocab ramp
        start, stride = rng.integers(0, v), rng.integers(1, 7)
        return (start + stride * np.arange(n)) % v
    # bigram chain with a small per-example transition table
    table = rng.integers(0, v, (16,))
    out = np.empty(n, np.int64)
    out[0] = rng.integers(0, v)
    for i in range(1, n):
        out[i] = table[out[i - 1] % 16] if rng.random() < 0.8 else rng.integers(0, v)
    return out


def batch_for_step(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Pure function (seed, step, host) -> host-local batch."""
    assert cfg.global_batch % cfg.host_count == 0
    local = cfg.global_batch // cfg.host_count
    out_tokens = np.empty((local, cfg.seq_len), np.int32)
    out_targets = np.empty((local, cfg.seq_len), np.int32)
    extras = {}
    if cfg.mode == "frames":
        frames = np.empty((local, cfg.seq_len, cfg.frame_dim), np.float32)
    for i in range(local):
        gidx = cfg.host_index * local + i
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, gidx]))
        seq = _example(rng, cfg)
        out_tokens[i] = seq[:-1]
        out_targets[i] = seq[1:]
        if cfg.mode == "frames":
            # frame embedding stub: target class embedded + noise
            base = rng.standard_normal((cfg.vocab_size, cfg.frame_dim)).astype(np.float32)
            frames[i] = base[seq[:-1] % cfg.vocab_size] * 0.5 \
                + rng.standard_normal((cfg.seq_len, cfg.frame_dim)).astype(np.float32) * 0.1
    batch = {"tokens": out_tokens, "targets": out_targets}
    if cfg.mode == "frames":
        batch["frames"] = frames
        del batch["tokens"]
    if cfg.vision_seq:
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, 10 ** 6]))
        extras["image_embeds"] = rng.standard_normal(
            (local, cfg.vision_seq, cfg.vision_dim)).astype(np.float32)
    batch.update(extras)
    return batch


class Prefetcher:
    """Background thread producing batches in step order, restartable."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch_depth)
        self._next_step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next_step
        while not self._stop.is_set():
            batch = batch_for_step(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
