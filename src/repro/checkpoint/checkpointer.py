"""Fault-tolerant checkpointing: async, atomic, integrity-checked,
elastic-restore.

Layout (one directory per step)::

    <root>/step_000123.tmp/...      while writing
    <root>/step_000123/             after atomic rename (commit point)
        manifest.json               tree structure, shapes, dtypes, hashes
        arr_00000.npy ...           one file per leaf

Production properties:
* **Atomicity**: a checkpoint is visible iff its rename committed; a
  preempted writer leaves only a .tmp dir that restore ignores and the
  next save garbage-collects. Every payload file and the tmp dir are
  fsync'd before the rename, and the parent directory after it -- the
  commit point itself survives power loss, not just process death.
* **Async**: ``save`` snapshots to host numpy (device->host copy) and
  returns; a worker thread does the serialization/fsync -- the training
  loop overlaps step N+1's compute with step N's I/O.
* **Integrity**: per-array crc32 stored in the manifest and verified on
  restore (detects torn/corrupt files -- the ABFT module covers in-memory
  corruption of the live state).
* **Elastic restore**: arrays are saved as full (unsharded) global views,
  so restore works under ANY device count / mesh shape -- the caller
  re-shards with device_put (ft/elastic.py drives this after rescale).
* **Retention**: keep_n newest checkpoints are retained.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import zlib

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _fsync_path(path: str) -> None:
    """fsync a file OR directory (dirs need an O_RDONLY fd on Linux --
    renaming inside a dir is a *directory* mutation, and only fsyncing the
    dir makes the new entry durable)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class Checkpointer:
    def __init__(self, root: str, keep_n: int = 3, async_write: bool = True):
        self.root = root
        self.keep_n = keep_n
        self.async_write = async_write
        os.makedirs(root, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._error = None
        self._worker = None
        if async_write:
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    # -- public API ---------------------------------------------------------

    def _raise_pending(self):
        """Re-raise (and clear) a failed async write. A silently dropped
        checkpoint is the worst failure mode this class has: the loop keeps
        running, retention GCs the older good steps, and the eventual
        restore finds nothing. Both entry points the loop calls
        (``save``/``wait``) funnel through here so the error surfaces at
        the next step boundary; clearing lets the caller retry."""
        if self._error is not None:
            step, exc = self._error
            self._error = None
            raise RuntimeError(
                f"[ckpt-async] async save of step {step} failed: {exc!r}"
            ) from exc

    def save(self, step: int, tree, block: bool = False):
        """Snapshot to host and enqueue the write. Returns immediately.
        Raises if a previously enqueued save failed."""
        self._raise_pending()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]   # device -> host
        if self.async_write and not block:
            self._q.put((step, host_leaves, treedef))
        else:
            self._write(step, host_leaves, treedef)

    def wait(self):
        """Block until all queued saves are durable; raise if any failed."""
        self._q.join()
        self._raise_pending()

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self):
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, step: int | None = None, shardings=None):
        """Returns the saved pytree (host numpy). ``shardings``: optional
        pytree of jax.sharding.Sharding to device_put onto (elastic)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = []
        for i, meta in enumerate(manifest["leaves"]):
            arr = np.load(os.path.join(d, f"arr_{i:05d}.npy"))
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(
                    f"checkpoint corruption: leaf {i} crc {crc} != {meta['crc32']}")
            if arr.dtype == np.uint16 and meta["dtype"] == "bfloat16":
                arr = arr.view(jax.numpy.bfloat16.dtype)
            leaves.append(arr)
        import pickle
        with open(os.path.join(d, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, step

    def restore_latest_good(self, shardings=None):
        """Restore the newest checkpoint that passes integrity checks,
        walking backwards past damaged ones (truncated arrays, crc
        mismatches, missing files). This is the escalation path of the
        train loop's rollback/retry: a live-state fault plus a damaged
        newest checkpoint must still land on SOME consistent state."""
        errors = []
        for step in reversed(self.all_steps()):
            try:
                return self.restore(step, shardings=shardings)
            except Exception as e:
                errors.append((step, e))
        raise FileNotFoundError(
            f"[ckpt-none-good] no restorable checkpoint under {self.root}"
            + (f"; tried {[(s, repr(e)) for s, e in errors]}" if errors else "")
        )

    # -- internals ----------------------------------------------------------

    def _run(self):
        while True:
            step, leaves, treedef = self._q.get()
            try:
                self._write(step, leaves, treedef)
            except Exception as e:  # surfaces on next save()/wait()
                self._error = (step, e)
            finally:
                self._q.task_done()

    def _write(self, step, leaves, treedef):
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        metas = []
        for i, arr in enumerate(leaves):
            save_arr = arr
            dtype_name = str(arr.dtype)
            if dtype_name == "bfloat16":     # np.save can't do bf16
                save_arr = arr.view(np.uint16)
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), save_arr)
            metas.append({
                "shape": list(arr.shape), "dtype": dtype_name,
                "crc32": zlib.crc32(np.ascontiguousarray(save_arr).tobytes()),
            })
        import pickle
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            pickle.dump(treedef, f)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": metas}, f)
            f.flush()
            os.fsync(f.fileno())
        for name in os.listdir(tmp):        # payloads durable pre-commit
            if name != "manifest.json":
                fd = os.open(os.path.join(tmp, name), os.O_RDONLY)
                try:
                    os.fsync(fd)
                finally:
                    os.close(fd)
        _fsync_path(tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)               # commit point
        _fsync_path(self.root)              # make the rename itself durable
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)
        for name in os.listdir(self.root):   # orphaned tmp dirs
            if name.endswith(".tmp"):
                full = os.path.join(self.root, name)
                final = full[:-4]
                if os.path.exists(final):
                    shutil.rmtree(full, ignore_errors=True)
