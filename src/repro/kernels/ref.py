"""Pure-jnp oracles for the TSM2X kernels.

These are the ground truth every Pallas kernel is validated against
(``tests/test_kernels_*.py`` sweep shapes/dtypes with ``assert_allclose``).

We also keep jnp re-statements of the paper's optimization ladder (V0..V3,
paper Section 4.2.1) so the ablation benchmark can show *why* the final
kernel is shaped the way it is:

* V0 — inner product: each output element is an independent k-reduction
  (the paper's Algorithm 1; maximal re-loading of A in the GPU cost model).
* V1 — outer product: rank-1 update accumulation (Algorithm 2; A touched
  once per (m-row, k) element).
* V2/V3 — staging + prefetch have no pure-jnp distinction (XLA fuses), so
  the ladder continues inside the Pallas kernel (scratch accumulator =
  staging; grid pipelining = prefetch).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def tsm2r_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[m,n] = A[m,k] @ B[k,n] with f32 accumulation. m ~ k >> n."""
    return lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(a.dtype)


def tsm2l_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[m,n] = A[m,k] @ B[k,n] with f32 accumulation. m >> k ~ n."""
    return tsm2r_ref(a, b)


def tsmt_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """C[a,b] = X[m,a]^T @ Y[m,b] with f32 accumulation. m >> a, b.

    The TSMTTSM-style case (Ernst et al.) the paper cites as uncovered;
    needed by PowerSGD's second projection and ABFT verification.
    """
    return lax.dot_general(
        x, y, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Paper optimization-ladder restatements (for benchmarks/bench_ablation.py)
# ---------------------------------------------------------------------------

def tsm2r_v0_inner(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 1 (inner product): n independent matrix-vector products.

    This is the shape of the cuBLAS-workaround the paper criticises
    (disassemble the skinny matrix into vectors, do n GEMVs).
    """
    cols = [
        lax.dot_general(a, b[:, i], (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)
        for i in range(b.shape[1])
    ]
    return jnp.stack(cols, axis=1).astype(a.dtype)


def tsm2r_v1_outer(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 2 (outer product): scan of rank-1 updates over k.

    Each element of A participates exactly once, mirroring the paper's
    register-resident accumulation.
    """
    m, k = a.shape
    n = b.shape[1]

    def step(acc, ab):
        a_col, b_row = ab
        return acc + a_col[:, None].astype(jnp.float32) * b_row[None, :].astype(jnp.float32), None

    acc0 = jnp.zeros((m, n), jnp.float32)
    acc, _ = lax.scan(step, acc0, (a.T, b))
    return acc.astype(a.dtype)
