"""Int8 quantized TSM2X kernels: int8 tiles, f32 accumulate, dequant epilogue.

The paper's whole argument is that tall-and-skinny GEMM is HBM-bandwidth
bound; int8 operands cut the dominant streamed-bytes term 2-4x on exactly
that regime. This module holds both halves of the low-precision path:

* **Quantization helpers** -- per-row-block symmetric scales
  (``scale = absmax / 127``) for the tall operand, carried as a tiny
  ``(blocks, 1)`` f32 sidecar where ``blocks = m / block_m`` matches the
  kernel's resolved row blocking, plus a single per-tensor scale for the
  small operand. Zero blocks quantize with ``scale = 1`` so dequant is
  exact. ``quantize_param``/``dequantize_weights`` wrap the same scheme as
  an offline weight-compression record for ``serve/engine`` (arrays-only
  dict, so records pass through ``jax.jit`` pytrees).
* **Quantized kernel variants** of tsm2r/tsm2l/tsmt (plus split-reduction
  forms). Tiles are loaded as int8 (1 byte/elem of HBM traffic), the MXU
  contraction accumulates in int32 (exact: ``127*127*block <= 2^31`` for
  every feasible block), and the scales multiply into the f32 accumulator
  epilogue. Scale placement per kind:

  - **tsm2r**: A's scale is per m-block (grid dim ``i``), constant across
    the sequential k sweep, so both scales fold in once at the flush.
  - **tsm2l**: single-shot kernel; scales fold into the one store.
  - **tsmt**: both operands' scales vary along the *reduced* m axis, so
    each accumulate step is dequantized before ``+=`` (still f32
    accumulate, just per-step scaling).

  Split variants emit f32 partials exactly like their unquantized
  siblings, so ``kernels/reduce.py`` and the shard_map collectives are
  unchanged -- dequant happened before the partials left the kernel.

Numerics: symmetric per-block int8 bounds the element error by
``scale / 2 = absmax / 254`` per operand; the dot accumulates ~``sqrt(k)``
of it. ``tests/test_quant.py`` pins the round-trip bound exactly and the
GEMM-vs-f32-oracle error at 5% of the output absmax.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

QMAX = 127.0


# ---------------------------------------------------------------------------
# Quantization helpers (trace-safe: usable on activations under jit)
# ---------------------------------------------------------------------------


def quantize_blocks(x: jnp.ndarray, block_rows: int):
    """Symmetric int8 quantization per ``block_rows``-row band.

    Returns ``(q, scale)`` with ``q`` int8 of ``x.shape`` and ``scale`` a
    ``(m // block_rows, 1)`` f32 sidecar; ``dequant = q * scale[band]``.
    All-zero bands get ``scale = 1`` so they round-trip exactly.
    """
    m = x.shape[0]
    assert m % block_rows == 0, (m, block_rows)
    blocks = m // block_rows
    g = x.reshape((blocks, block_rows) + x.shape[1:]).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(g), axis=tuple(range(1, g.ndim)))
    scale = jnp.where(absmax > 0.0, absmax / QMAX, 1.0)
    expand = scale.reshape((blocks,) + (1,) * (g.ndim - 1))
    q = jnp.clip(jnp.round(g / expand), -QMAX, QMAX).astype(jnp.int8)
    return q.reshape(x.shape), scale[:, None]


def dequantize_blocks(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    """Inverse of ``quantize_blocks``; band size is implied by the shapes."""
    blocks = scale.shape[0]
    block_rows = q.shape[0] // blocks
    g = q.reshape((blocks, block_rows) + q.shape[1:]).astype(jnp.float32)
    out = g * scale.reshape((blocks,) + (1,) * (g.ndim - 1))
    return out.reshape(q.shape).astype(dtype)


def quantize_tensor(x: jnp.ndarray):
    """Per-tensor symmetric int8; scale returned as a ``(1, 1)`` f32 array
    (the shape the kernels' constant-index scale BlockSpec expects)."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf))
    scale = jnp.where(absmax > 0.0, absmax / QMAX, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -QMAX, QMAX).astype(jnp.int8)
    return q, scale.reshape(1, 1)


def fake_quant(x: jnp.ndarray) -> jnp.ndarray:
    """Quantize -> dequantize in ``x.dtype``. This is the honest int8 wire
    format for collectives: raw int8 psum is not sum-safe across ranks with
    different scales, so each rank dequantizes before the reduction and the
    byte saving is accounted where the transfer is priced."""
    q, scale = quantize_tensor(x)
    return (q.astype(jnp.float32) * scale[0, 0]).astype(x.dtype)


# --- offline weight records (serve path) -----------------------------------


def _is_qrec(t) -> bool:
    return isinstance(t, dict) and "q8" in t and "q8_scale" in t


def quantize_param(w: jnp.ndarray, *, block_rows: int = 256):
    """Offline per-tile record for one 2D weight: ``{"q8", "q8_scale"}``.

    Arrays-only so the record is a plain jit-safe pytree; the band size and
    original row count are recoverable from the shapes. Falls back to one
    per-tensor band when ``block_rows`` does not divide the rows.
    """
    m = w.shape[0]
    br = block_rows if block_rows and m % block_rows == 0 else m
    q, scale = quantize_blocks(w, br)
    return {"q8": q, "q8_scale": scale}


def dequantize_param(rec, dtype=jnp.float32) -> jnp.ndarray:
    return dequantize_blocks(rec["q8"], rec["q8_scale"], dtype)


def quantize_weights(params, *, block_rows: int = 256, min_size: int = 4096):
    """Quantize every large 2D floating leaf of a params pytree offline.

    Small/odd leaves (biases, norms, embeddings reshaped elsewhere) pass
    through untouched, so the result drops into the same model code.
    """

    def one(w):
        if (
            not hasattr(w, "ndim")
            or w.ndim != 2
            or w.size < min_size
            or not jnp.issubdtype(jnp.asarray(w).dtype, jnp.floating)
        ):
            return w
        return quantize_param(w, block_rows=block_rows)

    return jax.tree_util.tree_map(one, params)


def dequantize_weights(params, dtype=jnp.float32):
    """Inverse of ``quantize_weights``; non-record leaves pass through."""
    return jax.tree_util.tree_map(
        lambda t: dequantize_param(t, dtype) if _is_qrec(t) else t,
        params,
        is_leaf=_is_qrec,
    )


def has_quantized_weights(params) -> bool:
    found = []
    jax.tree_util.tree_map(
        lambda t: found.append(True) if _is_qrec(t) else None,
        params,
        is_leaf=_is_qrec,
    )
    return bool(found)


# ---------------------------------------------------------------------------
# Quantized TSM2R: C[m,n] = A @ B, A per-m-block scales, B per-tensor
# ---------------------------------------------------------------------------


def _tsm2r_q8_kernel(a_ref, b_ref, as_ref, bs_ref, o_ref, acc_ref):
    """acc[bm, n] += int32(A8[bm, bk] @ B8[bk, n]); scales fold at flush
    (A's scale is per m-block, constant across the sequential k sweep)."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.int32
    ).astype(jnp.float32)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * (as_ref[0, 0] * bs_ref[0, 0])).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("out_dtype", "block_m", "block_k", "interpret")
)
def tsm2r_q8_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    a_scale: jnp.ndarray,
    b_scale: jnp.ndarray,
    *,
    out_dtype,
    block_m: int,
    block_k: int,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Quantized TSM2R. ``a``/``b`` int8, ``a_scale`` ``(m/bm, 1)`` f32
    (one band per grid row block), ``b_scale`` ``(1, 1)`` f32."""
    if interpret is None:
        interpret = compat.auto_interpret()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and k % block_k == 0, (m, k, block_m, block_k)
    assert a_scale.shape == (m // block_m, 1), (a_scale.shape, m, block_m)
    assert b_scale.shape == (1, 1), b_scale.shape
    grid = (m // block_m, k // block_k)

    return compat.pallas_call(
        _tsm2r_q8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_k, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[compat.VMEM((block_m, n), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b, a_scale, b_scale)


def _tsm2r_q8_split_kernel(a_ref, b_ref, as_ref, bs_ref, o_ref):
    """Split slice s: f32 partial O[s][bm, n] += dequantized A8 B8. Scales
    fold per step (cheap; the partial leaves the kernel already in real
    units so the reduce tree stays quantization-blind)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += (
        jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.int32).astype(
            jnp.float32
        )
        * (as_ref[0, 0] * bs_ref[0, 0])
    )[None]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_k", "splits", "interpret")
)
def tsm2r_q8_pallas_split(
    a: jnp.ndarray,
    b: jnp.ndarray,
    a_scale: jnp.ndarray,
    b_scale: jnp.ndarray,
    *,
    block_m: int,
    block_k: int,
    splits: int,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Split-reduction quantized TSM2R: ``(splits, m, n)`` f32 partials,
    already dequantized -- sum with ``reduce.reduce_partials`` as usual."""
    if interpret is None:
        interpret = compat.auto_interpret()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and k % (splits * block_k) == 0, (
        m,
        k,
        block_m,
        block_k,
        splits,
    )
    assert a_scale.shape == (m // block_m, 1), (a_scale.shape, m, block_m)
    assert b_scale.shape == (1, 1), b_scale.shape
    steps = k // (splits * block_k)
    grid = (splits, m // block_m, steps)

    return compat.pallas_call(
        _tsm2r_q8_split_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda s, i, j: (i, s * steps + j)),
            pl.BlockSpec((block_k, n), lambda s, i, j: (s * steps + j, 0)),
            pl.BlockSpec((1, 1), lambda s, i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda s, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, n), lambda s, i, j: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((splits, m, n), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b, a_scale, b_scale)


# ---------------------------------------------------------------------------
# Quantized TSM2L: C[m,n] = A @ B with k, n tiny; single-shot per m block
# ---------------------------------------------------------------------------


def _tsm2l_q8_kernel(a_ref, b_ref, as_ref, bs_ref, o_ref):
    """O[bm, n] = (int32(A8 @ B8) * sA * sB); B window is constant."""
    o_ref[...] = (
        jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.int32).astype(
            jnp.float32
        )
        * (as_ref[0, 0] * bs_ref[0, 0])
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_m", "interpret"))
def tsm2l_q8_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    a_scale: jnp.ndarray,
    b_scale: jnp.ndarray,
    *,
    out_dtype,
    block_m: int,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Quantized TSM2L. ``a_scale`` ``(m/bm, 1)`` f32, ``b_scale``
    ``(1, 1)`` f32; B stays VMEM-resident exactly as in the f32 kernel."""
    if interpret is None:
        interpret = compat.auto_interpret()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0, (m, block_m)
    assert a_scale.shape == (m // block_m, 1), (a_scale.shape, m, block_m)
    assert b_scale.shape == (1, 1), b_scale.shape
    grid = (m // block_m,)

    return compat.pallas_call(
        _tsm2l_q8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(a, b, a_scale, b_scale)


# ---------------------------------------------------------------------------
# Quantized TSMT: C[a,b] = X^T @ Y; both scales vary along the reduced axis
# ---------------------------------------------------------------------------


def _tsmt_q8_kernel(x_ref, y_ref, xs_ref, ys_ref, o_ref, acc_ref):
    """acc[ba, b] += int32(X8^T Y8) * sX[j] * sY[j]: the m-band scales
    change every sequential step, so dequant happens before each ``+=``."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dot = jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    acc_ref[...] += dot.astype(jnp.float32) * (xs_ref[0, 0] * ys_ref[0, 0])

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("out_dtype", "block_m", "block_a", "interpret")
)
def tsmt_q8_pallas(
    x: jnp.ndarray,
    y: jnp.ndarray,
    x_scale: jnp.ndarray,
    y_scale: jnp.ndarray,
    *,
    out_dtype,
    block_m: int,
    block_a: int,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Quantized TSMT. Both operands are tall, so both carry per-m-band
    ``(m/bm, 1)`` f32 sidecars indexed by the sequential grid dim."""
    if interpret is None:
        interpret = compat.auto_interpret()
    m, a = x.shape
    m2, b = y.shape
    assert m == m2, (x.shape, y.shape)
    assert m % block_m == 0 and a % block_a == 0, (m, a, block_m, block_a)
    assert x_scale.shape == (m // block_m, 1), (x_scale.shape, m, block_m)
    assert y_scale.shape == (m // block_m, 1), (y_scale.shape, m, block_m)
    grid = (a // block_a, m // block_m)

    return compat.pallas_call(
        _tsmt_q8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_a), lambda i, j: (j, i)),
            pl.BlockSpec((block_m, b), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_a, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a, b), out_dtype),
        scratch_shapes=[compat.VMEM((block_a, b), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, y, x_scale, y_scale)


def _tsmt_q8_split_kernel(x_ref, y_ref, xs_ref, ys_ref, o_ref):
    """Split slice s: f32 partial O[s][ba, b] += dequantized X8^T Y8."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dot = jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] += (dot.astype(jnp.float32) * (xs_ref[0, 0] * ys_ref[0, 0]))[None]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_a", "splits", "interpret")
)
def tsmt_q8_pallas_split(
    x: jnp.ndarray,
    y: jnp.ndarray,
    x_scale: jnp.ndarray,
    y_scale: jnp.ndarray,
    *,
    block_m: int,
    block_a: int,
    splits: int,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Split-reduction quantized TSMT: ``(splits, a, b)`` f32 partials,
    dequantized in-kernel so the reduce/psum machinery is unchanged."""
    if interpret is None:
        interpret = compat.auto_interpret()
    m, a = x.shape
    m2, b = y.shape
    assert m == m2, (x.shape, y.shape)
    assert m % (splits * block_m) == 0 and a % block_a == 0, (
        m,
        a,
        block_m,
        block_a,
        splits,
    )
    assert x_scale.shape == (m // block_m, 1), (x_scale.shape, m, block_m)
    assert y_scale.shape == (m // block_m, 1), (y_scale.shape, m, block_m)
    steps = m // (splits * block_m)
    grid = (splits, a // block_a, steps)

    return compat.pallas_call(
        _tsmt_q8_split_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_a), lambda s, i, j: (s * steps + j, i)),
            pl.BlockSpec((block_m, b), lambda s, i, j: (s * steps + j, 0)),
            pl.BlockSpec((1, 1), lambda s, i, j: (s * steps + j, 0)),
            pl.BlockSpec((1, 1), lambda s, i, j: (s * steps + j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_a, b), lambda s, i, j: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((splits, a, b), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, y, x_scale, y_scale)
