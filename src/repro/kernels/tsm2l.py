"""TSM2L Pallas kernel: C[m,n] = A[m,k] @ B[k,n] with m >> k ~ n (both tiny).

TPU-native restatement of paper Section 3.2 (Algorithms 6/7):

The GPU problem: with k tiny, each thread's reduction is too shallow to hide
latency -> latency-bound; the fix is launching fewer, fatter threads (tcf).
The TPU analogue: with k tiny there is no reduction grid axis at all -- the
whole B (k x n, at most a few KB) is pinned in VMEM for the kernel's
lifetime, and the grid runs over m only. The tcf trade becomes the choice of
``block_m`` (rows per grid cell):

* block_m too small  -> many shallow grid steps; per-step fixed cost
  dominates (the latency-bound failure mode of the naive port, Fig. 4).
* block_m too large  -> too few steps for the pipeliner to overlap the next
  A-window DMA with current compute (and VMEM pressure).

``choose_params_tsm2l`` picks block_m from the same modeled-time argmin the
paper derives tcf from (Fig. 5's sweep is reproduced in
``benchmarks/bench_tsm2l.py``).

Opt1 vs Opt2 (sequential vs interleaved tiles): Mosaic's grid pipelining
*is* the interleaved schedule (Opt2) -- compute on tile i overlaps the DMA
of tile i+1, and there is no C re-load because the accumulator never leaves
the grid cell. Opt1 (sequential, C re-staged per tile) only exists on GPUs
because registers are per-thread; it would be strictly worse here and is
represented in benchmarks by disabling pipelining (grid=1 chunks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _tsm2l_kernel(a_ref, b_ref, o_ref):
    """One grid cell: O[bm, n] = A[bm, k] @ B[k, n]; B window is constant."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def tsm2l_pallas(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Raw pallas_call; requires m % block_m == 0.

    ``interpret=None`` auto-detects (Python bodies off-TPU). Use
    ``repro.kernels.ops.tsm2l`` for the padded/dispatched public entry;
    the ``shard_map`` executor in ``repro.core.tsmm`` handles multi-chip
    meshes by invoking that entry per shard.
    """
    if interpret is None:
        interpret = compat.auto_interpret()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m,)

    return compat.pallas_call(
        _tsm2l_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            # index_map is constant: B is fetched once and stays resident.
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(a, b)
