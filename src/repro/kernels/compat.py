"""JAX/Pallas version-compatibility layer.

Every symbol that has drifted across the JAX versions this repo must run on
is resolved here, once, at import time. Kernel/model/test code imports from
this module instead of guessing which spelling the installed JAX uses.

Shims and the version ranges they cover:

* ``CompilerParams`` -- the Mosaic compiler-params class.
  ``pltpu.TPUCompilerParams`` on jax 0.4.30 -- 0.6.x; renamed to
  ``pltpu.CompilerParams`` in 0.7. Resolution order prefers the new name.
* ``VMEM`` -- the TPU memory-space handle used for scratch shapes.
  Present as ``pltpu.VMEM`` on every covered version; on very old releases
  it lived on ``pltpu.TPUMemorySpace.VMEM`` (fallback kept for 0.4.2x).
* ``abstract_mesh(axis_sizes, axis_names)`` -- ``jax.sharding.AbstractMesh``
  construction. 0.4.3x takes one ``((name, size), ...)`` shape tuple;
  0.5+ takes ``(axis_sizes, axis_names)`` positionally. The helper accepts
  the modern calling convention and translates when needed.
* ``optimization_barrier`` -- ``jax.lax.optimization_barrier`` has no
  differentiation rule before jax 0.5.1 (jax-ml/jax#25392). On those
  versions we wrap it in a ``jax.custom_vjp`` identity whose backward
  re-applies the barrier to the cotangent, so reverse-mode keeps the same
  hoisting protection the primal asked for. On newer JAX the native
  primitive (which differentiates) is used directly.
* ``make_mesh(shape, axis_names)`` -- ``jax.make_mesh`` grew the
  ``axis_types`` kwarg (and ``jax.sharding.AxisType``) in 0.5; on 0.4.3x
  the kwarg does not exist and Auto is the only behavior. The helper
  passes explicit-Auto types only where the installed JAX has them.
* ``get_context_mesh()`` -- the ``with mesh:`` context mesh, read through
  the public ``jax.interpreters.pxla`` surface (the dispatcher must never
  import ``jax._src``). Returns None outside a mesh scope.
* ``mesh_axis_sizes(mesh)`` -- ``{axis_name: size}`` for a Mesh or
  AbstractMesh. ``mesh.shape`` is an OrderedDict on the versions covered
  but has drifted (plain dict / ``axis_sizes`` tuple) -- callers that only
  need names x sizes go through this instead of touching ``.shape``.
* ``shard_map(...)`` -- lived in ``jax.experimental.shard_map`` through
  0.5.x and moved to ``jax.shard_map`` later; ``check_rep`` was also
  renamed away. The wrapper takes the modern keyword signature and drops
  kwargs the installed JAX rejects.
* ``psum_scatter(x, axis)`` / ``all_gather(x, axis)`` -- the collective
  pair the sharded-output ``tsmm_t`` path is built on.
  ``lax.psum_scatter(..., tiled=True)`` has been stable since well before
  0.4.30, but the ``tiled`` kwarg is the part most likely to drift (it
  already changed semantics once in jax's history), so both wrappers pin
  the tiled calling convention here and fall back to an explicit
  psum+slice / concat emulation if the installed JAX rejects it.
* ``auto_interpret()`` -- the Pallas interpret-mode default: kernel bodies
  run in Python off-TPU (correctness on CPU), compile via Mosaic on TPU.

The probes are trace-time only (``jax.eval_shape``): importing this module
never compiles or executes device code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "CompilerParams",
    "VMEM",
    "abstract_mesh",
    "make_mesh",
    "optimization_barrier",
    "BARRIER_IS_DIFFERENTIABLE",
    "get_context_mesh",
    "mesh_axis_sizes",
    "shard_map",
    "psum_scatter",
    "all_gather",
    "auto_interpret",
]


def auto_interpret() -> bool:
    """Pallas interpret-mode default: Python bodies off-TPU, Mosaic on TPU."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Mosaic compiler params: pltpu.CompilerParams (new) vs TPUCompilerParams
# ---------------------------------------------------------------------------

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams", None)
if CompilerParams is None:  # pragma: no cover - ancient pallas
    raise ImportError(
        "pallas TPU backend exposes neither CompilerParams nor "
        "TPUCompilerParams; need jax >= 0.4.30")


# ---------------------------------------------------------------------------
# VMEM scratch memory space
# ---------------------------------------------------------------------------

VMEM = getattr(pltpu, "VMEM", None)
if VMEM is None:  # pragma: no cover - pre-0.4.30 spelling
    VMEM = pltpu.TPUMemorySpace.VMEM


# ---------------------------------------------------------------------------
# AbstractMesh construction
# ---------------------------------------------------------------------------

def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """``AbstractMesh((16, 16), ("data", "model"))`` on every covered JAX.

    jax >= 0.5 takes exactly this signature; 0.4.3x wants a single
    ``((name, size), ...)`` tuple instead, which raises
    ``TypeError: 'int' object is not iterable`` when handed bare sizes.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def make_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]):
    """``jax.make_mesh`` with explicit-Auto axis types where supported.

    jax >= 0.5 wants ``axis_types=(AxisType.Auto, ...)`` spelled out (the
    default flipped during the explicit-sharding rollout); 0.4.3x has
    neither the kwarg nor ``jax.sharding.AxisType`` and is always Auto.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


# ---------------------------------------------------------------------------
# Mesh-context introspection + shard_map
# ---------------------------------------------------------------------------

def _resolve_thread_resources():
    """Probe the public pxla re-export once at import. A failed probe is a
    version-drift event worth a warning, NOT silently equivalent to
    "no mesh active": the dispatcher's multi-chip guard depends on it."""
    try:
        from jax.interpreters import pxla
        pxla.thread_resources.env.physical_mesh  # full attribute path
        return pxla.thread_resources
    except Exception:  # pragma: no cover - future-JAX drift
        import warnings
        warnings.warn(
            "jax.interpreters.pxla.thread_resources is unavailable on this "
            "JAX; mesh-context detection (and the tsmm multi-chip dispatch "
            "guard) is disabled -- extend repro.kernels.compat for this "
            "version", RuntimeWarning, stacklevel=2)
        return None


_THREAD_RESOURCES = _resolve_thread_resources()


def get_context_mesh():
    """The active ``with mesh:`` context mesh, or None outside one.

    Read through ``jax.interpreters.pxla`` (public re-export) -- the
    abstract mesh is empty under a plain ``with mesh:`` scope, so the
    physical thread resources are the only reliable signal across the
    covered JAX versions.
    """
    if _THREAD_RESOURCES is None:
        return None
    m = _THREAD_RESOURCES.env.physical_mesh
    return m if m.axis_names else None


def _resolve_shard_map():
    try:
        from jax.experimental.shard_map import shard_map as f  # <= 0.5.x
        return f
    except ImportError:  # pragma: no cover - moved in newer JAX
        from jax import shard_map as f
        return f


_SHARD_MAP = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, on every covered JAX.

    ``check_rep=False`` keeps psum-producing bodies legal on 0.4.x/0.5.x;
    newer JAX renamed/removed the kwarg, so it is dropped on TypeError.
    """
    try:
        return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - post-rename JAX
        return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)


def mesh_axis_sizes(mesh) -> dict:
    """``{axis_name: size}`` for a Mesh/AbstractMesh, tolerant of the
    ``.shape`` container drifting (OrderedDict today; ``axis_sizes`` tuple
    on the explicit-sharding branches)."""
    shape = getattr(mesh, "shape", None)
    if shape is not None and hasattr(shape, "items"):
        return dict(shape)
    sizes = getattr(mesh, "axis_sizes", None)  # pragma: no cover - drift
    if sizes is not None:
        return dict(zip(mesh.axis_names, sizes))
    raise TypeError(  # pragma: no cover - future-JAX drift
        f"cannot read axis sizes off mesh {mesh!r}; extend "
        "repro.kernels.compat.mesh_axis_sizes for this JAX version")


# ---------------------------------------------------------------------------
# Collectives for sharded-output tsmm_t (psum_scatter / all_gather)
# ---------------------------------------------------------------------------

def psum_scatter(x, axis_name, *, scatter_dimension: int = 0):
    """Tiled reduce-scatter over ``axis_name`` (a name or tuple of names).

    Semantics pinned here: the *global* result equals ``lax.psum(x, axis)``
    with each shard keeping only its ``scatter_dimension`` slab -- i.e.
    ``lax.psum_scatter(..., tiled=True)``. Requires
    ``x.shape[scatter_dimension]`` divisible by the axis size (callers
    check; the tsmm dispatcher falls back to dense when it doesn't).
    """
    try:
        return jax.lax.psum_scatter(x, axis_name,
                                    scatter_dimension=scatter_dimension,
                                    tiled=True)
    except TypeError:  # pragma: no cover - tiled-kwarg drift
        summed = jax.lax.psum(x, axis_name)
        idx = _flat_axis_index(axis_name)
        size = jax.lax.psum(1, axis_name)
        slab = x.shape[scatter_dimension] // size
        return jax.lax.dynamic_slice_in_dim(summed, idx * slab, slab,
                                            axis=scatter_dimension)


def all_gather(x, axis_name, *, axis: int = 0):
    """Tiled all-gather over ``axis_name``: shards concatenate along
    ``axis`` (the inverse of :func:`psum_scatter` on the same axis)."""
    try:
        return jax.lax.all_gather(x, axis_name, axis=axis, tiled=True)
    except TypeError:  # pragma: no cover - tiled-kwarg drift
        # Untiled all_gather inserts a new leading dim of the axis size at
        # position ``axis``; tiled merges it into the next dim.
        stacked = jax.lax.all_gather(x, axis_name, axis=axis)
        merged = stacked.shape[axis] * stacked.shape[axis + 1]
        return stacked.reshape(*x.shape[:axis], merged, *x.shape[axis + 1:])


def _flat_axis_index(axis_name):
    """Row-major flat index over one axis name or a tuple of names."""
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    idx = 0
    for name in names:
        idx = idx * jax.lax.psum(1, name) + jax.lax.axis_index(name)
    return idx


# ---------------------------------------------------------------------------
# Differentiable optimization_barrier
# ---------------------------------------------------------------------------

def _probe_barrier_grad() -> bool:
    try:
        jax.eval_shape(
            jax.grad(lambda x: jax.lax.optimization_barrier(x * 1.0)),
            jax.ShapeDtypeStruct((), jnp.float32))
        return True
    except NotImplementedError:
        return False
    except Exception:
        return False


BARRIER_IS_DIFFERENTIABLE = _probe_barrier_grad()


@jax.custom_vjp
def _barrier_vjp(x):
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return _barrier_vjp(x), None


def _barrier_bwd(_, ct):
    # Barrier the cotangent too: the reverse pass wants the same
    # hoisting protection (e.g. keeping f32 upcasts loop-local) as the
    # primal that requested the barrier.
    return (jax.lax.optimization_barrier(ct),)


_barrier_vjp.defvjp(_barrier_fwd, _barrier_bwd)


def optimization_barrier(x):
    """Identity that blocks XLA hoisting; differentiable on every JAX."""
    if BARRIER_IS_DIFFERENTIABLE:
        return jax.lax.optimization_barrier(x)
    return _barrier_vjp(x)
