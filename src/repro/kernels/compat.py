"""JAX/Pallas version-compatibility layer.

Every symbol that has drifted across the JAX versions this repo must run on
is resolved here, once, at import time. Kernel/model/test code imports from
this module instead of guessing which spelling the installed JAX uses.

Shims and the version ranges they cover:

* ``CompilerParams`` -- the Mosaic compiler-params class.
  ``pltpu.TPUCompilerParams`` on jax 0.4.30 -- 0.6.x; renamed to
  ``pltpu.CompilerParams`` in 0.7. Resolution order prefers the new name.
* ``VMEM`` -- the TPU memory-space handle used for scratch shapes.
  Present as ``pltpu.VMEM`` on every covered version; on very old releases
  it lived on ``pltpu.TPUMemorySpace.VMEM`` (fallback kept for 0.4.2x).
* ``abstract_mesh(axis_sizes, axis_names)`` -- ``jax.sharding.AbstractMesh``
  construction. 0.4.3x takes one ``((name, size), ...)`` shape tuple;
  0.5+ takes ``(axis_sizes, axis_names)`` positionally. The helper accepts
  the modern calling convention and translates when needed.
* ``optimization_barrier`` -- ``jax.lax.optimization_barrier`` has no
  differentiation rule before jax 0.5.1 (jax-ml/jax#25392). On those
  versions we wrap it in a ``jax.custom_vjp`` identity whose backward
  re-applies the barrier to the cotangent, so reverse-mode keeps the same
  hoisting protection the primal asked for. On newer JAX the native
  primitive (which differentiates) is used directly.
* ``make_mesh(shape, axis_names)`` -- ``jax.make_mesh`` grew the
  ``axis_types`` kwarg (and ``jax.sharding.AxisType``) in 0.5; on 0.4.3x
  the kwarg does not exist and Auto is the only behavior. The helper
  passes explicit-Auto types only where the installed JAX has them.
* ``get_context_mesh()`` -- the ``with mesh:`` context mesh, read through
  the public ``jax.interpreters.pxla`` surface (the dispatcher must never
  import ``jax._src``). Returns None outside a mesh scope.
* ``mesh_axis_sizes(mesh)`` -- ``{axis_name: size}`` for a Mesh or
  AbstractMesh. ``mesh.shape`` is an OrderedDict on the versions covered
  but has drifted (plain dict / ``axis_sizes`` tuple) -- callers that only
  need names x sizes go through this instead of touching ``.shape``.
* ``shard_map(...)`` -- lived in ``jax.experimental.shard_map`` through
  0.5.x and moved to ``jax.shard_map`` later; ``check_rep`` was also
  renamed away. The wrapper takes the modern keyword signature and drops
  kwargs the installed JAX rejects.
* ``psum_scatter(x, axis)`` / ``all_gather(x, axis)`` -- the collective
  pair the sharded-output ``tsmm_t`` path is built on.
  ``lax.psum_scatter(..., tiled=True)`` has been stable since well before
  0.4.30, but the ``tiled`` kwarg is the part most likely to drift (it
  already changed semantics once in jax's history), so both wrappers pin
  the tiled calling convention here and fall back to an explicit
  psum+slice / concat emulation if the installed JAX rejects it.
* ``auto_interpret()`` -- the Pallas interpret-mode default: kernel bodies
  run in Python off-TPU (correctness on CPU), compile via Mosaic on TPU.
* ``pallas_call(...)`` / ``capture_launches()`` -- the launch-recording
  shim. Every in-repo kernel routes its ``pl.pallas_call`` through
  :func:`pallas_call`, which is a zero-overhead pass-through outside a
  :func:`capture_launches` scope and otherwise records a
  :class:`LaunchCapture` (grid, BlockSpec block shapes + index-map
  callables, dimension_semantics, operand/out/scratch avals, the kernel
  fn) per invocation. ``repro.analysis.kernel_verify`` drives the kernel
  entry points under ``jax.eval_shape`` inside such a scope to verify the
  grid dataflow statically -- no device, no compile.

The probes are trace-time only (``jax.eval_shape``): importing this module
never compiles or executes device code.
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "CompilerParams",
    "VMEM",
    "abstract_mesh",
    "make_mesh",
    "optimization_barrier",
    "BARRIER_IS_DIFFERENTIABLE",
    "get_context_mesh",
    "mesh_axis_sizes",
    "shard_map",
    "psum_scatter",
    "all_gather",
    "auto_interpret",
    "BlockSpecCapture",
    "LaunchCapture",
    "capture_launches",
    "pallas_call",
]


def auto_interpret() -> bool:
    """Pallas interpret-mode default: Python bodies off-TPU, Mosaic on TPU."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Mosaic compiler params: pltpu.CompilerParams (new) vs TPUCompilerParams
# ---------------------------------------------------------------------------

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams", None)
if CompilerParams is None:  # pragma: no cover - ancient pallas
    raise ImportError(
        "pallas TPU backend exposes neither CompilerParams nor "
        "TPUCompilerParams; need jax >= 0.4.30")


# ---------------------------------------------------------------------------
# VMEM scratch memory space
# ---------------------------------------------------------------------------

VMEM = getattr(pltpu, "VMEM", None)
if VMEM is None:  # pragma: no cover - pre-0.4.30 spelling
    VMEM = pltpu.TPUMemorySpace.VMEM


# ---------------------------------------------------------------------------
# AbstractMesh construction
# ---------------------------------------------------------------------------

def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """``AbstractMesh((16, 16), ("data", "model"))`` on every covered JAX.

    jax >= 0.5 takes exactly this signature; 0.4.3x wants a single
    ``((name, size), ...)`` tuple instead, which raises
    ``TypeError: 'int' object is not iterable`` when handed bare sizes.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def make_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]):
    """``jax.make_mesh`` with explicit-Auto axis types where supported.

    jax >= 0.5 wants ``axis_types=(AxisType.Auto, ...)`` spelled out (the
    default flipped during the explicit-sharding rollout); 0.4.3x has
    neither the kwarg nor ``jax.sharding.AxisType`` and is always Auto.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


# ---------------------------------------------------------------------------
# Mesh-context introspection + shard_map
# ---------------------------------------------------------------------------

def _resolve_thread_resources():
    """Probe the public pxla re-export once at import. A failed probe is a
    version-drift event worth a warning, NOT silently equivalent to
    "no mesh active": the dispatcher's multi-chip guard depends on it."""
    try:
        from jax.interpreters import pxla
        pxla.thread_resources.env.physical_mesh  # full attribute path
        return pxla.thread_resources
    except Exception:  # pragma: no cover - future-JAX drift
        import warnings
        warnings.warn(
            "jax.interpreters.pxla.thread_resources is unavailable on this "
            "JAX; mesh-context detection (and the tsmm multi-chip dispatch "
            "guard) is disabled -- extend repro.kernels.compat for this "
            "version", RuntimeWarning, stacklevel=2)
        return None


_THREAD_RESOURCES = _resolve_thread_resources()


def get_context_mesh():
    """The active ``with mesh:`` context mesh, or None outside one.

    Read through ``jax.interpreters.pxla`` (public re-export) -- the
    abstract mesh is empty under a plain ``with mesh:`` scope, so the
    physical thread resources are the only reliable signal across the
    covered JAX versions.
    """
    if _THREAD_RESOURCES is None:
        return None
    m = _THREAD_RESOURCES.env.physical_mesh
    return m if m.axis_names else None


def _resolve_shard_map():
    try:
        from jax.experimental.shard_map import shard_map as f  # <= 0.5.x
        return f
    except ImportError:  # pragma: no cover - moved in newer JAX
        from jax import shard_map as f
        return f


_SHARD_MAP = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, on every covered JAX.

    ``check_rep=False`` keeps psum-producing bodies legal on 0.4.x/0.5.x;
    newer JAX renamed/removed the kwarg, so it is dropped on TypeError.
    """
    try:
        return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - post-rename JAX
        return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)


def mesh_axis_sizes(mesh) -> dict:
    """``{axis_name: size}`` for a Mesh/AbstractMesh, tolerant of the
    ``.shape`` container drifting (OrderedDict today; ``axis_sizes`` tuple
    on the explicit-sharding branches)."""
    shape = getattr(mesh, "shape", None)
    if shape is not None and hasattr(shape, "items"):
        return dict(shape)
    sizes = getattr(mesh, "axis_sizes", None)  # pragma: no cover - drift
    if sizes is not None:
        return dict(zip(mesh.axis_names, sizes))
    raise TypeError(  # pragma: no cover - future-JAX drift
        f"cannot read axis sizes off mesh {mesh!r}; extend "
        "repro.kernels.compat.mesh_axis_sizes for this JAX version")


# ---------------------------------------------------------------------------
# Collectives for sharded-output tsmm_t (psum_scatter / all_gather)
# ---------------------------------------------------------------------------

def psum_scatter(x, axis_name, *, scatter_dimension: int = 0):
    """Tiled reduce-scatter over ``axis_name`` (a name or tuple of names).

    Semantics pinned here: the *global* result equals ``lax.psum(x, axis)``
    with each shard keeping only its ``scatter_dimension`` slab -- i.e.
    ``lax.psum_scatter(..., tiled=True)``. Requires
    ``x.shape[scatter_dimension]`` divisible by the axis size (callers
    check; the tsmm dispatcher falls back to dense when it doesn't).
    """
    try:
        return jax.lax.psum_scatter(x, axis_name,
                                    scatter_dimension=scatter_dimension,
                                    tiled=True)
    except TypeError:  # pragma: no cover - tiled-kwarg drift
        summed = jax.lax.psum(x, axis_name)
        idx = _flat_axis_index(axis_name)
        size = jax.lax.psum(1, axis_name)
        slab = x.shape[scatter_dimension] // size
        return jax.lax.dynamic_slice_in_dim(summed, idx * slab, slab,
                                            axis=scatter_dimension)


def all_gather(x, axis_name, *, axis: int = 0):
    """Tiled all-gather over ``axis_name``: shards concatenate along
    ``axis`` (the inverse of :func:`psum_scatter` on the same axis)."""
    try:
        return jax.lax.all_gather(x, axis_name, axis=axis, tiled=True)
    except TypeError:  # pragma: no cover - tiled-kwarg drift
        # Untiled all_gather inserts a new leading dim of the axis size at
        # position ``axis``; tiled merges it into the next dim.
        stacked = jax.lax.all_gather(x, axis_name, axis=axis)
        merged = stacked.shape[axis] * stacked.shape[axis + 1]
        return stacked.reshape(*x.shape[:axis], merged, *x.shape[axis + 1:])


def _flat_axis_index(axis_name):
    """Row-major flat index over one axis name or a tuple of names."""
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    idx = 0
    for name in names:
        idx = idx * jax.lax.psum(1, name) + jax.lax.axis_index(name)
    return idx


# ---------------------------------------------------------------------------
# Differentiable optimization_barrier
# ---------------------------------------------------------------------------

def _probe_barrier_grad() -> bool:
    try:
        jax.eval_shape(
            jax.grad(lambda x: jax.lax.optimization_barrier(x * 1.0)),
            jax.ShapeDtypeStruct((), jnp.float32))
        return True
    except NotImplementedError:
        return False
    except Exception:
        return False


BARRIER_IS_DIFFERENTIABLE = _probe_barrier_grad()


@jax.custom_vjp
def _barrier_vjp(x):
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return _barrier_vjp(x), None


def _barrier_bwd(_, ct):
    # Barrier the cotangent too: the reverse pass wants the same
    # hoisting protection (e.g. keeping f32 upcasts loop-local) as the
    # primal that requested the barrier.
    return (jax.lax.optimization_barrier(ct),)


_barrier_vjp.defvjp(_barrier_fwd, _barrier_bwd)


def optimization_barrier(x):
    """Identity that blocks XLA hoisting; differentiable on every JAX."""
    if BARRIER_IS_DIFFERENTIABLE:
        return jax.lax.optimization_barrier(x)
    return _barrier_vjp(x)


# ---------------------------------------------------------------------------
# Launch-recording pallas_call shim (repro.analysis.kernel_verify)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockSpecCapture:
    """One ``pl.BlockSpec`` as captured at launch-construction time.

    ``block_shape`` entries may be None (pallas' "whole dim" spelling);
    ``index_map`` is the raw Python callable, evaluable with plain ints.
    """
    block_shape: tuple
    index_map: object


@dataclasses.dataclass(frozen=True)
class LaunchCapture:
    """Everything the dataflow verifier needs about one ``pallas_call``.

    Captured when the launch is *constructed* inside a
    :func:`capture_launches` scope -- i.e. at trace time, before any
    compile -- so ``jax.eval_shape`` over a kernel entry point is enough
    to populate it. ``operands``/``out_shapes``/``scratch_shapes`` are
    ``jax.ShapeDtypeStruct``-like (``.shape``/``.dtype``); ``kernel`` is
    the Python kernel function (for AST guard inspection).
    """
    name: str
    kernel: object
    grid: tuple
    in_specs: tuple          # of BlockSpecCapture
    out_specs: tuple         # of BlockSpecCapture
    operands: tuple          # abstract values of the call's array args
    out_shapes: tuple        # ShapeDtypeStructs
    scratch_shapes: tuple    # ShapeDtypeStructs (dtype normalized)
    dimension_semantics: tuple | None
    interpret: bool


_CAPTURE_STACK: list[list] = []


@contextlib.contextmanager
def capture_launches():
    """Collect a ``LaunchCapture`` per :func:`pallas_call` in scope.

    Scopes nest; each capture lands only in the innermost collector.
    Trace-time only -- typical use wraps a ``jax.eval_shape`` of an
    (unjitted) kernel entry point.
    """
    log: list[LaunchCapture] = []
    _CAPTURE_STACK.append(log)
    try:
        yield log
    finally:
        _CAPTURE_STACK.pop()


def _capture_spec(spec) -> BlockSpecCapture:
    return BlockSpecCapture(
        block_shape=tuple(getattr(spec, "block_shape", ()) or ()),
        index_map=getattr(spec, "index_map", None),
    )


def _capture_sds(x):
    """Normalize anything shaped (MemoryRef, ShapeDtypeStruct, aval) to a
    plain ShapeDtypeStruct. Scratch MemoryRefs carry ``dtype`` as a scalar
    *class* (e.g. ``jnp.float32``) on some versions -- ``jnp.dtype``
    canonicalizes both spellings."""
    return jax.ShapeDtypeStruct(tuple(x.shape), jnp.dtype(x.dtype))


def pallas_call(kernel, *, grid, in_specs, out_specs, out_shape,
                scratch_shapes=(), compiler_params=None, interpret=False,
                **kwargs):
    """``pl.pallas_call`` pass-through that records the launch spec.

    Outside a :func:`capture_launches` scope this adds one truthiness
    check per trace. Inside one, the returned callable logs a
    :class:`LaunchCapture` each time it is invoked (so the recorded
    operand avals are the ones actually passed). The keyword-only
    signature pins the subset of the ``pallas_call`` surface the repo's
    kernels use; new kwargs flow through ``**kwargs`` untouched.
    """
    inner = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, scratch_shapes=scratch_shapes,
        compiler_params=compiler_params, interpret=interpret, **kwargs)
    if not _CAPTURE_STACK:
        return inner

    out_specs_t = out_specs if isinstance(out_specs, (tuple, list)) \
        else (out_specs,)
    out_shape_t = out_shape if isinstance(out_shape, (tuple, list)) \
        else (out_shape,)
    semantics = getattr(compiler_params, "dimension_semantics", None)

    def recorded(*operands):
        _CAPTURE_STACK[-1].append(LaunchCapture(
            name=getattr(kernel, "__name__", repr(kernel)),
            kernel=kernel,
            grid=tuple(grid),
            in_specs=tuple(_capture_spec(s) for s in in_specs),
            out_specs=tuple(_capture_spec(s) for s in out_specs_t),
            operands=tuple(_capture_sds(x) for x in operands),
            out_shapes=tuple(_capture_sds(s) for s in out_shape_t),
            scratch_shapes=tuple(_capture_sds(s) for s in scratch_shapes),
            dimension_semantics=(tuple(semantics)
                                 if semantics is not None else None),
            interpret=bool(interpret),
        ))
        return inner(*operands)

    return recorded
