"""TSM2R Pallas kernel: C[m,n] = A[m,k] @ B[k,n] with m ~ k >> n.

TPU-native restatement of paper Algorithm 4 (outer product + shared-memory
staging + data prefetch):

* Grid ``(m/bm, k/bk)`` with ``dimension_semantics=("parallel", "arbitrary")``:
  the k axis is the innermost sequential reduction, so Mosaic double-buffers
  the next (bm, bk) A window and (bk, n) B window while the MXU consumes the
  current ones -- exactly the nextA/nextB register prefetch of Algorithm 4,
  done by the pipeliner instead of by hand.
* A f32 accumulator lives in VMEM scratch across the k steps of one m-row of
  the grid (the paper's register-resident C_{1:t2}); it is zeroed on the
  first k step and flushed to the output window on the last. Consequence:
  **A is streamed from HBM exactly once** (Algorithm 2's outer-product
  guarantee).
* B's (bk, n) window is re-fetched once per m-block -- the analogue of the
  paper's ``n/t1`` B-reload factor; with k*n tiny this is noise (it is the
  term the paper also drops, Section 3.1.8 "minor inaccuracy").
* The shared-memory bank-conflict analysis (paper Section 3.1.4) has no TPU
  analogue; the corresponding layout decision here is lane-dim padding of n
  to 128 (done by ``ops.tsm2r`` when lowering for real TPUs).

Block sizes (bm, bk) come from ``repro.core.perf_model.choose_params_tsm2r``,
the discrete Algorithm-5 analogue -- which also picks the split factor S for
``tsm2r_pallas_split``, the split-reduction variant: the k sweep is cut into
S independent parallel slices (grid ``(S, m/bm, k/(S*bk))``,
``dimension_semantics=("parallel", "parallel", "arbitrary")``) emitting an
``(S, m, n)`` stack of f32 partials that
``repro.kernels.reduce.reduce_partials`` sums. Splitting widens the parallel
grid when ``m/bm`` alone cannot occupy a multi-core chip, at the cost of the
partials round trip -- the occupancy term in ``tsm2r_model_time`` prices
exactly that trade.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _tsm2r_kernel(a_ref, b_ref, o_ref, acc_ref):
    """One grid cell: acc[bm, n] += A[bm, bk] @ B[bk, n]."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "interpret"))
def tsm2r_pallas(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int, block_k: int,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Raw pallas_call; requires m % block_m == 0 and k % block_k == 0.

    ``interpret=None`` auto-detects (Python bodies off-TPU). Use
    ``repro.kernels.ops.tsm2r`` for the padded/dispatched public entry;
    under a multi-chip mesh the ``shard_map`` executor in
    ``repro.core.tsmm`` invokes that entry per shard (this call has no
    GSPMD partitioning rule of its own).
    """
    if interpret is None:
        interpret = compat.auto_interpret()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and k % block_k == 0, (m, k, block_m, block_k)
    grid = (m // block_m, k // block_k)

    return compat.pallas_call(
        _tsm2r_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_k, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[compat.VMEM((block_m, n), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)


def _tsm2r_split_kernel(a_ref, b_ref, o_ref):
    """One grid cell of reduction slice s: O[s][bm, n] += A B over the
    slice's k blocks. The f32 output block is invariant in the inner
    sequential axis (VMEM-resident across the slice) -- no scratch."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )[None]


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "splits",
                                             "interpret"))
def tsm2r_pallas_split(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int,
                       block_k: int, splits: int,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Split-reduction TSM2R: returns the ``(splits, m, n)`` f32 partials.

    Requires ``m % block_m == 0`` and ``k % (splits * block_k) == 0``
    (``ops.tsm2r`` pads). Grid ``(splits, m/bm, k/(S*bk))``: slices are
    parallel, each sweeps its own k range sequentially. Callers sum the
    leading axis (``repro.kernels.reduce.reduce_partials``).
    """
    if interpret is None:
        interpret = compat.auto_interpret()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and k % (splits * block_k) == 0, \
        (m, k, block_m, block_k, splits)
    steps = k // (splits * block_k)   # k blocks per reduction slice
    grid = (splits, m // block_m, steps)

    return compat.pallas_call(
        _tsm2r_split_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k),
                         lambda s, i, j: (i, s * steps + j)),
            pl.BlockSpec((block_k, n), lambda s, i, j: (s * steps + j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, n), lambda s, i, j: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((splits, m, n), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
