"""Tree-reduce epilogue for the split-reduction (split-K) kernels.

The split variants of TSMT/TSM2R emit an ``(S, rows, cols)`` stack of f32
partial products (one slab per reduction slice). This module owns the sum
over the leading axis:

* small stacks (a few MB -- every PowerSGD/ABFT shape) go through a plain
  ``jnp.sum``: XLA fuses the (S, a, b) reduction into the consumer and a
  custom kernel would only add a dispatch;
* large stacks (split TSM2R outputs: (S, m, n) with m huge) go through a
  tiny Pallas kernel gridded over the row axis, so the partials stream
  through VMEM once instead of materializing an XLA reduce tree.

Both paths accumulate in f32 and cast once at the end -- the split kernels
already accumulate their own slice in f32, so split-K results are
bitwise-stable against the split factor up to the final reassociation
(pinned vs the sequential kernels in tests/test_split.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

# Below this many f32 partial elements the jnp.sum path wins (no second
# kernel dispatch; XLA fuses). 1 MiB of partials ~ every skinny-output
# (tsmt) case; split tsm2r stacks at paper shapes are tens of MB.
JNP_REDUCE_MAX_ELEMS = 1 << 18


def _sum_lead_kernel(x_ref, o_ref):
    """One grid cell: O[br, cols] = sum_S X[S, br, cols] (f32 accumulate)."""
    o_ref[...] = jnp.sum(
        x_ref[...].astype(jnp.float32), axis=0
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_r", "out_dtype",
                                             "interpret"))
def sum_partials_pallas(p: jnp.ndarray, *, block_r: int, out_dtype,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Pallas sum over the leading axis of ``(S, rows, cols)``.

    Requires ``rows % block_r == 0`` (the split kernels' row axis is
    already a block multiple). The whole S stack of one row block is
    resident per cell -- callers size ``block_r`` against VMEM
    (:func:`reduce_partials` does).
    """
    if interpret is None:
        interpret = compat.auto_interpret()
    s, rows, cols = p.shape
    assert rows % block_r == 0, (rows, block_r)
    return compat.pallas_call(
        _sum_lead_kernel,
        grid=(rows // block_r,),
        in_specs=[pl.BlockSpec((s, block_r, cols), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((block_r, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(p)


def epilogue_block_r(s: int, rows: int, cols: int, *, block_r: int,
                     vmem_budget: int) -> int | None:
    """Row block the Pallas epilogue would launch with, or None.

    The pure half of :func:`reduce_partials`: None means the fused
    ``jnp.sum`` path runs (single slice, small stack, or no VMEM-feasible
    row block that divides ``rows``). A returned value is the resolved
    ``block_r`` -- the (S, rows, cols) sweep in ``repro.analysis.audit``
    and the launch metadata on ``DispatchEvent`` both derive the epilogue
    grid ``(rows // block_r,)`` from it.
    """
    if s == 1 or s * rows * cols <= JNP_REDUCE_MAX_ELEMS:
        return None
    block_r = min(block_r, rows)
    # in stack + out block, f32; lane-padded cols approximates the tile.
    cols_pad = ((cols + 127) // 128) * 128

    def cell_bytes(br):
        return (s + 1) * br * cols_pad * 4

    while cell_bytes(block_r) > vmem_budget and block_r % 2 == 0 and block_r > 8:
        block_r //= 2
    if rows % block_r != 0:  # defensive: fall back to the fused XLA sum
        return None
    return block_r


def reduce_partials(p: jnp.ndarray, out_dtype, *, block_r: int,
                    vmem_budget: int, interpret: bool | None = None
                    ) -> jnp.ndarray:
    """Sum the ``(S, rows, cols)`` partials stack to ``(rows, cols)``.

    ``block_r`` is the emitting kernel's row block (it divides rows by
    construction); :func:`epilogue_block_r` halves it while the per-cell
    stack would overrun ``vmem_budget`` bytes, or returns None to take the
    fused ``jnp.sum`` path (small stacks, or no feasible block).
    """
    s, rows, cols = p.shape
    if s == 1:
        return p[0].astype(out_dtype)
    br = epilogue_block_r(s, rows, cols, block_r=block_r,
                          vmem_budget=vmem_budget)
    if br is None:
        return jnp.sum(p.astype(jnp.float32), axis=0).astype(out_dtype)
    return sum_partials_pallas(p, block_r=br, out_dtype=out_dtype,
                               interpret=interpret)
