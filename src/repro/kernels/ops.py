"""Public jit'd entry points for the TSM2X kernels.

Handles: block-size AND split-factor selection (measured winners from
``GemmPolicy.tuning_table`` when present, else the analytic perf model --
run under the table's bucket-local fitted spec when it has one; explicit
per-call block/``splits=`` kwargs beat both, and ``GemmPolicy.split`` pins
S scope-wide), padding to block multiples (zero-padding is exact for GEMM;
split paths pad the reduction to whole S-slices), interpret-mode
resolution (policy field; auto-detect runs kernel bodies in Python on CPU
and compiles via Mosaic on TPU), and lane-dim padding of skinny minor dims
when lowering for real TPUs. Split (S > 1) dispatch runs the
``*_pallas_split`` kernel and sums the (S, ...) f32 partials through
``repro.kernels.reduce.reduce_partials`` before slicing off the padding,
so callers see the exact sequential-kernel contract.

All three entries carry ``jax.custom_vjp`` rules that take the resolved
``GemmPolicy`` through their nondiff args, so the backward re-enters
``repro.core.tsmm`` under the *caller's* scope -- the paper's central
observation applied to autodiff: the VJP of one tall-and-skinny GEMM class
lands in another.

    tsm2r/tsm2l:  C = A B        Abar = Chat B^T   (TSM2L-shaped for TSM2L)
                                 Bbar = A^T Chat   (TSMTTSM shape -> tsmt)
    tsmt:         C = X^T Y      Xbar = Y Chat^T   (TSM2L-shaped)
                                 Ybar = X Chat     (TSM2L-shaped)

Routing goes through ``tsmm.classify_gemm`` / ``tsmm.classify_gemm_t`` with
the scoped thresholds, so gradients stay inside the tall-skinny regime
instead of falling back to XLA dense dots; shapes that leave the regime
degrade to ``dot_general`` exactly like the forward dispatcher does.

Under a multi-chip mesh the backward re-dispatch also keeps the caller's
*collective*: ``tsmm.backward_policy`` preserves ``GemmPolicy.reduce``, so
in a ``reduce="psum_scatter"`` scope the weight-gradient ``tsmm_t``s here
(``Bbar = A^T Chat``) land on the ``shard_map-scatter`` executor and come
back row-sharded over the DP axes -- no all-gather between the kernel and
a ZeRO-sharded optimizer. Only ``reduce="none"`` is rewritten (to "psum"):
stacked partials would change the cotangent shape, which custom_vjp
forbids.

``spec=`` / ``interpret=`` kwargs are kept as per-call overrides of the
corresponding policy fields (prefer ``with tsmm.policy(...)`` scopes).

Under ``GemmPolicy.quant="int8"`` each impl quantizes its padded operands
(per-resolved-row-block scales for the tall operand, per-tensor for the
small one -- ``kernels/quant.py``) and launches the quantized kernel
variant; parameter resolution, tuning-table lookups and contract checks
all run against the int8 *effective dtype*, so the grid that is scored,
tuned and audited is the grid that launches. Outputs (and split partials,
which are dequantized in-kernel) keep the unquantized path's dtypes
exactly, so the reduce epilogue and the VJP rules below are unchanged.

Online ABFT sits ABOVE this layer: the checksum wrap
(``tsmm._abft_guard``) and the fault-injection tap
(``ft.inject.tap_executor``) both live at the dispatcher's
executor-registry boundary, so every arm routed through ``repro.core.tsmm``
-- including the split and quantized paths here -- is guarded and
injectable, while the impls in this module stay checksum-free. Calling
``ops.tsm2r``/``tsm2l``/``tsmt`` directly bypasses both the guard and
the tap; the
backward re-dispatch goes through ``tsmm`` and so re-enters them
(``tsmm.backward_policy`` preserves ``GemmPolicy.abft``).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.analysis import contracts
from repro.core import perf_model
from repro.kernels import compat, ref
from repro.kernels import quant as kquant
from repro.kernels.reduce import epilogue_block_r, reduce_partials
from repro.kernels.tsm2l import tsm2l_pallas
from repro.kernels.tsm2r import tsm2r_pallas, tsm2r_pallas_split
from repro.kernels.tsmt import tsmt_pallas, tsmt_pallas_split

# The TSMT kernels keep their (block_a, b) f32 accumulator as ONE unblocked
# VMEM tile, so the small output dim is hard-limited (the classifier's
# max_skinny_t default is derived from the same t2_threshold ~ 481, rounded
# up to the lane multiple). Past it, ops.tsmt refuses loudly instead of
# silently compiling a huge accumulator tile. The value is a contract, so
# it is owned by ``analysis.contracts`` and re-exported here.
TSMT_MAX_B = contracts.TSMT_MAX_B


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _dispatcher():
    # Deferred: repro.core.tsmm imports this module (forward dispatch);
    # the backward-pass dependency in the other direction stays lazy.
    from repro.core import tsmm
    return tsmm


def _effective_policy(policy, spec, interpret):
    """The caller's policy with legacy per-call kwargs folded in."""
    p = policy if policy is not None else _dispatcher().current_policy()
    repl = {}
    if spec is not None and spec is not p.spec:
        repl["spec"] = spec
    if interpret is not None and interpret != p.interpret:
        repl["interpret"] = interpret
    return dataclasses.replace(p, **repl) if repl else p


def _resolve_interpret(policy) -> bool:
    return (compat.auto_interpret() if policy.interpret is None
            else policy.interpret)


def _tuned_params(policy, kind, dims, dtype, interpret) -> dict | None:
    """Measured-best block params from ``policy.tuning_table``, if any.

    The table is keyed by (kind, shape bucket, dtype, spec name, executor);
    the executor key matches how this call will actually run, so a table
    tuned in interpret mode never silences the analytic model on hardware.
    Records tuned before the split-reduction dimension existed carry no
    "splits" key; consumers default it to 1 (the sequential kernel they
    actually measured).
    """
    table = policy.tuning_table
    if table is None:
        return None
    executor = "interpret" if interpret else "pallas-tpu"
    rec = table.lookup(kind, *dims, dtype=dtype, spec=policy.spec.name,
                       executor=executor)
    return None if rec is None else rec.params_dict


def _analytic_spec(policy, kind, dims, dtype):
    """Spec driving the analytic parameter choice for this shape: the
    tuning table's bucket-local fitted constants when it carries any
    (``TuningTable.fitted_spec`` -- bucket fit first, global fit second),
    else the policy's spec unchanged. Duck-typed so pre-fit tables (and
    any hashable stand-in) keep working."""
    table = policy.tuning_table
    fitted = getattr(table, "fitted_spec", None)
    if fitted is None:
        return policy.spec
    return fitted(kind, *dims, dtype=dtype, spec=policy.spec)


def _policy_split(policy) -> int | None:
    """The policy's split pin as an int, or None for "auto" (resolve from
    the tuning table / analytic chooser)."""
    s = policy.split
    if s == "never":
        return 1
    if s == "auto":
        return None
    return int(s)


def _vmem_budget(policy) -> int:
    return int(policy.spec.vmem_bytes * policy.spec.vmem_usable)


def _note_launch(kind, padded_shape, params):
    """Stamp the resolved launch onto the current DispatchEvent (no-op
    outside a `tsmm.record_dispatches` scope). Grid and semantics come
    from the pure contract -- `analysis.kernel_verify` proves that
    derivation equals the captured `pallas_call` (launch-meta-drift)."""
    grid, sem = contracts.launch_grid(kind, padded_shape, params)
    _dispatcher().note_launch(kind, grid, sem,
                              dict(params).get("splits", 1))


# ---------------------------------------------------------------------------
# Parameter resolution (pure; shared by the impls and analysis/audit)
# ---------------------------------------------------------------------------

def _resolve_tsm2r(m, k, n, dtype, policy, block_m, block_k, splits,
                   interpret):
    explicit_bk = block_k is not None
    if splits is None:
        splits = _policy_split(policy)
    if block_m is None or block_k is None or splits is None:
        tuned = _tuned_params(policy, "tsm2r", (m, k, n), dtype, interpret)
        if tuned is None:
            bm, bk, s = perf_model.choose_params_tsm2r(
                m, k, n, _analytic_spec(policy, "tsm2r", (m, k, n), dtype),
                dtype)
        else:
            bm, bk = tuned["block_m"], tuned["block_k"]
            s = tuned.get("splits", 1)
        block_m = block_m or bm
        block_k = block_k or bk
        if splits is None:
            splits = s
    # Sublane quantum is dtype-aware (int8 tiles are 32 rows deep); for
    # f32/bf16 this is exactly spec.sublane, as before.
    block_m = min(block_m, _ceil_mult(m, contracts.min_sublane(policy.spec,
                                                               dtype)))
    # block_k is a lane dim of the A window: clamp with the same lane
    # quantization the perf model's candidate filter uses, so the block the
    # kernel runs is the block the VMEM budget was checked against.
    block_k = min(block_k, _ceil_mult(k, policy.spec.lane))
    if splits > 1 and not explicit_bk:
        # A pinned S must be honored even when the chooser (which assumed
        # its own S) picked a block too deep for S whole slices: shrink
        # the reduction block -- unless the caller pinned it explicitly,
        # in which case the block wins and S clamps below.
        block_k = min(block_k,
                      _ceil_mult(-(-k // splits), policy.spec.lane))
    # Each reduction slice must own >= one block, or the extra slices are
    # pure zero-padding work: clamp S like the candidate filter does.
    splits = max(1, min(splits, -(-k // block_k)))
    return {"block_m": block_m, "block_k": block_k, "splits": splits}


def _resolve_tsm2l(m, k, n, dtype, policy, block_m, interpret):
    if block_m is None:
        tuned = _tuned_params(policy, "tsm2l", (m, k, n), dtype, interpret)
        block_m = (tuned["block_m"] if tuned is not None else
                   perf_model.choose_params_tsm2l(
                       m, k, n, _analytic_spec(policy, "tsm2l", (m, k, n),
                                               dtype), dtype))
    block_m = min(block_m, _ceil_mult(m, contracts.min_sublane(policy.spec,
                                                               dtype)))
    return {"block_m": block_m}


def _resolve_tsmt(m, a_dim, b_dim, dtype, policy, block_m, block_a, splits,
                  interpret):
    explicit_bm = block_m is not None
    if splits is None:
        splits = _policy_split(policy)
    if block_m is None or block_a is None or splits is None:
        tuned = _tuned_params(policy, "tsmt", (m, a_dim, b_dim), dtype,
                              interpret)
        if tuned is None:
            bm, ba, s = perf_model.choose_params_tsmt(
                m, a_dim, b_dim,
                _analytic_spec(policy, "tsmt", (m, a_dim, b_dim), dtype),
                dtype)
        else:
            bm, ba = tuned["block_m"], tuned["block_a"]
            s = tuned.get("splits", 1)
        block_m = block_m or bm
        block_a = block_a or ba
        if splits is None:
            splits = s
    sub = contracts.min_sublane(policy.spec, dtype)
    block_m = min(block_m, _ceil_mult(m, sub))
    # block_a is a lane dim of the X window: lane-quantized clamp, matching
    # the perf model's candidate filter (see _resolve_tsm2r).
    block_a = min(block_a, _ceil_mult(a_dim, policy.spec.lane))
    if splits > 1 and not explicit_bm:
        # honor a pinned S by shrinking the reduction block (m here);
        # an explicit block_m kwarg wins and S clamps instead.
        block_m = min(block_m, _ceil_mult(-(-m // splits), sub))
    # m is the reduction here: each slice must own >= one m block.
    splits = max(1, min(splits, -(-m // block_m)))
    return {"block_m": block_m, "block_a": block_a, "splits": splits}


def resolve_params(kind: str, m: int, d1: int, d2: int, dtype, policy, *,
                   block_m: int | None = None, block_k: int | None = None,
                   block_a: int | None = None, splits: int | None = None,
                   interpret: bool | None = None) -> dict:
    """Resolve the launch parameters dispatch would use -- without running.

    The exact trace-time logic of the op entry points, factored out so the
    offline auditor (``analysis/audit.py``) can sweep it: tuned winner from
    ``policy.tuning_table`` -> analytic chooser (under the table's fitted
    spec) -> quantized clamps -> split-slice clamp. Explicit kwargs beat
    both sources, exactly like the per-call kwargs on ``tsm2r``/``tsm2l``/
    ``tsmt``. ``(d1, d2)`` are ``(k, n)`` for tsm2r/tsm2l, ``(a, b)`` for
    tsmt.

    When ``policy.verify_contracts`` is set the resolved configuration is
    asserted against ``analysis.contracts.check_kernel_config`` under the
    same effective spec the chooser ran with; a violation raises
    ``ValueError`` (trace time, never on-device).

    Under ``policy.quant="int8"`` the whole resolution runs against the
    int8 *effective dtype* -- tuning-table lookups (dtype is already a key
    dimension, so quantized grids get their own measured winners with no
    schema fork), the analytic chooser's byte pricing, the clamps' wider
    32-row sublane quantum, and the contract check (which then prices the
    output window at the caller's ``dtype``). ``verify_contracts`` scopes
    additionally *reject* explicitly pinned blocks the int8 quantization
    would silently re-quantize, mirroring the lane-clamp contract: a pin
    that survives unchanged on the f32 path can be off the 32-row quantum
    or clamped to a different value under int8, and a quantized launch the
    caller didn't ask for must fail loudly.
    """
    if interpret is None:
        interpret = _resolve_interpret(policy)
    quant = getattr(policy, "quant", "none") == "int8"
    eff_dtype = jnp.int8 if quant else dtype
    if kind == "tsm2r":
        params = _resolve_tsm2r(m, d1, d2, eff_dtype, policy, block_m,
                                block_k, splits, interpret)
    elif kind == "tsm2l":
        params = _resolve_tsm2l(m, d1, d2, eff_dtype, policy, block_m,
                                interpret)
    elif kind == "tsmt":
        params = _resolve_tsmt(m, d1, d2, eff_dtype, policy, block_m,
                               block_a, splits, interpret)
    else:
        raise ValueError(f"unknown kernel kind {kind!r}: valid kinds are "
                         f"{', '.join(contracts.KINDS)}")
    if getattr(policy, "verify_contracts", False):
        if quant:
            sub = contracts.min_sublane(policy.spec, eff_dtype)
            bad = []
            for name, pin in (("block_m", block_m), ("block_k", block_k),
                              ("block_a", block_a)):
                if pin is None or name not in params:
                    continue
                q = sub if name == "block_m" else policy.spec.lane
                if pin % q != 0 or params[name] != pin:
                    bad.append(
                        f"[pinned-block-quant] {name}={pin} is infeasible "
                        f"under the int8 tile quantization (quantum {q}; "
                        f"resolution would re-quantize it to "
                        f"{params[name]})")
            if bad:
                raise ValueError(
                    "GemmPolicy.verify_contracts: explicit block pin(s) "
                    "rejected rather than silently re-quantized under "
                    "quant='int8': " + "; ".join(bad))
        eff_spec = _analytic_spec(policy, kind, (m, d1, d2), eff_dtype)
        violations = contracts.check_kernel_config(
            kind, (m, d1, d2), params, eff_dtype, eff_spec,
            max_b=getattr(policy, "max_skinny_t", None),
            out_dtype=dtype if quant else None)
        if violations:
            raise ValueError(
                "GemmPolicy.verify_contracts: resolved kernel config "
                f"breaks {len(violations)} contract(s): "
                + "; ".join(f"[{v.rule}] {v.detail}" for v in violations))
    return params


# ---------------------------------------------------------------------------
# TSM2R
# ---------------------------------------------------------------------------

def _tsm2r_impl(a, b, block_m, block_k, splits, policy):
    m, k = a.shape
    n = b.shape[1]
    interpret = _resolve_interpret(policy)
    p = resolve_params("tsm2r", m, k, n, a.dtype, policy, block_m=block_m,
                       block_k=block_k, splits=splits, interpret=interpret)
    block_m, block_k, splits = p["block_m"], p["block_k"], p["splits"]
    quant = getattr(policy, "quant", "none") == "int8"
    if splits == 1:
        a_p = _pad_to(_pad_to(a, 0, block_m), 1, block_k)
        b_p = _pad_to(b, 0, block_k)
        _note_launch("tsm2r", (a_p.shape[0], a_p.shape[1], n), p)
        if quant:
            a_q, a_s = kquant.quantize_blocks(a_p, block_m)
            b_q, b_s = kquant.quantize_tensor(b_p)
            out = kquant.tsm2r_q8_pallas(
                a_q, b_q, a_s, b_s, out_dtype=a.dtype, block_m=block_m,
                block_k=block_k, interpret=interpret)
        else:
            out = tsm2r_pallas(a_p, b_p, block_m=block_m, block_k=block_k,
                               interpret=interpret)
        return out[:m]
    # Split reduction: pad k so every slice is whole (zero-padding is exact
    # for GEMM, so m % (S*bk) non-multiples cost only the padded stream).
    a_p = _pad_to(_pad_to(a, 0, block_m), 1, splits * block_k)
    b_p = _pad_to(b, 0, splits * block_k)
    _note_launch("tsm2r", (a_p.shape[0], a_p.shape[1], n), p)
    if quant:
        a_q, a_s = kquant.quantize_blocks(a_p, block_m)
        b_q, b_s = kquant.quantize_tensor(b_p)
        parts = kquant.tsm2r_q8_pallas_split(
            a_q, b_q, a_s, b_s, block_m=block_m, block_k=block_k,
            splits=splits, interpret=interpret)
    else:
        parts = tsm2r_pallas_split(a_p, b_p, block_m=block_m,
                                   block_k=block_k, splits=splits,
                                   interpret=interpret)
    br = epilogue_block_r(splits, a_p.shape[0], n, block_r=block_m,
                          vmem_budget=_vmem_budget(policy))
    if br is not None:
        _note_launch("reduce", (splits, a_p.shape[0], n), {"block_r": br})
    out = reduce_partials(parts, a.dtype, block_r=block_m,
                          vmem_budget=_vmem_budget(policy),
                          interpret=interpret)
    return out[:m]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _tsm2r_diff(a, b, block_m, block_k, splits, policy):
    return _tsm2r_impl(a, b, block_m, block_k, splits, policy)


def _tsm2r_fwd(a, b, block_m, block_k, splits, policy):
    return _tsm2r_impl(a, b, block_m, block_k, splits, policy), (a, b)


def _tsm2r_bwd(block_m, block_k, splits, policy, res, ct):
    a, b = res
    tsmm = _dispatcher()
    bp = tsmm.backward_policy(policy)
    # Abar[m,k] = Chat[m,n] B^T[n,k]: tiny contraction; TSM2L-shaped when
    # k is small, dense when k ~ m (the TSM2R case) -- classifier decides.
    da = tsmm.tsmm(ct, b.T, policy=bp)
    # Bbar[k,n] = A^T[k,m] Chat[m,n]: reduction over tall m -- the TSMTTSM
    # shape (Ernst et al.), dispatched via classify_gemm_t.
    db = tsmm.tsmm_t(a, ct, policy=bp)
    return da.astype(a.dtype), db.astype(b.dtype)


_tsm2r_diff.defvjp(_tsm2r_fwd, _tsm2r_bwd)


def tsm2r(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int | None = None,
          block_k: int | None = None, splits: int | None = None,
          spec: perf_model.TPUSpec | None = None,
          interpret: bool | None = None,
          policy=None) -> jnp.ndarray:
    """C[m,n] = A[m,k] @ B[k,n], m ~ k >> n. Paper's TSM2R. Differentiable.

    ``splits=`` pins the split-reduction factor per call (like the block
    kwargs it beats the policy, the tuning table, and the model; S=1 is
    the sequential kernel).
    """
    p = _effective_policy(policy, spec, interpret)
    return _tsm2r_diff(a, b, block_m, block_k, splits, p)


# ---------------------------------------------------------------------------
# TSM2L
# ---------------------------------------------------------------------------

def _tsm2l_impl(a, b, block_m, policy):
    m, k = a.shape
    n = b.shape[1]
    interpret = _resolve_interpret(policy)
    block_m = resolve_params("tsm2l", m, k, n, a.dtype, policy,
                             block_m=block_m, interpret=interpret)["block_m"]
    a_p = _pad_to(a, 0, block_m)
    _note_launch("tsm2l", (a_p.shape[0], k, n), {"block_m": block_m})
    if getattr(policy, "quant", "none") == "int8":
        a_q, a_s = kquant.quantize_blocks(a_p, block_m)
        b_q, b_s = kquant.quantize_tensor(b)
        out = kquant.tsm2l_q8_pallas(a_q, b_q, a_s, b_s, out_dtype=a.dtype,
                                     block_m=block_m, interpret=interpret)
    else:
        out = tsm2l_pallas(a_p, b, block_m=block_m, interpret=interpret)
    return out[:m]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _tsm2l_diff(a, b, block_m, policy):
    return _tsm2l_impl(a, b, block_m, policy)


def _tsm2l_fwd(a, b, block_m, policy):
    return _tsm2l_impl(a, b, block_m, policy), (a, b)


def _tsm2l_bwd(block_m, policy, res, ct):
    a, b = res
    tsmm = _dispatcher()
    bp = tsmm.backward_policy(policy)
    # Abar[m,k] = Chat[m,n] B^T[n,k]: m >> n ~ k -- exactly TSM2L again.
    da = tsmm.tsmm(ct, b.T, policy=bp)
    # Bbar[k,n] = A^T Chat: tall-m reduction -> TSMT.
    db = tsmm.tsmm_t(a, ct, policy=bp)
    return da.astype(a.dtype), db.astype(b.dtype)


_tsm2l_diff.defvjp(_tsm2l_fwd, _tsm2l_bwd)


def tsm2l(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int | None = None,
          spec: perf_model.TPUSpec | None = None,
          interpret: bool | None = None,
          policy=None) -> jnp.ndarray:
    """C[m,n] = A[m,k] @ B[k,n], m >> k ~ n. Paper's TSM2L. Differentiable."""
    p = _effective_policy(policy, spec, interpret)
    return _tsm2l_diff(a, b, block_m, p)


# ---------------------------------------------------------------------------
# TSMT
# ---------------------------------------------------------------------------

def _tsmt_impl(x, y, block_m, block_a, splits, policy):
    m, a_dim = x.shape
    b_dim = y.shape[1]
    interpret = _resolve_interpret(policy)
    p = resolve_params("tsmt", m, a_dim, b_dim, x.dtype, policy,
                       block_m=block_m, block_a=block_a, splits=splits,
                       interpret=interpret)
    block_m, block_a, splits = p["block_m"], p["block_a"], p["splits"]
    quant = getattr(policy, "quant", "none") == "int8"
    if splits == 1:
        x_p = _pad_to(_pad_to(x, 0, block_m), 1, block_a)
        y_p = _pad_to(y, 0, block_m)
        _note_launch("tsmt", (x_p.shape[0], x_p.shape[1], b_dim), p)
        if quant:
            x_q, x_s = kquant.quantize_blocks(x_p, block_m)
            y_q, y_s = kquant.quantize_blocks(y_p, block_m)
            out = kquant.tsmt_q8_pallas(
                x_q, y_q, x_s, y_s, out_dtype=x.dtype, block_m=block_m,
                block_a=block_a, interpret=interpret)
        else:
            out = tsmt_pallas(x_p, y_p, block_m=block_m, block_a=block_a,
                              interpret=interpret)
        return out[:a_dim]
    # Split reduction over m: pad to whole slices (zeros contribute
    # nothing to the partial sums), reduce the (S, a, b) f32 stack.
    x_p = _pad_to(_pad_to(x, 0, splits * block_m), 1, block_a)
    y_p = _pad_to(y, 0, splits * block_m)
    _note_launch("tsmt", (x_p.shape[0], x_p.shape[1], b_dim), p)
    if quant:
        x_q, x_s = kquant.quantize_blocks(x_p, block_m)
        y_q, y_s = kquant.quantize_blocks(y_p, block_m)
        parts = kquant.tsmt_q8_pallas_split(
            x_q, y_q, x_s, y_s, block_m=block_m, block_a=block_a,
            splits=splits, interpret=interpret)
    else:
        parts = tsmt_pallas_split(x_p, y_p, block_m=block_m,
                                  block_a=block_a, splits=splits,
                                  interpret=interpret)
    br = epilogue_block_r(splits, x_p.shape[1], b_dim, block_r=block_a,
                          vmem_budget=_vmem_budget(policy))
    if br is not None:
        _note_launch("reduce", (splits, x_p.shape[1], b_dim),
                     {"block_r": br})
    out = reduce_partials(parts, x.dtype, block_r=block_a,
                          vmem_budget=_vmem_budget(policy),
                          interpret=interpret)
    return out[:a_dim]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _tsmt_diff(x, y, block_m, block_a, splits, policy):
    return _tsmt_impl(x, y, block_m, block_a, splits, policy)


def _tsmt_fwd(x, y, block_m, block_a, splits, policy):
    return _tsmt_impl(x, y, block_m, block_a, splits, policy), (x, y)


def _tsmt_bwd(block_m, block_a, splits, policy, res, ct):
    x, y = res
    tsmm = _dispatcher()
    bp = tsmm.backward_policy(policy)
    # Xbar[m,a] = Y[m,b] Chat^T[b,a] and Ybar[m,b] = X[m,a] Chat[a,b]:
    # both are tall-m, tiny-contraction products -- TSM2L-shaped.
    dx = tsmm.tsmm(y, ct.T, policy=bp)
    dy = tsmm.tsmm(x, ct, policy=bp)
    return dx.astype(x.dtype), dy.astype(y.dtype)


_tsmt_diff.defvjp(_tsmt_fwd, _tsmt_bwd)


def tsmt(x: jnp.ndarray, y: jnp.ndarray, *, block_m: int | None = None,
         block_a: int | None = None, splits: int | None = None,
         spec: perf_model.TPUSpec | None = None,
         interpret: bool | None = None,
         policy=None) -> jnp.ndarray:
    """C[a,b] = X[m,a]^T @ Y[m,b], m >> a, b. TSMTTSM-style extension.
    Differentiable.

    ``splits=`` pins the split-reduction factor per call (S=1 sequential).
    Raises ``ValueError`` when the unblocked output dim b exceeds the
    accumulator limit -- ``TSMT_MAX_B``, or the scope's ``max_skinny_t``
    when a policy deliberately raised the classifier past it (raising the
    threshold is an explicit opt-in to the bigger VMEM tile); reorient the
    operands (or use ``tsmm.tsmm``) instead.
    """
    p = _effective_policy(policy, spec, interpret)
    limit = max(TSMT_MAX_B, getattr(p, "max_skinny_t", TSMT_MAX_B))
    if y.ndim == 2 and y.shape[1] > limit:
        raise ValueError(
            f"tsmt small output dim b={y.shape[1]} exceeds the unblocked "
            f"f32 accumulator limit ({limit}): the (block_a, b) "
            "accumulator is a single VMEM tile. Orient the operands so the "
            "larger output dim comes first (C = tsmt(y, x).T), or dispatch "
            "through tsmm.tsmm_t, which classifies such shapes dense.")
    return _tsmt_diff(x, y, block_m, block_a, splits, p)


# Quantization primitive, owned by the contract layer (one copy).
_ceil_mult = contracts.ceil_mult


# Re-exported oracles so callers can A/B against the pure-jnp path.
tsm2r_ref = ref.tsm2r_ref
tsm2l_ref = ref.tsm2l_ref
tsmt_ref = ref.tsmt_ref


def tsqr(a: jnp.ndarray, *, policy=None, passes: int | None = None,
         shift_rel: float | None = None):
    """Tall-skinny QR (CholeskyQR2) built on :func:`tsmt` + :func:`tsm2l`.

    Thin re-export of :func:`repro.linalg.tsqr` for symmetry with the
    kernel entries; see that module for numerics and the distributed
    ``tree_tsqr`` variant. Imported lazily -- ``repro.linalg`` consumes
    the dispatcher above, so a top-level import would be cyclic.
    """
    from repro import linalg
    return linalg.tsqr(a, policy=policy, passes=passes,
                       shift_rel=shift_rel)
