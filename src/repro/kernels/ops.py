"""Public jit'd entry points for the TSM2X kernels.

Handles: block-size selection (perf model), padding to block multiples
(zero-padding is exact for GEMM), interpret-mode auto-detection (CPU runs
the kernel bodies in Python for correctness; TPU compiles via Mosaic), and
lane-dim padding of skinny minor dims when lowering for real TPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import perf_model
from repro.kernels import ref
from repro.kernels.tsm2l import tsm2l_pallas
from repro.kernels.tsm2r import tsm2r_pallas
from repro.kernels.tsmt import tsmt_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def tsm2r(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int | None = None,
          block_k: int | None = None, spec: perf_model.TPUSpec = perf_model.V5E,
          interpret: bool | None = None) -> jnp.ndarray:
    """C[m,n] = A[m,k] @ B[k,n], m ~ k >> n. Paper's TSM2R."""
    m, k = a.shape
    n = b.shape[1]
    if interpret is None:
        interpret = _auto_interpret()
    if block_m is None or block_k is None:
        bm, bk = perf_model.choose_params_tsm2r(m, k, n, spec, a.dtype)
        block_m = block_m or bm
        block_k = block_k or bk
    block_m = min(block_m, _ceil_mult(m, 8))
    block_k = min(block_k, _ceil_mult(k, 8))
    a_p = _pad_to(_pad_to(a, 0, block_m), 1, block_k)
    b_p = _pad_to(b, 0, block_k)
    out = tsm2r_pallas(a_p, b_p, block_m=block_m, block_k=block_k,
                       interpret=interpret)
    return out[:m]


def tsm2l(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int | None = None,
          spec: perf_model.TPUSpec = perf_model.V5E,
          interpret: bool | None = None) -> jnp.ndarray:
    """C[m,n] = A[m,k] @ B[k,n], m >> k ~ n. Paper's TSM2L."""
    m, k = a.shape
    n = b.shape[1]
    if interpret is None:
        interpret = _auto_interpret()
    if block_m is None:
        block_m = perf_model.choose_params_tsm2l(m, k, n, spec, a.dtype)
    block_m = min(block_m, _ceil_mult(m, 8))
    a_p = _pad_to(a, 0, block_m)
    out = tsm2l_pallas(a_p, b, block_m=block_m, interpret=interpret)
    return out[:m]


def tsmt(x: jnp.ndarray, y: jnp.ndarray, *, block_m: int | None = None,
         block_a: int | None = None, spec: perf_model.TPUSpec = perf_model.V5E,
         interpret: bool | None = None) -> jnp.ndarray:
    """C[a,b] = X[m,a]^T @ Y[m,b], m >> a, b. TSMTTSM-style extension."""
    m, a_dim = x.shape
    b_dim = y.shape[1]
    if interpret is None:
        interpret = _auto_interpret()
    if block_m is None or block_a is None:
        bm, ba = perf_model.choose_params_tsmt(m, a_dim, b_dim, spec, x.dtype)
        block_m = block_m or bm
        block_a = block_a or ba
    block_m = min(block_m, _ceil_mult(m, 8))
    block_a = min(block_a, _ceil_mult(a_dim, 8))
    x_p = _pad_to(_pad_to(x, 0, block_m), 1, block_a)
    y_p = _pad_to(y, 0, block_m)
    out = tsmt_pallas(x_p, y_p, block_m=block_m, block_a=block_a,
                      interpret=interpret)
    return out[:a_dim]


def _ceil_mult(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


# Re-exported oracles so callers can A/B against the pure-jnp path.
tsm2r_ref = ref.tsm2r_ref
tsm2l_ref = ref.tsm2l_ref
tsmt_ref = ref.tsmt_ref
