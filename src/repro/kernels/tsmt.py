"""TSMT Pallas kernel: C[a,b] = X[m,a]^T @ Y[m,b] with m >> a, b.

Beyond-paper extension: the transposed tall-and-skinny case ("TSMTTSM",
Ernst et al. [38]) which the paper explicitly leaves uncovered. The
framework needs it for:

* PowerSGD's second projection  Q = G^T P  (G: huge gradient matrix,
  P: m x r with r in {2..16});
* ABFT checksum *verification*  s = G^T e  against the encoded checksum.

Shape character: the reduction axis is the huge one (m), both output dims
are small. The TPU formulation:

* Grid ``(a/ba, m/bm)`` with the m axis innermost-sequential
  (``dimension_semantics=("parallel", "arbitrary")``): a (ba x b) f32
  accumulator in VMEM survives the m sweep; X and Y windows stream through
  double-buffered VMEM exactly once per a-block.
* The second output dim (b) must be small (<= ~512): it stays unblocked so
  the accumulator is a single VMEM tile. Callers orient their operands so
  the large output dim is first (ops.tsmt handles this; it raises a clear
  ValueError past the limit instead of compiling a huge accumulator).

Split reduction (``tsmt_pallas_split``): with PowerSGD/ABFT shapes
(a, b <= 16) the parallel grid dim collapses to ``a/ba == 1`` cell, so one
core sweeps the entire m reduction while the rest of the chip idles. The
split variant cuts the m sweep into S independent slices -- grid
``(S, a/ba, m/(S*bm))`` with ``dimension_semantics=("parallel",
"parallel", "arbitrary")`` -- each accumulating its own f32 partial into an
``(S, a, b)`` stack; ``repro.kernels.reduce.reduce_partials`` sums the
stack. This is the TSM paper's leap-based global-reduce, discretized:
occupancy x S for one extra (tiny) partials round trip.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _tsmt_kernel(x_ref, y_ref, o_ref, acc_ref):
    """One grid cell: acc[ba, b] += X[bm, ba]^T @ Y[bm, b]."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_a", "interpret"))
def tsmt_pallas(x: jnp.ndarray, y: jnp.ndarray, *, block_m: int, block_a: int,
                interpret: bool | None = None) -> jnp.ndarray:
    """Raw pallas_call; requires m % block_m == 0 and a % block_a == 0.

    ``interpret=None`` auto-detects (Python bodies off-TPU). Use
    ``repro.kernels.ops.tsmt`` for the padded/dispatched public entry;
    under a multi-chip mesh the ``shard_map`` executor in
    ``repro.core.tsmm`` runs that entry per shard and psums the partials.
    """
    if interpret is None:
        interpret = compat.auto_interpret()
    m, a = x.shape
    m2, b = y.shape
    assert m == m2, (x.shape, y.shape)
    assert m % block_m == 0 and a % block_a == 0, (m, a, block_m, block_a)
    grid = (a // block_a, m // block_m)

    return compat.pallas_call(
        _tsmt_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_a), lambda i, j: (j, i)),
            pl.BlockSpec((block_m, b), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_a, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a, b), x.dtype),
        scratch_shapes=[compat.VMEM((block_a, b), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, y)


def _tsmt_split_kernel(x_ref, y_ref, o_ref):
    """One grid cell of reduction slice s: O[s][ba, b] += X^T Y over the
    slice's m blocks. The output block is f32 and invariant in the inner
    sequential axis, so it stays VMEM-resident across the slice's sweep --
    no scratch accumulator needed."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None]


@functools.partial(jax.jit, static_argnames=("block_m", "block_a", "splits",
                                             "interpret"))
def tsmt_pallas_split(x: jnp.ndarray, y: jnp.ndarray, *, block_m: int,
                      block_a: int, splits: int,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Split-reduction TSMT: returns the ``(splits, a, b)`` f32 partials.

    Requires ``m % (splits * block_m) == 0`` and ``a % block_a == 0``
    (``ops.tsmt`` pads). Grid ``(splits, a/ba, m/(S*bm))``: the first two
    dims are parallel (slices are independent), the third sweeps one
    slice's m blocks sequentially. Callers sum the leading axis
    (``repro.kernels.reduce.reduce_partials``).
    """
    if interpret is None:
        interpret = compat.auto_interpret()
    m, a = x.shape
    m2, b = y.shape
    assert m == m2, (x.shape, y.shape)
    assert m % (splits * block_m) == 0 and a % block_a == 0, \
        (m, a, block_m, block_a, splits)
    steps = m // (splits * block_m)   # m blocks per reduction slice
    grid = (splits, a // block_a, steps)

    return compat.pallas_call(
        _tsmt_split_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_a),
                         lambda s, i, j: (s * steps + j, i)),
            pl.BlockSpec((block_m, b), lambda s, i, j: (s * steps + j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_a, b), lambda s, i, j: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((splits, a, b), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, y)
