"""Shared model layers: norms, RoPE, MLPs, embeddings.

Pure-functional style: ``init_*`` returns a params pytree (nested dict of
arrays); ``*_fwd`` applies it. All matmul accumulation is f32
(``preferred_element_type``); norms run in f32 regardless of activation
dtype. Weight layout convention: ``w[in_dim, out_dim]`` so activations hit
the MXU without transposes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

import functools

from repro.core import tsmm


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def _dense_raw(w, x):
    # repro: allow-raw-param-matmul (this IS the dense primitive dense()
    # routes non-tsmm shapes to -- 1-D params and the mode="dense" A/B arm;
    # wrapping it in tsmm would recurse)
    return lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


# Param-dtype-gradient variant (GemmPolicy.param_dtype_grads, the old
# REPRO_BF16_PARAM_GRADS lever): emit parameter gradients in the parameter
# dtype instead of f32. The default VJP of an f32-accumulating dot produces
# f32 cotangents, doubling per-device gradient memory under pure-DP/ZeRO-1
# (12.8 GiB -> 6.4 GiB for a 3B model). Accumulation inside each dot stays
# f32 either way; the policy rides the nondiff arg so the backward
# re-dispatch honors the scope dense() was traced under.

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _dense_pg(w, x, policy):
    return tsmm.tsmm(x, w, policy=policy)


def _dense_pg_fwd(w, x, policy):
    return tsmm.tsmm(x, w, policy=policy), (w, x)


def _dense_pg_bwd(policy, res, dy):
    w, x = res
    bp = tsmm.backward_policy(policy)
    # dw[d_in,d_out] = X^T dY reduced over every token dim: the TSMTTSM
    # shape; tsmm_t collapses the leading dims into the reduction itself.
    dw = tsmm.tsmm_t(x, dy, policy=bp).astype(w.dtype)
    dx = tsmm.tsmm(dy, w.T, policy=bp).astype(x.dtype)
    return dw, dx


_dense_pg.defvjp(_dense_pg_fwd, _dense_pg_bwd)


def dense(w, x):
    """x @ w over the trailing dim of x.

    Every model projection (QKV/out/MLP/LoRA/SSM in-out) lands here, so
    this is where the tall-and-skinny dispatcher hooks into the train path:
    ``tsmm`` takes the (..., S, d_in) activations as-is (it owns the
    leading-dim collapse), routes to a TSM2X kernel when the shape
    qualifies (e.g. LoRA/PowerSGD ranks, skinny heads at large token
    counts), to the identical reshape-free ``dot_general`` otherwise, and
    to the per-shard ``shard_map`` executor under a multi-chip mesh. All
    routing follows the active ``tsmm.policy(...)`` scope, captured at
    trace time -- ``with tsmm.policy(mode="dense")`` is the A/B escape
    hatch (A/B arms still need separate jit caches). When the scope sets
    ``param_dtype_grads``, the custom-VJP ``_dense_pg`` variant owns the
    backward dtype.
    """
    p = tsmm.current_policy()
    if x.ndim < 2:
        return _dense_raw(w, x)
    if p.param_dtype_grads:
        return _dense_pg(w, x, p)
    return tsmm.tsmm(x, w)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, fraction: float, theta: float):
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot)), rot


def apply_rope(x, positions, *, theta: float = 10000.0, fraction: float = 1.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S).

    ``fraction < 1`` rotates only the leading slice of D (ChatGLM-style
    partial / '2d' RoPE); the remainder passes through unrotated.
    """
    d = x.shape[-1]
    inv_freq, rot = rope_freqs(d, fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, rot/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., : rot // 2], x_rot[..., rot // 2:]
    r1 = (x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin)
    r2 = (x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin)
    return jnp.concatenate(
        [r1.astype(x.dtype), r2.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params, x):
    g = dense(params["w_gate"], x)
    u = dense(params["w_up"], x)
    return dense(params["w_down"], jax.nn.silu(g) * u)


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x):
    h = dense(params["w_up"], x) + params["b_up"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return dense(params["w_down"], h) + params["b_down"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d_model: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * d_model ** -0.5).astype(dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    """Logits in f32 (loss stability); table may be the tied embedding."""
    # repro: allow-raw-param-matmul (logits must stay f32 -- tsmm returns
    # the operand dtype -- and vocab-sized outputs never classify
    # tall-skinny; GSPMD shards the dense dot over the tied table)
    return lax.dot_general(
        x, params["table"], (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def lora_init(key, d_in: int, d_out: int, rank: int, dtype):
    """Low-rank adapter: a tall-and-skinny GEMM pair (TSM2X shapes)."""
    k1, k2 = jax.random.split(key)
    return {
        "a": dense_init(k1, d_in, rank, dtype),
        "b": jnp.zeros((rank, d_out), dtype),
    }


def lora_apply(params, x, base_out=None):
    h = dense(params["a"], x)
    out = dense(params["b"], h)
    return out if base_out is None else base_out + out
