"""Per-layer blocks: init / train-forward / prefill / decode for every layer
kind used by the ten assigned architectures.

Kinds:
  attn_mlp   dense transformer layer (GQA + MLP)        [llama/qwen/chatglm/
                                                          mistral/hubert]
  attn_moe   GQA + routed MoE                            [mixtral]
  mla_mlp    DeepSeek MLA + dense MLP                    [deepseek first-3]
  mla_moe    DeepSeek MLA + MoE (shared+routed)          [deepseek]
  mamba      Mamba2 layer                                [zamba2 backbone]
  rwkv       RWKV6 time-mix + channel-mix                [rwkv6]
  cross_mlp  gated cross-attention to image tokens + MLP [llama3.2-vision]

Residual/pre-norm convention: x = x + f(norm(x)) everywhere (hubert uses
LayerNorm via cfg.norm, others RMSNorm).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention, layers, mamba2, moe, rwkv6


def _norm_init(cfg, d=None):
    d = d or cfg.d_model
    return (layers.layernorm_init(d) if cfg.norm == "ln"
            else layers.rmsnorm_init(d))


def norm_apply(cfg, p, x):
    return (layers.layernorm(p, x, cfg.norm_eps) if cfg.norm == "ln"
            else layers.rmsnorm(p, x, cfg.norm_eps))


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _mlp_init(key, cfg):
    if cfg.mlp_type == "gelu":
        return layers.gelu_mlp_init(key, cfg.d_model, cfg.d_ff, _dtype(cfg))
    return layers.swiglu_init(key, cfg.d_model, cfg.d_ff, _dtype(cfg))


def _mlp_fwd(cfg, p, x):
    return (layers.gelu_mlp(p, x) if cfg.mlp_type == "gelu"
            else layers.swiglu(p, x))


def _attn_kwargs(cfg):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                rope_fraction=cfg.rope_fraction)


def _mla_kwargs(cfg):
    m = cfg.mla
    return dict(n_heads=cfg.n_heads, nope_dim=m.nope_dim, rope_dim=m.rope_dim,
                v_dim=m.v_dim, rope_theta=cfg.rope_theta)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def block_init(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    if kind in ("attn_mlp", "attn_moe"):
        p = {
            "norm1": _norm_init(cfg),
            "attn": attention.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.resolved_head_dim,
                                       qkv_bias=cfg.qkv_bias, dtype=dt),
            "norm2": _norm_init(cfg),
        }
        p["ffn"] = (moe.moe_init(ks[1], cfg.d_model, cfg.moe, dt)
                    if kind == "attn_moe" else _mlp_init(ks[1], cfg))
        return p
    if kind in ("mla_mlp", "mla_moe"):
        m = cfg.mla
        p = {
            "norm1": _norm_init(cfg),
            "attn": attention.mla_init(ks[0], cfg.d_model, cfg.n_heads,
                                       q_lora=m.q_lora, kv_lora=m.kv_lora,
                                       nope_dim=m.nope_dim, rope_dim=m.rope_dim,
                                       v_dim=m.v_dim, dtype=dt),
            "norm2": _norm_init(cfg),
        }
        p["ffn"] = (moe.moe_init(ks[1], cfg.d_model, cfg.moe, dt)
                    if kind == "mla_moe" else _mlp_init(ks[1], cfg))
        return p
    if kind == "mamba":
        return {
            "norm1": _norm_init(cfg),
            "mixer": mamba2.mamba2_init(ks[0], cfg.d_model, cfg.ssm, dt),
        }
    if kind == "rwkv":
        return {
            "norm1": _norm_init(cfg),
            "time_mix": rwkv6.rwkv6_time_mix_init(ks[0], cfg.d_model, cfg.rwkv, dt),
            "norm2": _norm_init(cfg),
            "channel_mix": rwkv6.rwkv6_channel_mix_init(ks[1], cfg.d_model,
                                                        cfg.d_ff, dt),
        }
    if kind == "cross_mlp":
        return {
            "norm1": _norm_init(cfg),
            "attn": attention.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.resolved_head_dim,
                                       dtype=dt),
            "kv_proj_k": layers.dense_init(ks[1], cfg.vision_dim,
                                           cfg.n_kv_heads * cfg.resolved_head_dim, dt),
            "kv_proj_v": layers.dense_init(ks[2], cfg.vision_dim,
                                           cfg.n_kv_heads * cfg.resolved_head_dim, dt),
            "gate_attn": jnp.zeros((), jnp.float32),
            "norm2": _norm_init(cfg),
            "ffn": _mlp_init(ks[3], cfg),
            "gate_ffn": jnp.zeros((), jnp.float32),
        }
    raise ValueError(kind)


def cross_kv(p, cfg, image_embeds):
    """Project image-patch embeddings to cross-attention K/V."""
    b, s_img, _ = image_embeds.shape
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = layers.dense(p["kv_proj_k"], image_embeds).reshape(b, s_img, hk, hd)
    v = layers.dense(p["kv_proj_v"], image_embeds).reshape(b, s_img, hk, hd)
    return k, v


# ---------------------------------------------------------------------------
# Train forward (full sequence, no cache)
# ---------------------------------------------------------------------------

def block_fwd(p, x, cfg, kind: str, extras=None):
    """Returns (x, metrics)."""
    metrics = {}
    if kind in ("attn_mlp", "attn_moe"):
        h, _ = attention.gqa_fwd(p["attn"], norm_apply(cfg, p["norm1"], x),
                                 causal=cfg.causal, window=cfg.attn_window,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                 **_attn_kwargs(cfg))
        x = x + h
        h2in = norm_apply(cfg, p["norm2"], x)
        if kind == "attn_moe":
            h2, metrics = moe.moe_fwd(p["ffn"], h2in, cfg.moe)
        else:
            h2 = _mlp_fwd(cfg, p["ffn"], h2in)
        return x + h2, metrics
    if kind in ("mla_mlp", "mla_moe"):
        h, _ = attention.mla_fwd(p["attn"], norm_apply(cfg, p["norm1"], x),
                                 causal=cfg.causal, q_chunk=cfg.q_chunk,
                                 kv_chunk=cfg.kv_chunk, **_mla_kwargs(cfg))
        x = x + h
        h2in = norm_apply(cfg, p["norm2"], x)
        if kind == "mla_moe":
            h2, metrics = moe.moe_fwd(p["ffn"], h2in, cfg.moe)
        else:
            h2 = _mlp_fwd(cfg, p["ffn"], h2in)
        return x + h2, metrics
    if kind == "mamba":
        h = mamba2.mamba2_fwd(p["mixer"], norm_apply(cfg, p["norm1"], x), cfg.ssm)
        return x + h, metrics
    if kind == "rwkv":
        h = rwkv6.rwkv6_time_mix(p["time_mix"], norm_apply(cfg, p["norm1"], x),
                                 cfg.rwkv)
        x = x + h
        h2 = rwkv6.rwkv6_channel_mix(p["channel_mix"],
                                     norm_apply(cfg, p["norm2"], x))
        return x + h2, metrics
    if kind == "cross_mlp":
        kv = cross_kv(p, cfg, extras["image_embeds"])
        h, _ = attention.gqa_fwd(p["attn"], norm_apply(cfg, p["norm1"], x),
                                 causal=False, kv_override=kv,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                 **{**_attn_kwargs(cfg), "rope_fraction": 0.0})
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        h2 = _mlp_fwd(cfg, p["ffn"], norm_apply(cfg, p["norm2"], x))
        return x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * h2, metrics
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Cache init / prefill / decode
# ---------------------------------------------------------------------------

def cache_init(cfg, kind: str, batch: int, max_len: int):
    """Zero cache entry for one layer of this kind."""
    dt = _dtype(cfg)
    hd, hk = cfg.resolved_head_dim, cfg.n_kv_heads
    if kind in ("attn_mlp", "attn_moe"):
        s = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
        return {"k": jnp.zeros((batch, s, hk, hd), dt),
                "v": jnp.zeros((batch, s, hk, hd), dt)}
    if kind in ("mla_mlp", "mla_moe"):
        m = cfg.mla
        return {"c": jnp.zeros((batch, max_len, m.kv_lora), dt),
                "kpe": jnp.zeros((batch, max_len, m.rope_dim), dt)}
    if kind == "mamba":
        s = cfg.ssm
        return {"ssm": jnp.zeros((batch, s.n_heads, s.state_dim,
                                  s.d_inner // s.n_heads), jnp.float32),
                "conv": jnp.zeros((batch, s.conv_width - 1,
                                   s.d_inner + 2 * s.n_groups * s.state_dim), dt)}
    if kind == "rwkv":
        r = cfg.rwkv
        return {"wkv": jnp.zeros((batch, r.n_heads, r.head_dim, r.head_dim),
                                 jnp.float32),
                "tm_prev": jnp.zeros((batch, 1, cfg.d_model), dt),
                "cm_prev": jnp.zeros((batch, 1, cfg.d_model), dt)}
    if kind == "cross_mlp":
        return {"k": jnp.zeros((batch, cfg.vision_seq, hk, hd), dt),
                "v": jnp.zeros((batch, cfg.vision_seq, hk, hd), dt)}
    raise ValueError(kind)


def block_prefill(p, x, cfg, kind: str, cache, extras=None):
    """Full-sequence forward that also fills the cache. Returns (x, cache)."""
    s = x.shape[1]
    if kind in ("attn_mlp", "attn_moe"):
        h, (k, v) = attention.gqa_fwd(
            p["attn"], norm_apply(cfg, p["norm1"], x), causal=cfg.causal,
            window=cfg.attn_window, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            **_attn_kwargs(cfg))
        if cfg.attn_window and cache["k"].shape[1] == cfg.attn_window:
            w = cfg.attn_window
            if s >= w:  # ring layout: slot = pos % w
                k_last, v_last = k[:, -w:], v[:, -w:]
                shift = s % w
                cache = {"k": jnp.roll(k_last, shift, axis=1),
                         "v": jnp.roll(v_last, shift, axis=1)}
            else:
                cache = {"k": lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                         "v": lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)}
        else:
            cache = {"k": lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                     "v": lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)}
        x = x + h
        h2in = norm_apply(cfg, p["norm2"], x)
        h2 = (moe.moe_fwd(p["ffn"], h2in, cfg.moe)[0] if kind == "attn_moe"
              else _mlp_fwd(cfg, p["ffn"], h2in))
        return x + h2, cache
    if kind in ("mla_mlp", "mla_moe"):
        h, (c, kpe) = attention.mla_fwd(
            p["attn"], norm_apply(cfg, p["norm1"], x), causal=cfg.causal,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk, **_mla_kwargs(cfg))
        cache = {"c": lax.dynamic_update_slice_in_dim(cache["c"], c, 0, 1),
                 "kpe": lax.dynamic_update_slice_in_dim(cache["kpe"], kpe, 0, 1)}
        x = x + h
        h2in = norm_apply(cfg, p["norm2"], x)
        h2 = (moe.moe_fwd(p["ffn"], h2in, cfg.moe)[0] if kind == "mla_moe"
              else _mlp_fwd(cfg, p["ffn"], h2in))
        return x + h2, cache
    if kind == "mamba":
        h, (ssm, conv) = mamba2.mamba2_fwd(
            p["mixer"], norm_apply(cfg, p["norm1"], x), cfg.ssm,
            return_state=True)
        return x + h, {"ssm": ssm, "conv": conv}
    if kind == "rwkv":
        n1 = norm_apply(cfg, p["norm1"], x)
        h, (wkv, tm_prev_n) = rwkv6.rwkv6_time_mix(p["time_mix"], n1, cfg.rwkv,
                                                   return_state=True)
        x = x + h
        n2 = norm_apply(cfg, p["norm2"], x)
        h2, cm_prev_n = rwkv6.rwkv6_channel_mix(p["channel_mix"], n2,
                                                return_state=True)
        # Cache the *normed* last inputs: decode re-normalizes the new token,
        # so store what the mixers actually consumed.
        return x + h2, {"wkv": wkv, "tm_prev": tm_prev_n, "cm_prev": cm_prev_n}
    if kind == "cross_mlp":
        k, v = cross_kv(p, cfg, extras["image_embeds"])
        cache = {"k": k, "v": v}
        h, _ = attention.gqa_fwd(p["attn"], norm_apply(cfg, p["norm1"], x),
                                 causal=False, kv_override=(k, v),
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                 **{**_attn_kwargs(cfg), "rope_fraction": 0.0})
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        h2 = _mlp_fwd(cfg, p["ffn"], norm_apply(cfg, p["norm2"], x))
        return x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * h2, cache
    raise ValueError(kind)


def block_decode(p, x, cfg, kind: str, cache, pos, extras=None):
    """One-token step. x: (B,1,d). Returns (x, cache)."""
    if kind in ("attn_mlp", "attn_moe"):
        ring = (cfg.attn_window
                if cfg.attn_window and cache["k"].shape[1] == cfg.attn_window
                else None)
        h, ck, cv = attention.gqa_decode(
            p["attn"], norm_apply(cfg, p["norm1"], x), cache["k"], cache["v"],
            pos, window=None if ring else cfg.attn_window, ring_window=ring,
            **_attn_kwargs(cfg))
        cache = {"k": ck, "v": cv}
        x = x + h
        h2in = norm_apply(cfg, p["norm2"], x)
        h2 = (moe.moe_fwd(p["ffn"], h2in, cfg.moe)[0] if kind == "attn_moe"
              else _mlp_fwd(cfg, p["ffn"], h2in))
        return x + h2, cache
    if kind in ("mla_mlp", "mla_moe"):
        h, cc, ckpe = attention.mla_decode(
            p["attn"], norm_apply(cfg, p["norm1"], x), cache["c"], cache["kpe"],
            pos, absorb=cfg.mla_absorb, **_mla_kwargs(cfg))
        cache = {"c": cc, "kpe": ckpe}
        x = x + h
        h2in = norm_apply(cfg, p["norm2"], x)
        h2 = (moe.moe_fwd(p["ffn"], h2in, cfg.moe)[0] if kind == "mla_moe"
              else _mlp_fwd(cfg, p["ffn"], h2in))
        return x + h2, cache
    if kind == "mamba":
        h, ssm, conv = mamba2.mamba2_decode(
            p["mixer"], norm_apply(cfg, p["norm1"], x), cache["ssm"],
            cache["conv"], cfg.ssm)
        return x + h, {"ssm": ssm, "conv": conv}
    if kind == "rwkv":
        n1 = norm_apply(cfg, p["norm1"], x)
        h, wkv, tm_prev = rwkv6.rwkv6_time_mix_decode(
            p["time_mix"], n1, cache["wkv"], cache["tm_prev"], cfg.rwkv)
        x = x + h
        n2 = norm_apply(cfg, p["norm2"], x)
        h2 = rwkv6.rwkv6_channel_mix(p["channel_mix"], n2,
                                     x_prev=cache["cm_prev"])
        return x + h2, {"wkv": wkv, "tm_prev": tm_prev, "cm_prev": n2}
    if kind == "cross_mlp":
        ctx = attention.decode_attention(
            _cross_q(p, cfg, norm_apply(cfg, p["norm1"], x)),
            cache["k"], cache["v"], cache["k"].shape[1])
        b = x.shape[0]
        h = layers.dense(p["attn"]["wo"],
                         ctx.reshape(b, 1, cfg.n_heads * cfg.resolved_head_dim))
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        h2 = _mlp_fwd(cfg, p["ffn"], norm_apply(cfg, p["norm2"], x))
        return x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * h2, cache
    raise ValueError(kind)


def _cross_q(p, cfg, x):
    b = x.shape[0]
    q = layers.dense(p["attn"]["wq"], x)
    return q.reshape(b, 1, cfg.n_heads, cfg.resolved_head_dim)
