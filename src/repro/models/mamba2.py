"""Mamba2 (SSD) layer: chunked matmul-form scan for training/prefill, O(1)
recurrent step for decode. Zamba2's backbone.

State-space recurrence per head h (state size N, head dim P):
    S_t = a_t * S_{t-1} + dt_t * B_t x_t^T          (S: (N, P))
    y_t = C_t @ S_t + D * x_t
with a_t = exp(dt_t * A) (scalar per head per step, A < 0).

Chunked (SSD) evaluation over chunks of length L turns the recurrence into
MXU-friendly matmuls: an intra-chunk (L x L) masked "attention" against
decay weights plus an inter-chunk state carried by a lax.scan -- the same
decomposition as Mamba-2's SSD algorithm (arXiv:2405.21060), adapted to
dense jnp (the (L x L) tile is the VMEM-sized working set).

Includes the depthwise causal conv (width 4) over [x, B, C] and the gated
RMSNorm output stage.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_inner: int           # expansion * d_model
    n_heads: int           # d_inner / head_dim
    state_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128


def mamba2_init(key, d_model: int, cfg: Mamba2Config, dtype):
    ks = jax.random.split(key, 4)
    di, h, n, g = cfg.d_inner, cfg.n_heads, cfg.state_dim, cfg.n_groups
    conv_dim = di + 2 * g * n
    proj_out = 2 * di + 2 * g * n + h   # x, z, B, C, dt
    return {
        "in_proj": layers.dense_init(ks[0], d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim), jnp.float32)
                   * cfg.conv_width ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": layers.rmsnorm_init(di, dtype),
        "out_proj": layers.dense_init(ks[2], di, d_model, dtype),
    }


def _split_proj(proj, cfg: Mamba2Config):
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.state_dim, cfg.n_heads
    x, z, bb, cc, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    return x, z, bb, cc, dt


def _causal_conv(seq, w, b, prev=None):
    """Depthwise causal conv. seq: (B, S, C); w: (W, C); prev: (B, W-1, C)."""
    width = w.shape[0]
    if prev is None:
        prev = jnp.zeros((seq.shape[0], width - 1, seq.shape[-1]), seq.dtype)
    padded = jnp.concatenate([prev, seq], axis=1)
    out = sum(padded[:, i:i + seq.shape[1]] * w[i] for i in range(width))
    new_prev = padded[:, -(width - 1):] if width > 1 else prev
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(seq.dtype), new_prev


def mamba2_fwd(params, x_in, cfg: Mamba2Config, *, initial_state=None,
               conv_state=None, return_state: bool = False):
    """x_in: (B, S, d_model). Chunked SSD scan.

    Returns out, or (out, (ssm_state, conv_state)) when return_state
    (prefill needs the states to seed decode).
    """
    b, s, _ = x_in.shape
    di, h, n, g = cfg.d_inner, cfg.n_heads, cfg.state_dim, cfg.n_groups
    p = di // h
    hg = h // g

    proj = layers.dense(params["in_proj"], x_in)
    x, z, bb, cc, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([x, bb, cc], axis=-1)
    conv_out, conv_state_new = _causal_conv(conv_in, params["conv_w"],
                                            params["conv_b"], conv_state)
    x, bb, cc = jnp.split(conv_out, [di, di + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    a_neg = -jnp.exp(params["A_log"])                                  # (H,)
    loga = dt * a_neg                                                  # log decay

    lc = min(cfg.chunk, s)
    while s % lc:
        lc -= 1
    nc = s // lc
    xh = x.reshape(b, nc, lc, h, p).astype(jnp.float32)
    bh = bb.reshape(b, nc, lc, g, n).astype(jnp.float32)
    ch = cc.reshape(b, nc, lc, g, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, lc, h)
    logac = loga.reshape(b, nc, lc, h)

    cum = jnp.cumsum(logac, axis=2)                                    # (B,nc,L,H)

    # Intra-chunk: scores[t, s'] = (C_t . B_s') * exp(cum_t - cum_s') * dt_s'
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]                # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((lc, lc), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bclgn,bcsgn->bclsg", ch, bh)                      # (B,nc,L,L,G)
    cb = jnp.repeat(cb, hg, axis=-1)                                   # -> (...,H)
    scores = cb * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", scores, xh)

    # Chunk-end states: S_c = sum_t exp(cum_L - cum_t) dt_t B_t x_t^T
    dec_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                      # (B,nc,L,H)
    b_rep = jnp.repeat(bh, hg, axis=3)                                 # (B,nc,L,H,N)
    s_chunk = jnp.einsum("bclhn,bclhp->bchnp",
                         b_rep, xh * (dtc * dec_to_end)[..., None])

    # Inter-chunk scan: carry state, emit state at chunk *start*.
    chunk_decay = jnp.exp(cum[:, :, -1, :])                            # (B,nc,H)

    def scan_fn(state, inp):
        s_c, dec = inp                                                 # (B,H,N,P), (B,H)
        out_state = state
        new_state = state * dec[..., None, None] + s_c
        return new_state, out_state

    init = (jnp.zeros((b, h, n, p), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    final_state, s_starts = lax.scan(
        scan_fn, init,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_starts = jnp.moveaxis(s_starts, 0, 1)                            # (B,nc,H,N,P)

    c_rep = jnp.repeat(ch, hg, axis=3)                                 # (B,nc,L,H,N)
    y_inter = jnp.einsum("bclhn,bchnp->bclhp",
                         c_rep * jnp.exp(cum)[..., None], s_starts)

    y = (y_intra + y_inter).reshape(b, s, di)
    y = y + (x.astype(jnp.float32).reshape(b, s, h, p)
             * params["D"][None, None, :, None]).reshape(b, s, di)
    y = y.astype(x_in.dtype)
    y = layers.rmsnorm(params["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(x_in.dtype)
    out = layers.dense(params["out_proj"], y)
    if return_state:
        return out, (final_state, conv_state_new)
    return out


def mamba2_decode(params, x_in, state, conv_state, cfg: Mamba2Config):
    """One token. x_in: (B, 1, d_model); state: (B, H, N, P) f32."""
    b = x_in.shape[0]
    di, h, n, g = cfg.d_inner, cfg.n_heads, cfg.state_dim, cfg.n_groups
    p = di // h
    hg = h // g

    proj = layers.dense(params["in_proj"], x_in)
    x, z, bb, cc, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([x, bb, cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"], conv_state)
    x, bb, cc = jnp.split(conv_out, [di, di + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    a = jnp.exp(dt * -jnp.exp(params["A_log"]))                             # (B,H)
    xh = x.reshape(b, h, p).astype(jnp.float32)
    b_rep = jnp.repeat(bb.reshape(b, g, n), hg, axis=1)                     # (B,H,N)
    c_rep = jnp.repeat(cc.reshape(b, g, n), hg, axis=1)

    state = state * a[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", b_rep, xh * dt[..., None])
    y = jnp.einsum("bhn,bhnp->bhp", c_rep, state)
    y = y + xh * params["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(x_in.dtype)
    y = layers.rmsnorm(params["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(x_in.dtype)
    return layers.dense(params["out_proj"], y), state, conv_state


def mamba2_ref_recurrent(params, x_in, cfg: Mamba2Config):
    """Step-by-step oracle for testing the chunked path."""
    b, s, _ = x_in.shape
    h, n, p = cfg.n_heads, cfg.state_dim, cfg.d_inner // cfg.n_heads
    state = jnp.zeros((b, h, n, p), jnp.float32)
    conv_state = jnp.zeros((b, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.n_groups * cfg.state_dim),
                           x_in.dtype)
    outs = []
    for t in range(s):
        o, state, conv_state = mamba2_decode(params, x_in[:, t:t + 1], state,
                                             conv_state, cfg)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
