"""Losses: masked softmax cross-entropy with optional z-loss.

Logits arrive in f32 (unembed promotes); the logsumexp path is stable for
vocab up to 152k (qwen2). z-loss (PaLM-style) keeps the partition function
bounded for bf16 training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, targets, mask=None, z_loss: float = 0.0):
    """logits: (..., V) f32; targets: (...) int32; mask broadcastable.

    Sharding note: the gold logit is extracted with an iota-compare masked
    reduce (not take_along_axis) so a vocab-sharded logits tensor reduces
    shard-locally under GSPMD instead of being all-gathered.

    Returns (mean_loss, metrics).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    onehot = (vocab_iota == targets[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((logits.argmax(-1) == targets) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc,
                  "tokens": mask.sum(), "z": jnp.abs(lse).mean()}


def lm_loss(logits, batch, z_loss: float = 0.0):
    """Next-token loss for LM batches ({'tokens','targets'[,'loss_mask']})."""
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    return softmax_xent(logits, targets, mask, z_loss)


def chunked_lm_loss(unembed_fn, hidden, batch, *, chunk: int = 512,
                    z_loss: float = 0.0):
    """Sequence-chunked loss: never materializes the full (B, S, V) logits.

    At S=4096, V=128k, B=16/device, f32 logits are ~34 GB/device -- the
    dominant training-memory term. Scanning the unembed+xent over sequence
    chunks bounds the live logits tensor to (B, chunk, V_shard):
    chunk=512 => ~260 MB/device with a 16-way vocab-sharded head.

    ``unembed_fn(x_chunk) -> logits_chunk`` closes over the (sharded) head.
    """
    b, s = batch["targets"].shape
    c = min(chunk, s)
    while s % c:
        c -= 1
    n = s // c
    targets = batch["targets"].reshape(b, n, c)
    mask = batch.get("loss_mask")
    mask = (jnp.ones((b, s), jnp.float32) if mask is None
            else mask.astype(jnp.float32)).reshape(b, n, c)
    hid = hidden.reshape(b, n, c, hidden.shape[-1])

    def body(acc, ix):
        logits = unembed_fn(hid[:, ix]).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        onehot = iota == targets[:, ix][..., None]
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        nll = lse - gold
        if z_loss:
            nll = nll + z_loss * jnp.square(lse)
        m = mask[:, ix]
        correct = (logits.argmax(-1) == targets[:, ix]).astype(jnp.float32)
        return (acc[0] + (nll * m).sum(), acc[1] + (correct * m).sum(),
                acc[2] + m.sum(), acc[3] + jnp.abs(lse).sum()), None

    # remat: recompute each chunk's logits in the backward instead of
    # saving n stacked (B, chunk, V_shard) f32 tensors (~4 GiB measured).
    (nll_sum, acc_sum, tok, z_sum), _ = jax.lax.scan(
        jax.checkpoint(body),
        (jnp.float32(0), jnp.float32(0), jnp.float32(0), jnp.float32(0)),
        jnp.arange(n))
    denom = jnp.maximum(tok, 1.0)
    loss = nll_sum / denom
    return loss, {"loss": loss, "accuracy": acc_sum / denom, "tokens": tok,
                  "z": z_sum / jnp.maximum(b * s, 1)}
