"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Recurrence per head (head dim D, matrix state S: (D, D)):
    wkv_t = S_{t-1} + diag(u) k_t v_t^T
    y_t   = r_t @ wkv_t
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(w0 + lora_w(x'_t))) -- the *data-dependent* decay that
distinguishes RWKV6; the decay LoRA (rank 64) is a tall-and-skinny GEMM
pair served by the TSM2X dispatcher at large batch*seq.

Two evaluation paths:
* ``rwkv6_time_mix`` -- chunked matmul form (training/prefill): intra-chunk
  (L x L) decay-weighted scores + inter-chunk state scan, mirroring the
  chunked-GLA decomposition. This is the MXU-friendly formulation.
* ``rwkv6_time_mix_ref`` -- per-step lax.scan oracle (tests + a perf
  baseline for §Perf: the step form has O(1) arithmetic intensity, the
  chunked form lifts it by ~L).

Token shift: RWKV's x'_t = lerp(x_t, x_{t-1}, mu) with learned per-channel
mu for each of r/k/v/w/g (the full RWKV6 uses a LoRA for the lerp too; we
keep the per-channel form and put the LoRA on the decay, the part the
paper's data-dependence claim rests on).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    n_heads: int
    head_dim: int = 64
    decay_lora_rank: int = 64
    chunk: int = 64


def rwkv6_time_mix_init(key, d_model: int, cfg: RWKV6Config, dtype):
    ks = jax.random.split(key, 8)
    d = d_model
    h, dh = cfg.n_heads, cfg.head_dim
    assert h * dh == d
    return {
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),          # slow default decay
        "w_lora": layers.lora_init(ks[1], d, d, cfg.decay_lora_rank, dtype),
        "u": jnp.zeros((h, dh), jnp.float32),             # per-head bonus
        "wr": layers.dense_init(ks[2], d, d, dtype),
        "wk": layers.dense_init(ks[3], d, d, dtype),
        "wv": layers.dense_init(ks[4], d, d, dtype),
        "wg": layers.dense_init(ks[5], d, d, dtype),
        "wo": layers.dense_init(ks[6], d, d, dtype),
        "ln_x": layers.layernorm_init(d, dtype),          # per-head group norm
    }


def _token_shift(x, prev):
    """x: (B,S,d); prev: (B,1,d) last token of previous segment (or zeros)."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _projections(params, x, x_prev):
    xx = _token_shift(x, x_prev)
    mu = params["mu"].astype(x.dtype)
    mix = [x + (xx - x) * mu[i] for i in range(5)]
    xr, xk, xv, xw, xg = mix
    r = layers.dense(params["wr"], xr)
    k = layers.dense(params["wk"], xk)
    v = layers.dense(params["wv"], xv)
    g = layers.dense(params["wg"], xg)
    logw = -jnp.exp(params["w0"] +
                    layers.lora_apply(params["w_lora"], xw).astype(jnp.float32))
    return r, k, v, g, logw                               # logw <= 0


def _headed(x, h, dh):
    return x.reshape(*x.shape[:-1], h, dh)


def _out_stage(params, y, g, h, dh):
    b, s = y.shape[0], y.shape[1]
    y = y.reshape(b, s, h * dh).astype(g.dtype)
    y = layers.layernorm(params["ln_x"], y)
    return layers.dense(params["wo"], y * jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype))


def rwkv6_time_mix(params, x, cfg: RWKV6Config, *, state=None, x_prev=None,
                   return_state: bool = False):
    """Chunked evaluation. x: (B,S,d). state: (B,H,D,D) f32."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    if x_prev is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
    r, k, v, g, logw = _projections(params, x, x_prev)
    rh = _headed(r, h, dh).astype(jnp.float32)
    kh = _headed(k, h, dh).astype(jnp.float32)
    vh = _headed(v, h, dh).astype(jnp.float32)
    lw = _headed(logw, h, dh)                              # (B,S,H,D)

    lc = min(cfg.chunk, s)
    while s % lc:
        lc -= 1
    nc = s // lc
    rc = rh.reshape(b, nc, lc, h, dh)
    kc = kh.reshape(b, nc, lc, h, dh)
    vc = vh.reshape(b, nc, lc, h, dh)
    lwc = lw.reshape(b, nc, lc, h, dh)
    cum = jnp.cumsum(lwc, axis=2)                          # inclusive

    # Intra-chunk: for s' < t: A[t,s'] = sum_d r_t[d] k_s'[d] exp(cum_{t-1} - cum_{s'})[d]
    # (decay applies on steps s'+1 .. t-1; y_t reads S_{t-1}).
    cum_tm1 = cum - lwc                                    # cum_{t-1}
    # scores via exp-trick: exp(cum_tm1_t - cum_s') = exp(cum_tm1_t) * exp(-cum_s')
    # is numerically unsafe; use pairwise difference instead (L is small).
    diff = cum_tm1[:, :, :, None, :, :] - cum[:, :, None, :, :, :]   # (B,nc,L,L,H,D)
    strict = jnp.tril(jnp.ones((lc, lc), bool), k=-1)
    dec = jnp.where(strict[None, None, :, :, None, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcthd,bcshd,bctshd->bctsh", rc, kc, dec)
    y_intra = jnp.einsum("bctsh,bcshd->bcthd", scores, vc)
    # Diagonal (current token) via bonus u:
    y_diag = (rc * kc * params["u"][None, None, None]).sum(-1, keepdims=True) * vc
    y_intra = y_intra + y_diag

    # Chunk-end state contributions: sum_t exp(cum_L - cum_t) k_t v_t^T
    dec_end = jnp.exp(cum[:, :, -1:, :, :] - cum)          # (B,nc,L,H,D)
    s_chunk = jnp.einsum("bcthd,bcthe->bchde", kc * dec_end, vc)
    chunk_decay = jnp.exp(cum[:, :, -1])                   # (B,nc,H,D)

    def scan_fn(st, inp):
        sc, dec_c = inp
        out_st = st
        return st * dec_c[..., None] + sc, out_st

    init = jnp.zeros((b, h, dh, dh), jnp.float32) if state is None else state
    final_state, s_starts = lax.scan(
        scan_fn, init, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_starts = jnp.moveaxis(s_starts, 0, 1)                # (B,nc,H,D,D)

    # Inter-chunk: y_t += r_t (exp(cum_{t-1}) .) S_in
    r_dec = rc * jnp.exp(cum_tm1)
    y_inter = jnp.einsum("bcthd,bchde->bcthe", r_dec, s_starts)

    y = (y_intra + y_inter).reshape(b, s, h, dh)
    out = _out_stage(params, y, g, h, dh)
    if return_state:
        return out, (final_state, x[:, -1:])
    return out


def rwkv6_time_mix_ref(params, x, cfg: RWKV6Config):
    """Per-step oracle (also the latency-bound perf baseline)."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    r, k, v, g, logw = _projections(params, x, jnp.zeros((b, 1, d), x.dtype))
    rh = _headed(r, h, dh).astype(jnp.float32)
    kh = _headed(k, h, dh).astype(jnp.float32)
    vh = _headed(v, h, dh).astype(jnp.float32)
    wh = jnp.exp(_headed(logw, h, dh))

    def step(st, inp):
        rt, kt, vt, wt = inp                                # (B,H,D)
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        wkv = st + params["u"][None, :, :, None] * kv
        # repro: allow-raw-param-matmul (wkv is the recurrent attention
        # STATE, not a parameter -- the name trips the weight heuristic)
        yt = jnp.einsum("bhd,bhde->bhe", rt, wkv)
        return st * wt[..., None] + kv, yt

    _, ys = lax.scan(step, jnp.zeros((b, h, dh, dh), jnp.float32),
                     (jnp.moveaxis(rh, 1, 0), jnp.moveaxis(kh, 1, 0),
                      jnp.moveaxis(vh, 1, 0), jnp.moveaxis(wh, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)                              # (B,S,H,D)
    return _out_stage(params, y, g, h, dh)


def rwkv6_time_mix_decode(params, x, state, x_prev, cfg: RWKV6Config):
    """One token. x: (B,1,d); state: (B,H,D,D); x_prev: (B,1,d)."""
    h, dh = cfg.n_heads, cfg.head_dim
    r, k, v, g, logw = _projections(params, x, x_prev)
    rt = _headed(r, h, dh)[:, 0].astype(jnp.float32)
    kt = _headed(k, h, dh)[:, 0].astype(jnp.float32)
    vt = _headed(v, h, dh)[:, 0].astype(jnp.float32)
    wt = jnp.exp(_headed(logw, h, dh)[:, 0])
    kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
    wkv = state + params["u"][None, :, :, None] * kv
    # repro: allow-raw-param-matmul (wkv is recurrent state; see time_mix)
    yt = jnp.einsum("bhd,bhde->bhe", rt, wkv)[:, None]      # (B,1,H,D)
    new_state = state * wt[..., None] + kv
    out = _out_stage(params, yt, g, h, dh)
    return out, new_state, x


# ---------------------------------------------------------------------------
# Channel mix (RWKV's FFN)
# ---------------------------------------------------------------------------

def rwkv6_channel_mix_init(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "mu": jax.random.uniform(ks[0], (2, d_model), jnp.float32).astype(dtype),
        "wk": layers.dense_init(ks[1], d_model, d_ff, dtype),
        "wv": layers.dense_init(ks[2], d_ff, d_model, dtype),
        "wr": layers.dense_init(jax.random.fold_in(key, 7), d_model, d_model, dtype),
    }


def rwkv6_channel_mix(params, x, *, x_prev=None, return_state: bool = False):
    b, s, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
    xx = _token_shift(x, x_prev)
    mu = params["mu"].astype(x.dtype)
    xk = x + (xx - x) * mu[0]
    xr = x + (xx - x) * mu[1]
    k = jnp.square(jax.nn.relu(layers.dense(params["wk"], xk).astype(jnp.float32)))
    r = jax.nn.sigmoid(layers.dense(params["wr"], xr).astype(jnp.float32))
    out = (r * layers.dense(params["wv"], k.astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)
    if return_state:
        return out, x[:, -1:]
    return out
