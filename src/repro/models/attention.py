"""Attention: chunked online-softmax (flash-style in pure JAX), GQA, SWA,
MLA (DeepSeek latent attention), cross-attention, and decode paths.

Why chunked: materializing (B, H, S, S) scores at S=32k would need ~17 GB
per device; the two-level chunk scan keeps the live score tile at
(q_chunk x kv_chunk) with exact online-softmax accumulation (f32 stats).

Causality at chunk granularity: fully-masked chunk pairs are still
computed and zeroed (static grid). This ~2x waste on causal prefill is the
*paper-faithful baseline*; the §Perf hillclimb evaluates block-skipping.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers

_NEG = -1e30


# ---------------------------------------------------------------------------
# Core chunked attention
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                      q_offset=0, kv_valid_len=None,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      softmax_scale: float | None = None):
    """q: (B, Sq, H, D); k, v: (B, Skv, Hk, Dk/Dv); H % Hk == 0.

    ``q_offset``: global position of q[0] (prefill continuation / decode).
    ``kv_valid_len``: mask out cache slots >= this (scalar or (B,)).
    Supports Dk != Dv (MLA attends with 192-dim keys, 128-dim values).
    """
    b, sq, h, dk = q.shape
    _, skv, hk, _ = k.shape
    dv = v.shape[-1]
    g = h // hk
    scale = softmax_scale if softmax_scale is not None else dk ** -0.5

    # Pad sequences to chunk multiples rather than shrinking the chunk: a
    # divisor-shrink fallback degenerates to chunk=1 on prime lengths
    # (vision_seq=1601 produced a 1601-step kv scan per cross-attn layer —
    # caught by the roofline table, EXPERIMENTS.md §Perf).
    if kv_valid_len is None:
        kv_valid = jnp.full((b,), skv, jnp.int32)
    else:
        kv_valid = jnp.broadcast_to(jnp.asarray(kv_valid_len, jnp.int32), (b,))

    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    sq_pad = -(-sq // qc) * qc
    skv_pad = -(-skv // kc) * kc
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
        kv_valid = jnp.minimum(kv_valid, skv)   # padded slots masked out
    nq, nk = sq_pad // qc, skv_pad // kc

    qs = q.reshape(b, nq, qc, hk, g, dk)
    ks = k.reshape(b, nk, kc, hk, dk)
    vs = v.reshape(b, nk, kc, hk, dv)

    def q_step(_, qi_and_chunk):
        qi, q_blk = qi_and_chunk                      # q_blk: (b, qc, hk, g, dk)
        q_pos = q_offset + qi * qc + jnp.arange(qc)   # (qc,)

        # NB: kv_step is remat'd (see lax.scan below). Without it, the
        # backward saves every f32 score/probability tile stacked over both
        # scan levels -- the full S^2 attention backward (~28 GiB/device at
        # train_4k, measured) that chunking exists to avoid. With remat,
        # only the (m, l, acc) carries are saved and tiles are recomputed.
        def kv_step(carry, ki_and_blk):
            m_run, l_run, acc = carry
            ki, k_blk, v_blk = ki_and_blk
            kv_pos = ki * kc + jnp.arange(kc)         # (kc,)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            mask = kv_pos[None, :] <= q_pos[:, None] if causal else \
                jnp.ones((qc, kc), bool)
            if window is not None:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            mask = mask[None] & (kv_pos[None, None, :] < kv_valid[:, None, None])
            mask = mask[:, None, None]                # (b,1,1,qc,kc)
            s = jnp.where(mask, s, _NEG)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None]) * mask  # zero fully-masked rows
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hk, g, qc), _NEG, jnp.float32)
        l0 = jnp.zeros((b, hk, g, qc), jnp.float32)
        a0 = jnp.zeros((b, hk, g, qc, dv), jnp.float32)
        (m_f, l_f, acc_f), _ = lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0)))
        out = acc_f / jnp.maximum(l_f, 1e-30)[..., None]   # (b,hk,g,qc,dv)
        return None, jnp.einsum("bhgqd->bqhgd", out)

    _, outs = lax.scan(q_step, None, (jnp.arange(nq), jnp.moveaxis(qs, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq_pad, h, dv)[:, :sq]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, window: int | None = None,
                     softmax_scale: float | None = None):
    """Single-step decode: q (B, 1, H, D) against a (B, S, Hk, D) cache.

    ``cur_len``: number of valid cache slots per batch element (the new
    token's own k/v must already be written at cur_len - 1).
    """
    b, _, h, dk = q.shape
    _, s, hk, _ = k_cache.shape
    g = h // hk
    scale = softmax_scale if softmax_scale is not None else dk ** -0.5
    qh = q.reshape(b, hk, g, dk)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qh.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(s)
    lens = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    mask = pos[None, :] < lens[:, None]
    if window is not None:
        mask &= pos[None, :] >= lens[:, None] - window
    scores = jnp.where(mask[:, None, None, :], scores, _NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA self-attention module
# ---------------------------------------------------------------------------

def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             *, qkv_bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": layers.dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": layers.dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": layers.dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def gqa_project_qkv(params, x, positions, *, n_heads, n_kv, head_dim,
                    rope_theta=10000.0, rope_fraction=1.0):
    b, s, _ = x.shape
    q = layers.dense(params["wq"], x)
    k = layers.dense(params["wk"], x)
    v = layers.dense(params["wv"], x)
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv, head_dim)
    v = v.reshape(b, s, n_kv, head_dim)
    if rope_fraction > 0:
        q = layers.apply_rope(q, positions, theta=rope_theta, fraction=rope_fraction)
        k = layers.apply_rope(k, positions, theta=rope_theta, fraction=rope_fraction)
    return q, k, v


def gqa_fwd(params, x, *, n_heads, n_kv, head_dim, causal=True,
            window=None, rope_theta=10000.0, rope_fraction=1.0,
            q_chunk=1024, kv_chunk=1024, positions=None,
            kv_override=None):
    """Full-sequence attention (train / prefill). Returns (out, (k, v)).

    ``kv_override``: (k, v) to attend over instead of self-projections
    (cross-attention passes pre-projected image keys/values).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = gqa_project_qkv(params, x, positions, n_heads=n_heads, n_kv=n_kv,
                              head_dim=head_dim, rope_theta=rope_theta,
                              rope_fraction=rope_fraction)
    if kv_override is not None:
        k, v = kv_override
    ctx = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = layers.dense(params["wo"], ctx.reshape(b, s, n_heads * head_dim))
    return out, (k, v)


def gqa_decode(params, x, cache_k, cache_v, pos, *, n_heads, n_kv, head_dim,
               window=None, rope_theta=10000.0, rope_fraction=1.0,
               ring_window: int | None = None):
    """One-token decode. x: (B, 1, d). pos: scalar current position.

    Writes the new k/v at slot ``pos`` (or ``pos % ring_window`` for SWA
    ring caches) and attends over valid slots. Returns (out, cache_k, cache_v).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = gqa_project_qkv(params, x, positions, n_heads=n_heads, n_kv=n_kv,
                              head_dim=head_dim, rope_theta=rope_theta,
                              rope_fraction=rope_fraction)
    slot = pos if ring_window is None else pos % ring_window
    cache_k = lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    if ring_window is None:
        ctx = decode_attention(q, cache_k, cache_v, pos + 1, window=window)
    else:
        # Ring cache: all slots <= min(pos+1, ring) are valid; positions wrap,
        # and softmax is permutation-invariant so slot order is irrelevant.
        valid = jnp.minimum(pos + 1, ring_window)
        ctx = decode_attention(q, cache_k, cache_v, valid)
    out = layers.dense(params["wo"], ctx.reshape(b, 1, n_heads * head_dim))
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, d_model: int, n_heads: int, *, q_lora: int, kv_lora: int,
             nope_dim: int, rope_dim: int, v_dim: int, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return {
        "wdq": layers.dense_init(ks[0], d_model, q_lora, dtype),
        "q_norm": layers.rmsnorm_init(q_lora, dtype),
        "wuq": layers.dense_init(ks[1], q_lora, n_heads * (nope_dim + rope_dim), dtype),
        "wdkv": layers.dense_init(ks[2], d_model, kv_lora, dtype),
        "kv_norm": layers.rmsnorm_init(kv_lora, dtype),
        "wukv": layers.dense_init(ks[3], kv_lora, n_heads * (nope_dim + v_dim), dtype),
        "wkr": layers.dense_init(ks[4], d_model, rope_dim, dtype),
        "wo": layers.dense_init(ks[5], n_heads * v_dim, d_model, dtype),
    }


def _mla_q(params, x, positions, *, n_heads, nope_dim, rope_dim, rope_theta):
    b, s, _ = x.shape
    cq = layers.rmsnorm(params["q_norm"], layers.dense(params["wdq"], x))
    q = layers.dense(params["wuq"], cq).reshape(b, s, n_heads, nope_dim + rope_dim)
    q_nope, q_pe = q[..., :nope_dim], q[..., nope_dim:]
    q_pe = layers.apply_rope(q_pe, positions, theta=rope_theta)
    return q_nope, q_pe


def _mla_latent(params, x, positions, *, rope_theta):
    c = layers.rmsnorm(params["kv_norm"], layers.dense(params["wdkv"], x))
    k_pe = layers.dense(params["wkr"], x)[:, :, None, :]      # (b,s,1,rope)
    k_pe = layers.apply_rope(k_pe, positions, theta=rope_theta)
    return c, k_pe


def mla_fwd(params, x, *, n_heads, nope_dim, rope_dim, v_dim,
            rope_theta=10000.0, causal=True, q_chunk=1024, kv_chunk=1024,
            positions=None):
    """Full-sequence MLA. Returns (out, (c_latent, k_pe)) -- the latent cache."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q_nope, q_pe = _mla_q(params, x, positions, n_heads=n_heads,
                          nope_dim=nope_dim, rope_dim=rope_dim,
                          rope_theta=rope_theta)
    c, k_pe = _mla_latent(params, x, positions, rope_theta=rope_theta)
    kv = layers.dense(params["wukv"], c).reshape(b, s, n_heads, nope_dim + v_dim)
    k_nope, v = kv[..., :nope_dim], kv[..., nope_dim:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (*k_pe.shape[:2], n_heads, rope_dim))], -1)
    q = jnp.concatenate([q_nope, q_pe], -1)
    scale = (nope_dim + rope_dim) ** -0.5
    ctx = chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                            kv_chunk=kv_chunk, softmax_scale=scale)
    out = layers.dense(params["wo"], ctx.reshape(b, s, n_heads * v_dim))
    return out, (c, k_pe[:, :, 0, :])


def mla_decode(params, x, cache_c, cache_kpe, pos, *, n_heads, nope_dim,
               rope_dim, v_dim, rope_theta=10000.0, absorb: bool = True):
    """One-token MLA decode over the latent cache.

    ``absorb=True`` (beyond-paper optimization, recorded in §Perf): fold
    W_uk into the query and W_uv into the output so attention runs directly
    in the 512-dim latent space -- O(S * kv_lora) per step instead of
    re-expanding the whole cache to per-head k/v (O(S * H * (nope+v))).
    """
    b = x.shape[0]
    kv_lora = cache_c.shape[-1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_pe = _mla_q(params, x, positions, n_heads=n_heads,
                          nope_dim=nope_dim, rope_dim=rope_dim,
                          rope_theta=rope_theta)
    c_new, kpe_new = _mla_latent(params, x, positions, rope_theta=rope_theta)
    cache_c = lax.dynamic_update_slice_in_dim(cache_c, c_new, pos, axis=1)
    cache_kpe = lax.dynamic_update_slice_in_dim(cache_kpe, kpe_new[:, :, 0, :], pos, axis=1)
    scale = (nope_dim + rope_dim) ** -0.5
    s_len = cache_c.shape[1]
    wukv = params["wukv"].reshape(kv_lora, n_heads, nope_dim + v_dim)
    wuk, wuv = wukv[..., :nope_dim], wukv[..., nope_dim:]

    if absorb:
        # q_c[b,h,l] = sum_d q_nope[b,h,d] * wuk[l,h,d]
        # repro: allow-raw-param-matmul (absorbed decode: the 3-D per-head
        # W_uk slice folds into a batch-1 f32 einsum -- no 2-D tsmm form,
        # and per-step shapes never classify tall-skinny)
        q_c = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(jnp.float32),
                         wuk.astype(jnp.float32))
        s_nope = jnp.einsum("bhl,bsl->bhs", q_c, cache_c.astype(jnp.float32))
        s_pe = jnp.einsum("bhd,bsd->bhs", q_pe[:, 0].astype(jnp.float32),
                          cache_kpe.astype(jnp.float32))
        scores = (s_nope + s_pe) * scale
        mask = jnp.arange(s_len)[None, None, :] <= pos
        scores = jnp.where(mask, scores, _NEG)
        p = jax.nn.softmax(scores, axis=-1)
        ctx_c = jnp.einsum("bhs,bsl->bhl", p, cache_c.astype(jnp.float32))
        # repro: allow-raw-param-matmul (absorbed decode W_uv fold; see wuk)
        ctx = jnp.einsum("bhl,lhd->bhd", ctx_c, wuv.astype(jnp.float32))
    else:
        # repro: allow-raw-param-matmul (non-absorbed decode re-expands the
        # latent cache through the 3-D per-head W_ukv -- same exemption as
        # the absorbed path's folds above)
        kv = jnp.einsum("bsl,lhd->bshd", cache_c.astype(jnp.float32),
                        wukv.astype(jnp.float32))
        k_nope, v = kv[..., :nope_dim], kv[..., nope_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(cache_kpe[:, :, None, :].astype(jnp.float32),
                                      (*cache_kpe.shape[:2], n_heads, rope_dim))], -1)
        q = jnp.concatenate([q_nope, q_pe], -1)
        ctx = decode_attention(q, k.astype(x.dtype), v.astype(x.dtype), pos + 1,
                               softmax_scale=scale)[:, 0]
    out = layers.dense(params["wo"],
                       ctx.reshape(b, 1, n_heads * v_dim).astype(x.dtype))
    return out, cache_c, cache_kpe
