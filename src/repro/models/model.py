"""LM assembly: stacks blocks into the ten assigned architectures.

Layer stacks are ``lax.scan`` over parameter pytrees stacked on a leading
layer axis -- compile time is O(1) in depth (an 80-layer qwen2-72b lowers
as fast as a 2-layer smoke model), and remat wraps the scan body.

Heterogeneous architectures are expressed as *segments*, each a homogeneous
scan:

* dense/audio:   [attn_mlp x L]
* mixtral:       [attn_moe x L]
* deepseek-v3:   [mla_mlp x 3, mla_moe x (L-3)]
* rwkv6:         [rwkv x L]
* zamba2:        [zamba_group x G] + [mamba x rem] -- each group = `period`
                 Mamba2 layers (inner scan) + the weight-SHARED attention
                 block with a per-group LoRA (scan carries only the LoRA).
* llama3.2-vision: [vlm_group x 8] -- each group = 4 self layers (inner
                 scan) + 1 gated cross-attention layer.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import compat
from repro.models import attention, blocks, layers


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str          # block kind | zamba_group | vlm_group
    n: int             # outer scan length
    inner: int = 0     # inner layers per group


def segments(cfg) -> list[Segment]:
    f = cfg.family
    if f in ("dense", "audio"):
        return [Segment("attn_mlp", cfg.n_layers)]
    if f == "moe":
        if cfg.mla is not None:
            return [Segment("mla_mlp", cfg.first_k_dense),
                    Segment("mla_moe", cfg.n_layers - cfg.first_k_dense)]
        return [Segment("attn_moe", cfg.n_layers)]
    if f == "ssm":
        return [Segment("rwkv", cfg.n_layers)]
    if f == "hybrid":
        g = cfg.n_layers // cfg.hybrid_period
        rem = cfg.n_layers - g * cfg.hybrid_period
        segs = [Segment("zamba_group", g, inner=cfg.hybrid_period)]
        if rem:
            segs.append(Segment("mamba", rem))
        return segs
    if f == "vlm":
        period = cfg.cross_attn_period
        g = cfg.n_layers // period
        return [Segment("vlm_group", g, inner=period - 1)]
    raise ValueError(f)


def _stack_init(key, n: int, init_one):
    return jax.vmap(init_one)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params = {}
    if cfg.input_mode == "frames":
        params["frame_proj"] = {
            "w": layers.dense_init(keys[0], cfg.frame_dim, cfg.d_model, dt)}
        params["embed"] = layers.embedding_init(keys[1], cfg.vocab_size,
                                                cfg.d_model, dt)  # unembed table
    else:
        params["embed"] = layers.embedding_init(keys[1], cfg.vocab_size,
                                                cfg.d_model, dt)
    seg_params = []
    for i, seg in enumerate(segments(cfg)):
        k = jax.random.fold_in(keys[2], i)
        if seg.kind == "zamba_group":
            seg_params.append({
                "mamba": _stack_init(
                    k, seg.n,
                    lambda kk: _stack_init(kk, seg.inner,
                                           lambda k2: blocks.block_init(k2, cfg, "mamba"))),
                "lora_attn": _stack_init(
                    jax.random.fold_in(k, 1), seg.n,
                    lambda kk: layers.lora_init(kk, cfg.d_model, cfg.d_model,
                                                cfg.shared_lora_rank, dt)),
                "lora_ffn": _stack_init(
                    jax.random.fold_in(k, 2), seg.n,
                    lambda kk: layers.lora_init(kk, cfg.d_model, cfg.d_model,
                                                cfg.shared_lora_rank, dt)),
            })
        elif seg.kind == "vlm_group":
            seg_params.append({
                "self": _stack_init(
                    k, seg.n,
                    lambda kk: _stack_init(kk, seg.inner,
                                           lambda k2: blocks.block_init(k2, cfg, "attn_mlp"))),
                "cross": _stack_init(
                    jax.random.fold_in(k, 1), seg.n,
                    lambda kk: blocks.block_init(kk, cfg, "cross_mlp")),
            })
        else:
            seg_params.append(_stack_init(
                k, seg.n, lambda kk, kind=seg.kind: blocks.block_init(kk, cfg, kind)))
    params["segments"] = seg_params
    if cfg.family == "hybrid":
        params["shared_block"] = blocks.block_init(keys[3], cfg, "attn_mlp")
    params["final_norm"] = blocks._norm_init(cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.embedding_init(keys[4], cfg.vocab_size,
                                                  cfg.d_model, dt)
    return params


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

def _embed_input(params, cfg, batch):
    if cfg.input_mode == "frames":
        return layers.dense(params["frame_proj"]["w"], batch["frames"])
    return layers.embed(params["embed"], batch["tokens"])


def _logits(params, cfg, x):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return layers.unembed(head, blocks.norm_apply(cfg, params["final_norm"], x))


def _shared_block_fwd(shared_p, lora_a, lora_f, x, cfg, mode, cache=None, pos=None):
    """Zamba2's weight-shared attention block + per-application LoRA."""
    n1 = blocks.norm_apply(cfg, shared_p["norm1"], x)
    kw = blocks._attn_kwargs(cfg)
    if mode == "train":
        h, _ = attention.gqa_fwd(shared_p["attn"], n1, causal=cfg.causal,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk, **kw)
    elif mode == "prefill":
        h, (k, v) = attention.gqa_fwd(shared_p["attn"], n1, causal=cfg.causal,
                                      q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk, **kw)
        cache = {"k": lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                 "v": lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)}
    else:
        h, ck, cv = attention.gqa_decode(shared_p["attn"], n1, cache["k"],
                                         cache["v"], pos, **kw)
        cache = {"k": ck, "v": cv}
    h = h + layers.lora_apply(lora_a, n1)
    x = x + h
    n2 = blocks.norm_apply(cfg, shared_p["norm2"], x)
    h2 = layers.swiglu(shared_p["ffn"], n2) + layers.lora_apply(lora_f, n2)
    return x + h2, cache


def _zero_metrics(kind):
    if kind in ("attn_moe", "mla_moe"):
        return None  # block produces real metrics
    return {}


def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def _remat_group_size(cfg, n: int) -> int:
    """Largest divisor of n that is <= cfg.remat_group."""
    g = max(1, min(cfg.remat_group, n))
    while n % g:
        g -= 1
    return g


def _scan_layers_remat(cfg, seg_p, x, kind, n: int):
    """Homogeneous layer scan with nested-scan remat: outer scan saves only
    n/g residuals; the inner g-layer scan recomputes in the backward.

    For a 28L model at (16-seq, 4k, d) bf16 activations this turns an 11 GB
    carry-save into 2.8 GB (g=4) -- the measured difference in the dry-run
    iteration log."""
    def inner_body(h, lp):
        # Barrier keeps the f32 upcast of the residual loop-local: without
        # it XLA hoists convert(saved_stack) out of the backward while-loop,
        # materializing an f32 copy of ALL layer saves at once (21 GiB for
        # llama3.2-3b train_4k -- measured via buffer assignment).
        # compat wraps it in a custom_vjp identity on JAX versions where
        # the primitive has no differentiation rule.
        h = compat.optimization_barrier(h)
        out, met = blocks.block_fwd(lp, h, cfg, kind)
        return out, met

    g = _remat_group_size(cfg, n) if cfg.remat else 1
    if g <= 1:
        body = _maybe_remat(cfg, inner_body)
        return lax.scan(body, x, seg_p)

    grouped = jax.tree.map(lambda a: a.reshape(n // g, g, *a.shape[1:]), seg_p)

    def outer_body(h, gp):
        return lax.scan(inner_body, h, gp)

    x, mets = lax.scan(jax.checkpoint(outer_body), x, grouped)
    mets = jax.tree.map(lambda m: m.reshape(n, *m.shape[2:]), mets)
    return x, mets


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------

def forward(params, cfg, batch):
    """Returns (logits f32 (B,S,V), metrics)."""
    x, metrics = forward_hidden(params, cfg, batch)
    return _logits(params, cfg, x), metrics


def unembed_fn(params, cfg):
    """Closure for sequence-chunked loss: x_chunk -> logits_chunk."""
    return lambda xc: _logits(params, cfg, xc)


def forward_hidden(params, cfg, batch):
    """Backbone only: returns (hidden (B,S,d), metrics) -- the training
    path computes the head inside losses.chunked_lm_loss to bound logits
    memory."""
    x = _embed_input(params, cfg, batch)
    extras = {"image_embeds": batch.get("image_embeds")} if cfg.family == "vlm" else None
    all_metrics = []

    for seg, seg_p in zip(segments(cfg), params["segments"]):
        if seg.kind == "zamba_group":
            shared = params["shared_block"]

            def group_body(h, xs, shared=shared):
                gp = xs

                def mamba_body(hh, lp):
                    out, _ = blocks.block_fwd(lp, hh, cfg, "mamba")
                    return out, None

                h, _ = lax.scan(_maybe_remat(cfg, mamba_body), h, gp["mamba"])
                h, _ = _shared_block_fwd(shared, gp["lora_attn"], gp["lora_ffn"],
                                         h, cfg, "train")
                return h, None

            x, _ = lax.scan(group_body, x, seg_p)
        elif seg.kind == "vlm_group":
            def vgroup_body(h, xs):
                def self_body(hh, lp):
                    out, _ = blocks.block_fwd(lp, hh, cfg, "attn_mlp")
                    return out, None

                h, _ = lax.scan(_maybe_remat(cfg, self_body), h, xs["self"])
                h, _ = blocks.block_fwd(xs["cross"], h, cfg, "cross_mlp", extras)
                return h, None

            x, _ = lax.scan(_maybe_remat(cfg, vgroup_body), x, seg_p)
        else:
            x, mets = _scan_layers_remat(cfg, seg_p, x, seg.kind, seg.n)
            if mets:
                all_metrics.append(jax.tree.map(jnp.sum, mets))

    metrics = {}
    for m in all_metrics:
        for k, v in m.items():
            metrics[k] = metrics.get(k, 0.0) + v
    return x, metrics


# ---------------------------------------------------------------------------
# Cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int):
    caches = []
    for seg in segments(cfg):
        if seg.kind == "zamba_group":
            mamba = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (seg.n, seg.inner) + x.shape),
                blocks.cache_init(cfg, "mamba", batch_size, max_len))
            shared = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (seg.n,) + x.shape),
                blocks.cache_init(cfg, "attn_mlp", batch_size, max_len))
            caches.append({"mamba": mamba, "shared": shared})
        elif seg.kind == "vlm_group":
            selfc = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (seg.n, seg.inner) + x.shape),
                blocks.cache_init(cfg, "attn_mlp", batch_size, max_len))
            crossc = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (seg.n,) + x.shape),
                blocks.cache_init(cfg, "cross_mlp", batch_size, max_len))
            caches.append({"self": selfc, "cross": crossc})
        else:
            caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (seg.n,) + x.shape),
                blocks.cache_init(cfg, seg.kind, batch_size, max_len)))
    return caches


def prefill(params, cfg, batch, cache):
    """Returns (last-token logits (B,V), cache)."""
    x = _embed_input(params, cfg, batch)
    extras = {"image_embeds": batch.get("image_embeds")} if cfg.family == "vlm" else None
    new_caches = []

    for seg, seg_p, seg_c in zip(segments(cfg), params["segments"], cache):
        if seg.kind == "zamba_group":
            shared = params["shared_block"]

            def group_body(h, xs, shared=shared):
                gp, gc = xs

                def mamba_body(hh, inner):
                    lp, lc = inner
                    out, nc = blocks.block_prefill(lp, hh, cfg, "mamba", lc)
                    return out, nc

                h, mamba_c = lax.scan(mamba_body, h, (gp["mamba"], gc["mamba"]))
                h, shared_c = _shared_block_fwd(
                    shared, gp["lora_attn"], gp["lora_ffn"], h, cfg, "prefill",
                    cache=gc["shared"])
                return h, {"mamba": mamba_c, "shared": shared_c}

            x, nc = lax.scan(group_body, x, (seg_p, seg_c))
        elif seg.kind == "vlm_group":
            def vgroup_body(h, xs):
                gp, gc = xs

                def self_body(hh, inner):
                    lp, lc = inner
                    out, nc2 = blocks.block_prefill(lp, hh, cfg, "attn_mlp", lc)
                    return out, nc2

                h, self_c = lax.scan(self_body, h, (gp["self"], gc["self"]))
                h, cross_c = blocks.block_prefill(gp["cross"], h, cfg,
                                                  "cross_mlp", gc["cross"], extras)
                return h, {"self": self_c, "cross": cross_c}

            x, nc = lax.scan(vgroup_body, x, (seg_p, seg_c))
        else:
            def body(h, xs, kind=seg.kind):
                lp, lc = xs
                out, nc2 = blocks.block_prefill(lp, h, cfg, kind, lc)
                return out, nc2

            x, nc = lax.scan(body, x, (seg_p, seg_c))
        new_caches.append(nc)

    logits = _logits(params, cfg, x[:, -1:])[:, 0]
    return logits, new_caches


def decode_step(params, cfg, tokens, pos, cache):
    """tokens: (B, 1) int32; pos: scalar int32. Returns (logits (B,V), cache)."""
    x = layers.embed(params["embed"], tokens)
    new_caches = []

    for seg, seg_p, seg_c in zip(segments(cfg), params["segments"], cache):
        if seg.kind == "zamba_group":
            shared = params["shared_block"]

            def group_body(h, xs, shared=shared):
                gp, gc = xs

                def mamba_body(hh, inner):
                    lp, lc = inner
                    out, nc = blocks.block_decode(lp, hh, cfg, "mamba", lc, pos)
                    return out, nc

                h, mamba_c = lax.scan(mamba_body, h, (gp["mamba"], gc["mamba"]))
                h, shared_c = _shared_block_fwd(
                    shared, gp["lora_attn"], gp["lora_ffn"], h, cfg, "decode",
                    cache=gc["shared"], pos=pos)
                return h, {"mamba": mamba_c, "shared": shared_c}

            x, nc = lax.scan(group_body, x, (seg_p, seg_c))
        elif seg.kind == "vlm_group":
            def vgroup_body(h, xs):
                gp, gc = xs

                def self_body(hh, inner):
                    lp, lc = inner
                    out, nc2 = blocks.block_decode(lp, hh, cfg, "attn_mlp", lc, pos)
                    return out, nc2

                h, self_c = lax.scan(self_body, h, (gp["self"], gc["self"]))
                h, cross_c = blocks.block_decode(gp["cross"], h, cfg,
                                                 "cross_mlp", gc["cross"], pos)
                return h, {"self": self_c, "cross": cross_c}

            x, nc = lax.scan(vgroup_body, x, (seg_p, seg_c))
        else:
            def body(h, xs, kind=seg.kind):
                lp, lc = xs
                out, nc2 = blocks.block_decode(lp, h, cfg, kind, lc, pos)
                return out, nc2

            x, nc = lax.scan(body, x, (seg_p, seg_c))
        new_caches.append(nc)

    logits = _logits(params, cfg, x)[:, 0]
    return logits, new_caches
