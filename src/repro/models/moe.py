"""Mixture-of-Experts: top-k routing with sort-based capacity dispatch.

Scales to DeepSeek-V3's 256 experts: the classic one-hot dispatch tensor
(T x E x C) would be ~40 TB at T=64k tokens; instead we sort the (token,
expert) assignment list and scatter into a dense (E, C, d) buffer -- O(T*k)
bookkeeping + O(E*C*d) compute, the standard dropping formulation
(GShard-style capacity, tokens past capacity fall through on the residual).

Routers:
* ``softmax`` (Mixtral): softmax over E, top-k, renormalize selected.
* ``sigmoid`` (DeepSeek-V3): sigmoid scores; selection adds the
  aux-loss-free balancing bias (bias affects *selection only*, not the
  combine weights); selected weights renormalized to sum 1.

Expert parallelism: the (E, ...) axes of expert weights and the (E, C, d)
buffer shard over the mesh 'model' axis (see distributed/sharding.py);
dispatch/combine scatters become all-to-alls under GSPMD.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0          # defaults to d_ff_expert * n_shared
    router: str = "softmax"        # 'softmax' | 'sigmoid'
    capacity_factor: float = 1.25
    routed_scale: float = 1.0      # DeepSeek scales routed output by 2.5
    # Dispatch groups: tokens route within their group only (set to the DP
    # shard count so sort/scatter stay shard-local under GSPMD -- a global
    # argsort over the sharded token axis otherwise gathers the world:
    # 224 GiB/device measured on deepseek-v3 prefill_32k).
    dispatch_groups: int = 1


def moe_init(key, d_model: int, cfg: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_ff_expert
    params = {
        "router_w": layers.dense_init(ks[0], d_model, e, jnp.float32),
        "router_bias": jnp.zeros((e,), jnp.float32),
        # nested under "experts" so sharding rules can EP-shard these and
        # TP-shard dense "ffn/w_*" without path ambiguity
        "experts": {
            "w_gate": (jax.random.normal(ks[1], (e, d_model, f), jnp.float32)
                       * d_model ** -0.5).astype(dtype),
            "w_up": (jax.random.normal(ks[2], (e, d_model, f), jnp.float32)
                     * d_model ** -0.5).astype(dtype),
            "w_down": (jax.random.normal(ks[3], (e, f, d_model), jnp.float32)
                       * f ** -0.5).astype(dtype),
        },
    }
    if cfg.n_shared:
        d_sh = cfg.d_ff_shared or cfg.d_ff_expert * cfg.n_shared
        params["shared"] = layers.swiglu_init(ks[4], d_model, d_sh, dtype)
    return params


def route(params, xt, cfg: MoEConfig):
    """xt: (T, d) -> (weights (T,k) f32, expert_ids (T,k) i32, probs (T,E))."""
    logits = layers.dense(params["router_w"].astype(xt.dtype), xt).astype(jnp.float32)
    if cfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["router_bias"][None, :]   # bias: selection only
        _, idx = lax.top_k(sel, cfg.top_k)
        w = jnp.take_along_axis(scores, idx, axis=1)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = lax.top_k(probs, cfg.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx, probs


def _dispatch_indices(se, stok, sw, e: int, cap: int):
    """One group's sorted entries -> (tok_buf (E*C,), w_buf (E*C,), counts).

    Index-based: only int32 indices and f32 weights are scattered; the
    activation gather happens later at (E, C, d) granularity, so no
    (T*k, d) data tensor ever materializes.
    """
    tk = se.shape[0]
    starts = jnp.searchsorted(se, jnp.arange(e))
    rank = jnp.arange(tk) - starts[se]
    keep = rank < cap
    dest = jnp.where(keep, se * cap + rank, e * cap)   # OOB slot drops
    sentinel = stok.shape[0]  # index of the zero pad row in xt_pad
    tok_buf = jnp.full((e * cap,), sentinel, jnp.int32).at[dest].set(
        stok.astype(jnp.int32), mode="drop", unique_indices=True)
    w_buf = jnp.zeros((e * cap,), jnp.float32).at[dest].set(
        sw * keep, mode="drop", unique_indices=True)
    return tok_buf, w_buf, keep


def moe_fwd(params, x, cfg: MoEConfig):
    """x: (B, S, d). Returns (out, metrics dict).

    Dispatch is group-local (cfg.dispatch_groups = DP shard count): within
    each group, entries sort by expert, ranks clip to capacity, and int32
    index buffers address a (G, E, C, d) gather -- all shard-local under
    GSPMD; only the expert einsum touches the 'model' axis (EP).
    """
    from repro.distributed.sharding import maybe_wsc

    b, s, d = x.shape
    t = b * s
    ng = cfg.dispatch_groups if t % cfg.dispatch_groups == 0 else 1
    tl = t // ng                                     # tokens per group
    xt = x.reshape(t, d)
    w, idx, probs = route(params, xt, cfg)

    k = cfg.top_k
    e = cfg.n_experts
    cap = max(8, int(cfg.capacity_factor * tl * k / e))

    # Per-group flatten + stable sort by expert.
    ge = idx.reshape(ng, tl * k)
    gtok = jnp.broadcast_to(jnp.repeat(jnp.arange(tl), k)[None], (ng, tl * k))
    gw = w.reshape(ng, tl * k)
    ge = maybe_wsc(ge, ("pod", "data"), None)
    order = jnp.argsort(ge, axis=-1, stable=True)
    se = jnp.take_along_axis(ge, order, axis=-1)
    stok = jnp.take_along_axis(gtok, order, axis=-1)
    sw = jnp.take_along_axis(gw, order, axis=-1)

    tok_buf, w_buf, keep = jax.vmap(
        lambda a_, b_, c_: _dispatch_indices(a_, b_, c_, e, cap))(se, stok, sw)
    tok_buf = tok_buf.reshape(ng, e, cap)
    w_buf = w_buf.reshape(ng, e, cap)

    # Gather activations at (G, E, C, d): shard G over dp, E over model.
    # Every activation-side tensor is pinned: with FSDP param sharding the
    # contracting dim also wants 'data', and without pins GSPMD resolves
    # the conflict by UNsharding the group dim (measured: 5 GiB f32 expert
    # intermediates per instance on deepseek prefill).
    dp = ("pod", "data")
    xg = maybe_wsc(xt.reshape(ng, tl, d), dp, None, None)
    xg_pad = jnp.concatenate([xg, jnp.zeros((ng, 1, d), x.dtype)], axis=1)
    buf = jax.vmap(lambda xp, tb: xp[tb])(xg_pad, tok_buf)  # (G, E, C, d)
    buf = maybe_wsc(buf, dp, "model", None, None)

    # Expert SwiGLU (EP over 'model'; G rides along sharded over dp).
    ew = params["experts"]
    # repro: allow-raw-param-matmul (grouped per-expert einsum: the (E,d,f)
    # weight has no 2-D rhs form tsmm accepts, and the contraction must
    # stay a single GSPMD op so EP resolves to all-to-alls)
    g = maybe_wsc(jnp.einsum("gecd,edf->gecf", buf, ew["w_gate"],
                             preferred_element_type=jnp.float32),
                  dp, "model", None, None)
    # repro: allow-raw-param-matmul (same grouped-expert form as w_gate)
    u = maybe_wsc(jnp.einsum("gecd,edf->gecf", buf, ew["w_up"],
                             preferred_element_type=jnp.float32),
                  dp, "model", None, None)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    h = maybe_wsc(h, dp, "model", None, None)
    # repro: allow-raw-param-matmul (same grouped-expert form as w_gate)
    y = jnp.einsum("gecf,efd->gecd", h, ew["w_down"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    y = maybe_wsc(y, dp, "model", None, None)

    # Combine: weighted scatter-add back to tokens (index-addressed).
    yw = y * w_buf[..., None].astype(x.dtype)

    def combine(yg, tb):
        out = jnp.zeros((tl + 1, d), x.dtype)
        return out.at[tb.reshape(-1)].add(yg.reshape(-1, d))[:tl]

    out = jax.vmap(combine)(yw, tok_buf)               # (G, tl, d)
    out = maybe_wsc(out, dp, None, None)
    out = out.reshape(t, d) * jnp.asarray(cfg.routed_scale, x.dtype)

    if cfg.n_shared:
        out = out + layers.swiglu(params["shared"], xt)

    # Switch-style load-balance diagnostics (metric; DeepSeek uses the
    # aux-loss-free router-bias update instead -- see update_router_bias).
    counts = (w_buf > 0).sum(axis=(0, 2))              # honored slots per E
    frac_tokens = counts / jnp.maximum(counts.sum(), 1)
    mean_prob = probs.mean(axis=0)
    metrics = {
        "moe_balance_loss": e * jnp.sum(frac_tokens * mean_prob),
        "moe_dropped_frac": 1.0 - keep.mean(),
        "moe_max_load": frac_tokens.max() * e,
    }
    return out.reshape(b, s, d), metrics


def update_router_bias(params, metrics_counts, rate: float = 1e-3):
    """DeepSeek aux-loss-free balancing: nudge under-loaded experts up."""
    counts = metrics_counts
    target = counts.mean()
    delta = jnp.sign(target - counts) * rate
    return {**params, "router_bias": params["router_bias"] + delta}
