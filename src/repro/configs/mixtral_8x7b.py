"""mixtral-8x7b [moe]: 8 experts top-2 + sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000 [arXiv:2401.04088].
SWA window 4096 => ring KV cache bounds decode memory, making long_500k
runnable (window-bounded).
"""

from repro.configs.base import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128,
    rope_theta=1000000.0, attn_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336, router="softmax",
                  capacity_factor=1.25),
    dtype="bfloat16", microbatch=4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, attn_window=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, router="softmax",
                      capacity_factor=8.0),   # drop-free for smoke determinism
        q_chunk=16, kv_chunk=16, dtype="float32",
    )
