"""qwen2-72b [dense]: large dense model with QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 [arXiv:2407.10671].
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, head_dim=128,
    rope_theta=1000000.0, qkv_bias=True,
    dtype="bfloat16", microbatch=8,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
        vocab_size=256, head_dim=16, qkv_bias=True,
        q_chunk=16, kv_chunk=16, dtype="float32",
    )
