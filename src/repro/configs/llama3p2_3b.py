"""llama3.2-3b [dense]: small llama3 with GQA and tied embeddings.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-3B].
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab_size=128256, head_dim=128,
    rope_theta=500000.0, tie_embeddings=True,
    dtype="bfloat16", microbatch=4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, rope_theta=500000.0, tie_embeddings=True,
        q_chunk=16, kv_chunk=16, dtype="float32",
    )
