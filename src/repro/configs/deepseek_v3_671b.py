"""deepseek-v3-671b [moe]: MLA + 1 shared / 256 routed top-8 experts.

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280 [arXiv:2412.19437].
First 3 layers dense (d_ff=18432); sigmoid router with aux-loss-free bias;
routed output scaled 2.5. MLA: q_lora 1536, kv_lora 512, rope 64 -- the
low-rank projections are TSM2X dispatch shapes.

MTP (multi-token prediction) is NOT implemented (noted in DESIGN.md): it
adds an auxiliary loss head, orthogonal to this paper's kernel/runtime
focus.
"""

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab_size=129280, head_dim=128,
    mla=MLAConfig(q_lora=1536, kv_lora=512, nope_dim=128, rope_dim=64,
                  v_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                  d_ff_shared=2048, router="sigmoid", capacity_factor=1.25,
                  routed_scale=2.5),
    first_k_dense=3,
    dtype="bfloat16", microbatch=4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab_size=256, head_dim=16,
        mla=MLAConfig(q_lora=32, kv_lora=16, nope_dim=16, rope_dim=8, v_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=1,
                      d_ff_shared=32, router="sigmoid", routed_scale=2.5,
                      capacity_factor=8.0),   # drop-free for smoke determinism
        first_k_dense=1,
        q_chunk=16, kv_chunk=16, dtype="float32",
    )
