"""Architecture registry: ``--arch <id>`` resolution + shape skip matrix."""

from __future__ import annotations

from repro.configs import (chatglm3_6b, deepseek_v3_671b, hubert_xlarge,
                           llama3p2_3b, llama3p2_vision_11b, mistral_nemo_12b,
                           mixtral_8x7b, qwen2_72b, rwkv6_1p6b, zamba2_1p2b)
from repro.configs.base import SHAPES, ModelConfig

_MODULES = {
    "zamba2-1.2b": zamba2_1p2b,
    "chatglm3-6b": chatglm3_6b,
    "llama3.2-3b": llama3p2_3b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "qwen2-72b": qwen2_72b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "mixtral-8x7b": mixtral_8x7b,
    "rwkv6-1.6b": rwkv6_1p6b,
    "llama-3.2-vision-11b": llama3p2_vision_11b,
    "hubert-xlarge": hubert_xlarge,
}

ARCH_NAMES = list(_MODULES)

# long_500k needs sub-quadratic attention: runnable for SSM/hybrid/SWA.
_LONG_OK = {"zamba2-1.2b", "rwkv6-1.6b", "mixtral-8x7b"}
# encoder-only: no autoregressive decode at all.
_ENCODER_ONLY = {"hubert-xlarge"}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[name]
    return mod.smoke() if smoke else mod.CONFIG


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    """Skip matrix per DESIGN.md. Returns (supported, reason-if-not)."""
    sc = SHAPES[shape]
    if arch in _ENCODER_ONLY and sc.kind == "decode":
        return False, "encoder-only: no autoregressive decode"
    if shape == "long_500k" and arch not in _LONG_OK:
        return False, "pure full-attention arch: 500k KV decode excluded (needs sub-quadratic attention)"
    return True, ""


def all_cells(include_skipped: bool = False):
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            ok, reason = cell_supported(arch, shape)
            if ok or include_skipped:
                yield arch, shape, ok, reason
