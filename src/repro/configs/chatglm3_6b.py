"""chatglm3-6b [dense]: GQA kv=2, partial ('2d') RoPE, SwiGLU.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 [arXiv:2406.12793].
ChatGLM rotates only half of each head dim (rope_fraction=0.5) and carries
QKV bias.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab_size=65024, head_dim=128,
    rope_fraction=0.5, qkv_bias=True,
    dtype="bfloat16", microbatch=4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, rope_fraction=0.5, qkv_bias=True,
        q_chunk=16, kv_chunk=16, dtype="float32",
    )
