"""llama-3.2-vision-11b [vlm]: text backbone with gated cross-attention
layers to image patch embeddings.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision]. Cross-attn every 5th layer (8 total).
The vision frontend is a STUB per the task spec: ``input_specs()`` provides
precomputed patch embeddings (1601 tokens x 4096, one tile).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=128256, head_dim=128,
    rope_theta=500000.0,
    cross_attn_period=5, vision_seq=1601, vision_dim=4096,
    dtype="bfloat16", microbatch=4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-vision-smoke", family="vlm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16,
        cross_attn_period=2, vision_seq=24, vision_dim=48,
        q_chunk=16, kv_chunk=16, dtype="float32",
    )
