"""rwkv6-1.6b [ssm]: Finch -- attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536 [arXiv:2404.05892]. 32 heads of
dim 64; the decay LoRA (rank 64) is a TSM2X dispatch shape. O(1) decode
state => long_500k runs natively.
"""

from repro.configs.base import ModelConfig
from repro.models.rwkv6 import RWKV6Config

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
    vocab_size=65536, head_dim=64, norm="ln",
    rwkv=RWKV6Config(n_heads=32, head_dim=64, decay_lora_rank=64, chunk=32),
    dtype="bfloat16", microbatch=4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16, norm="ln",
        rwkv=RWKV6Config(n_heads=4, head_dim=16, decay_lora_rank=8, chunk=8),
        q_chunk=16, kv_chunk=16, dtype="float32",
    )
