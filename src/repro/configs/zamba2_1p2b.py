"""zamba2-1.2b [hybrid]: Mamba2 backbone + weight-shared attention block.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242]. Shared attn+MLP block applied every 6 Mamba2 layers
with per-application LoRA (rank 128) -- the LoRA pairs are TSM2X shapes.
"""

from repro.configs.base import ModelConfig
from repro.models.mamba2 import Mamba2Config

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, head_dim=64,
    ssm=Mamba2Config(d_inner=4096, n_heads=64, state_dim=64, n_groups=1,
                     chunk=128),
    hybrid_period=6, shared_lora_rank=128,
    dtype="bfloat16", microbatch=8,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16,
        ssm=Mamba2Config(d_inner=128, n_heads=4, state_dim=8, n_groups=1,
                         chunk=8),
        hybrid_period=2, shared_lora_rank=8,
        q_chunk=16, kv_chunk=16, dtype="float32",
    )
