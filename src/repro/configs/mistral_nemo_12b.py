"""mistral-nemo-12b [dense]: 128k-context dense model.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Mistral-Nemo-Base-2407]. head_dim=128 (not d_model/n_heads).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=131072, head_dim=128,
    rope_theta=1000000.0,
    dtype="bfloat16", microbatch=4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=32,   # head_dim != d_model/n_heads, as in full
        q_chunk=16, kv_chunk=16, dtype="float32",
    )
