"""Model/run configuration dataclasses.

One ``ModelConfig`` describes any of the ten assigned architectures; the
per-arch modules in this package instantiate it with the published numbers
and attach a reduced ``smoke()`` variant for CPU tests. ``ShapeConfig``
describes the four assigned input-shape cells.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.mamba2 import Mamba2Config
from repro.models.moe import MoEConfig
from repro.models.rwkv6 import RWKV6Config


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0     # chatglm3: 0.5 (partial/'2d' RoPE)
    qkv_bias: bool = False         # qwen2: True
    attn_window: Optional[int] = None  # mixtral SWA: 4096
    causal: bool = True            # hubert: False (encoder-only)
    norm: str = "rms"              # rms|ln
    mla: Optional[MLAConfig] = None
    mla_absorb: bool = True        # absorbed latent decode (W_uk/W_uv folded)
    # ffn
    mlp_type: str = "swiglu"       # swiglu|gelu
    moe: Optional[MoEConfig] = None
    first_k_dense: int = 0         # deepseek-v3: 3
    # ssm / hybrid
    ssm: Optional[Mamba2Config] = None
    rwkv: Optional[RWKV6Config] = None
    hybrid_period: int = 0         # zamba2: shared attn block every N mamba layers
    shared_lora_rank: int = 0      # zamba2: per-application LoRA rank
    # vlm
    cross_attn_period: int = 0     # llama3.2-vision: every 5th layer
    vision_seq: int = 0
    vision_dim: int = 0
    # audio (stub frontend: precomputed frame embeddings)
    input_mode: str = "tokens"     # tokens|frames
    frame_dim: int = 0
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "float32"
    # memory/schedule knobs (512x512 bounds the live f32 score tile and the
    # per-q-chunk stacked acc carries in the attention backward; see
    # EXPERIMENTS.md §Perf iteration log)
    q_chunk: int = 512
    kv_chunk: int = 512
    remat: bool = True
    remat_group: int = 1           # layers per remat group (nested-scan remat).
                                   # 1 = per-layer remat: measured best on the
                                   # dry-run backend (XLA:CPU inflates grouped
                                   # stack-saves via f32 DUS fusions; see
                                   # EXPERIMENTS.md §Perf iteration log)
    microbatch: int = 0            # number of grad-accumulation microbatches

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim
        total = v * d * (1 if self.tie_embeddings else 2)
        if self.rwkv is not None:
            per = 5 * d * d + 2 * d * self.rwkv.decay_lora_rank + d * self.d_ff + \
                d * self.d_ff + d * d
            return total + L * per
        if self.ssm is not None:
            di = self.ssm.d_inner
            per_m = d * (2 * di + 2 * self.ssm.n_groups * self.ssm.state_dim
                         + self.ssm.n_heads) + di * d
            n_shared = (L // self.hybrid_period) if self.hybrid_period else 0
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            shared = attn + 3 * d * f if n_shared else 0
            return total + L * per_m + shared
        if self.mla is not None:
            m = self.mla
            per_attn = d * m.q_lora + m.q_lora * self.n_heads * (m.nope_dim + m.rope_dim) \
                + d * m.kv_lora + m.kv_lora * self.n_heads * (m.nope_dim + m.v_dim) \
                + d * m.rope_dim + self.n_heads * m.v_dim * d
        else:
            per_attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.moe is not None:
            e = self.moe
            per_moe = 3 * d * e.d_ff_expert * e.n_experts + d * e.n_experts
            if e.n_shared:
                per_moe += 3 * d * (e.d_ff_shared or e.d_ff_expert * e.n_shared)
            n_moe = L - self.first_k_dense
            n_dense = self.first_k_dense
            ff = 3 * d * f
            return total + L * per_attn + n_moe * per_moe + n_dense * ff
        ff_mult = 3 if self.mlp_type == "swiglu" else 2
        return total + L * (per_attn + ff_mult * d * f)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d, L = self.d_model, self.n_layers
        full_experts = 3 * d * e.d_ff_expert * e.n_experts
        active = 3 * d * e.d_ff_expert * e.top_k
        n_moe = L - self.first_k_dense
        return self.param_count() - n_moe * (full_experts - active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
