"""hubert-xlarge [audio]: encoder-only bidirectional transformer.

48L d_model=1280 16H d_ff=5120 vocab=504 [arXiv:2106.07447]. The conv
waveform frontend is a STUB per the task spec: ``input_specs()`` provides
precomputed frame embeddings (dim 512, the wav2vec2 conv output width);
training is frame-level classification over 504 cluster targets.

Encoder-only => decode_32k / long_500k shapes are skipped (DESIGN.md).
RoPE stands in for HuBERT's convolutional relative positional embedding
(frontend-adjacent, stubbed).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab_size=504, head_dim=80,
    causal=False, norm="ln", mlp_type="gelu",
    input_mode="frames", frame_dim=512,
    dtype="bfloat16", microbatch=4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=64, head_dim=16,
        causal=False, norm="ln", mlp_type="gelu",
        input_mode="frames", frame_dim=32,
        q_chunk=16, kv_chunk=16, dtype="float32",
    )
