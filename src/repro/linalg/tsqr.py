"""CholeskyQR2 tall-skinny QR on the TSM2X kernel paths.

The factorization is pure TSM2X machinery (ROADMAP's lead open item, and
the regime Thies & Rohrig-Zollner show CholeskyQR-class methods dominate
Householder QR in): per pass,

    G = A^T A            # (r, r)  -- ``tsmt``, the split-K headline shape
    R = chol(G)^T        # (r, r)  -- host-shaped, negligible
    Q = A R^{-1}         # (m, r)  -- ``tsm2l`` (tiny contraction)

One Cholesky pass loses orthogonality like ``u * cond(A)^2``; the second
pass (CholeskyQR2, Yamamoto et al.) runs the same two GEMMs on the nearly
orthonormal ``Q`` and recovers ``‖QᵀQ − I‖ ~ u`` whenever the first pass
got ``cond(Q1)`` down to O(1). For operands beyond ``cond ~ 1/sqrt(u)``
the first Gram factor is numerically singular; each pass then falls back
to a shift-regularized Cholesky (``G + s*I``, shifted CholeskyQR a la
Fukaya et al.) selected via ``jnp.where`` so the fallback is trace-safe.
A shifted pass only caps -- not kills -- the conditioning, so the default
``DEFAULT_PASSES`` includes one recovery pass beyond classic QR2 and f32
operands stay ``‖QᵀQ − I‖∞ <= 1e-4`` through ``cond ~ 1e6``.

Both GEMM stages go through :mod:`repro.core.tsmm`, so the lexically
scoped :class:`~repro.core.tsmm.GemmPolicy` applies (executor selection,
shard_map composition, the dispatch spy, ``verify_contracts``), and
out-of-regime shapes degrade to the dense path instead of failing. The
small ``(r, r)`` Cholesky/triangular solves are host-shaped and exempt
from the ``raw-linalg-qr`` lint rule by scope (the rule guards
``models//optim//serve/``, not this subsystem).

``tsqr``/``qr`` carry a ``custom_vjp`` (Liao-style QR adjoint), so
PowerSGD's orthogonalization stays differentiable and the cotangent GEMMs
(``dQᵀQ`` is a ``tsmt``; the two ``R^{-T}`` applies are ``tsm2l``-shaped)
re-dispatch tall-skinny under :func:`tsmm.backward_policy`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsp_linalg

from repro.core import tsmm

__all__ = ["tsqr", "qr", "DEFAULT_PASSES"]

# Two classic CholeskyQR2 passes plus one recovery pass: when the shift
# fallback engages in pass 1 (cond(A) beyond ~1/sqrt(u)), pass 2's input
# still carries cond(Q1) ~ 1/sqrt(shift_rel) ~ 1e2, leaving pass-2
# orthogonality ~ u/shift_rel ~ 1e-3 -- one more pass lands it at ~u.
# Well-conditioned operands simply converge a pass early; each extra pass
# is one bandwidth-bound tsmt+tsm2l pair. Callers on known-benign inputs
# (PowerSGD's P factors) can pass ``passes=2``.
DEFAULT_PASSES = 3


def _wide_dtype():
    """float64 when x64 is enabled, else float32 (canonicalized)."""
    return jax.dtypes.canonicalize_dtype(jnp.float64)


def _default_shift_rel(m: int, r: int) -> float:
    """Shift (relative to the unit diagonal of the scaled Gram) that
    dominates the f32 Gram accumulation noise ``~ sqrt(m) * u`` while
    capping the post-shift conditioning at ``~ 1/sqrt(shift_rel)``."""
    eps = float(jnp.finfo(jnp.float32).eps)
    return 10.0 * float(m * r) ** 0.5 * eps


def _small_cholesky(g: jnp.ndarray, shift_rel: float):
    """Compensated Cholesky of the (r, r) Gram: Jacobi (diagonal) scaling
    conditions the factorization when x64 is unavailable, the factor is
    computed in f64 when it is, and a shift-regularized retry is selected
    via ``jnp.where`` whenever the unshifted factor came back non-finite
    (numerically singular / indefinite Gram). Returns the *lower* factor
    ``L`` with ``G ~ L Lᵀ`` in ``g.dtype``."""
    r = g.shape[0]
    eye = jnp.eye(r, dtype=g.dtype)
    d = jnp.sqrt(jnp.maximum(jnp.diag(g), jnp.finfo(g.dtype).tiny))
    gs = g / (d[:, None] * d[None, :])
    wide = _wide_dtype()
    l_plain = jnp.linalg.cholesky(gs.astype(wide))
    ok = jnp.all(jnp.isfinite(l_plain))
    l_shift = jnp.linalg.cholesky((gs + shift_rel * eye).astype(wide))
    l_scaled = jnp.where(ok, l_plain, l_shift).astype(g.dtype)
    return d[:, None] * l_scaled


def _chol_pass(q: jnp.ndarray, policy, shift_rel: float):
    """One CholeskyQR pass: returns (Q_next, R_factor)."""
    r_dim = q.shape[1]
    g = tsmm.tsmm_t(q, q, policy=policy)                       # TSMT
    g = 0.5 * (g + g.T)
    r_fac = _small_cholesky(g, shift_rel).T                    # upper
    r_inv = jsp_linalg.solve_triangular(
        r_fac, jnp.eye(r_dim, dtype=r_fac.dtype), lower=False)
    return tsmm.tsmm(q, r_inv, policy=policy), r_fac           # TSM2L


def _factor(a: jnp.ndarray, passes: int, policy, shift_rel: float | None):
    m, r_dim = a.shape
    q = a.astype(jnp.float32)
    srel = shift_rel if shift_rel is not None else _default_shift_rel(
        m, r_dim)
    r_acc = None
    for _ in range(passes):
        q, r_fac = _chol_pass(q, policy, srel)
        r_acc = r_fac if r_acc is None else r_fac @ r_acc
    return q, r_acc


# ``passes``/``policy``/``shift_rel`` ride the nondiff slots (GemmPolicy is
# frozen+hashable by contract), so the backward re-enters the dispatcher
# under the policy captured at forward-trace time -- same convention as the
# kernel ops' own custom_vjp rules.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _qr_diff(a, passes, policy, shift_rel):
    return _factor(a, passes, policy, shift_rel)


def _qr_fwd(a, passes, policy, shift_rel):
    q, r = _factor(a, passes, policy, shift_rel)
    return (q, r), (q, r)


def _qr_bwd(passes, policy, shift_rel, res, cts):
    del passes, shift_rel
    q, r = res
    dq, dr = cts
    bp = tsmm.backward_policy(policy)
    # QR adjoint for the reduced factorization (m >= r):
    #   M  = R dRᵀ − dQᵀ Q
    #   dA = (dQ + Q copyltu(M)) R^{-T}
    # dQᵀQ is the huge-reduction product -> tsmt; grouping the two small
    # (r, r) factors first leaves exactly two tall applies -> tsm2l.
    m_mat = r @ dr.T - tsmm.tsmm_t(dq, q, policy=bp)
    low = jnp.tril(m_mat, -1)
    copyltu = low + low.T + jnp.diag(jnp.diag(m_mat))
    rinv_t = jsp_linalg.solve_triangular(
        r, jnp.eye(r.shape[0], dtype=r.dtype), lower=False).T
    da = (tsmm.tsmm(dq, rinv_t, policy=bp)
          + tsmm.tsmm(q, copyltu @ rinv_t, policy=bp))
    return (da,)


_qr_diff.defvjp(_qr_fwd, _qr_bwd)


def tsqr(a: jnp.ndarray, *, policy: tsmm.GemmPolicy | None = None,
         passes: int | None = None, shift_rel: float | None = None):
    """Tall-skinny QR via CholeskyQR2: ``A (m, r) -> (Q, R)`` with
    ``Q`` orthonormal ``(m, r)`` in ``a.dtype`` and ``R`` upper-triangular
    ``(r, r)`` f32 with non-negative diagonal (the factorization is unique
    under that sign convention, which is what makes oracle comparisons and
    the tree variant's cross-shard agreement exact up to rounding).

    Compute runs in f32 regardless of input dtype (bf16 operands are
    upcast before the Gram stage -- a bf16 Gram cannot support any useful
    orthogonality target). Differentiable; both GEMM stages and their
    cotangents dispatch through :mod:`repro.core.tsmm` under ``policy``
    (default: the active ``tsmm.policy(...)`` scope).

    ``passes``: CholeskyQR passes (default :data:`DEFAULT_PASSES`).
    ``shift_rel``: override the relative Cholesky regularization shift
    used when a Gram factor comes back numerically singular.
    """
    if a.ndim != 2:
        raise ValueError(f"tsqr expects a 2-D (m, r) operand; got {a.shape}")
    m, r_dim = a.shape
    if r_dim == 0 or m < r_dim:
        raise ValueError(
            f"tsqr is the tall-skinny factorization (m >= r >= 1); got "
            f"shape {a.shape}")
    n_passes = DEFAULT_PASSES if passes is None else int(passes)
    if n_passes < 1:
        raise ValueError(f"tsqr needs passes >= 1; got {passes}")
    p = policy if policy is not None else tsmm.current_policy()
    if shift_rel is not None:
        shift_rel = float(shift_rel)
    # The f32 upcast sits OUTSIDE the custom_vjp (its transpose casts the
    # cotangent back), so the rule only ever sees f32 operands.
    q, r = _qr_diff(a.astype(jnp.float32), n_passes, p, shift_rel)
    return q.astype(a.dtype), r


def qr(a: jnp.ndarray, *, policy: tsmm.GemmPolicy | None = None,
       passes: int | None = None, shift_rel: float | None = None):
    """Alias of :func:`tsqr` under the conventional name."""
    return tsqr(a, policy=policy, passes=passes, shift_rel=shift_rel)
