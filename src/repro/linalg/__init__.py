"""Tall-skinny linear algebra on the TSM2X kernel paths.

* :func:`qr` / :func:`tsqr` -- CholeskyQR2 factorization of a replicated
  ``(m, r)`` operand (Gram via ``tsmt``, apply via ``tsm2l``, shift-
  regularized fallback, differentiable via ``custom_vjp``).
* :func:`tree_tsqr` -- the distributed variant for row-sharded operands
  inside a caller's shard_map (small-R butterfly/gather tree, psum-free).

Also re-exported as ``repro.kernels.ops.tsqr`` for symmetry with the
kernel entries.
"""

from repro.linalg.tsqr import DEFAULT_PASSES, qr, tsqr
from repro.linalg.tree_tsqr import tree_tsqr

__all__ = ["qr", "tsqr", "tree_tsqr", "DEFAULT_PASSES"]
