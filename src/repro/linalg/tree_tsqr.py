"""Distributed tall-skinny QR: per-shard CholeskyQR2 + a small-R tree.

Runs *inside the caller's shard_map* over a named mesh axis (the same
contract as ``powersgd.compress_one_sharded``): each rank factors its own
``(m/N, r)`` row block locally on the TSM2X kernel paths, then only the
tiny ``(r, r)`` ``R`` factors travel -- psum-free and log-depth -- so the
row-sharded ``Q`` factor never materializes replicated. This composes
with the PR 4 ``psum_scatter`` executors: a consumer that keeps its
operand row-sharded (PowerSGD's scattered ``Q`` state) feeds this
directly and gets a sharded orthonormal basis back in the same layout.

Two reduction schedules over the R factors (``reduce=``):

* ``"butterfly"`` -- an all-reduce-shaped TSQR: at level ``l`` each rank
  ``ppermute``-swaps its current ``R`` with partner ``i XOR 2^l``, both
  sides stack the pair lower-rank-first and take the same small
  Householder QR, so every rank finishes every level with an *identical*
  ``R`` and its own ``(r, r)`` Q-block, accumulated into a transform
  ``T``. ``log2(N)`` rounds of ``r*r`` exchanges, no psum, no gather.
  Requires a power-of-two axis size.
* ``"gather"`` -- direct TSQR (the mrtsqr lineage): ``all_gather`` the N
  small ``R`` factors, one ``(N*r, r)`` QR, each rank slices its own
  Q-block. One collective, fine at small N or non-power-of-two sizes.

``reduce="auto"`` picks butterfly exactly when the axis size is a power
of two. Every small QR is sign-normalized (non-negative R diagonal), and
the local Cholesky factors carry that convention already, so the global
``R`` -- and therefore ``Q = Q_local @ T`` -- matches the replicated
:func:`repro.linalg.tsqr` oracle up to rounding, not up to column signs.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import tsmm
from repro.kernels import compat
from repro.linalg.tsqr import tsqr as _local_tsqr

__all__ = ["tree_tsqr"]

_REDUCES = ("auto", "butterfly", "gather")


def _small_qr(x: jnp.ndarray):
    """Reduced QR of a stacked-R block, sign-fixed to R diag >= 0."""
    q, r = jnp.linalg.qr(x)
    s = jnp.where(jnp.diag(r) < 0, -1.0, 1.0).astype(x.dtype)
    return q * s[None, :], r * s[:, None]


def tree_tsqr(a: jnp.ndarray, *, axis: str,
              policy: tsmm.GemmPolicy | None = None,
              passes: int | None = None, reduce: str = "auto",
              shift_rel: float | None = None):
    """Tall-skinny QR of the row-sharded global operand whose local block
    is ``a (m/N, r)``; call inside a shard_map over mesh axis ``axis``.

    Returns ``(q_local, r)``: this rank's ``(m/N, r)`` row block of the
    global orthonormal ``Q`` (in ``a.dtype``) and the replicated global
    ``(r, r)`` upper-triangular ``R`` (f32, non-negative diagonal).

    The local factor is :func:`repro.linalg.tsqr` under the caller's
    policy forced to ``shard_map="local"`` (we are already per-shard --
    the dispatcher must not re-wrap), so both local GEMM stages stay on
    the tsmt/tsm2l executors; ``passes``/``shift_rel`` pass through.
    """
    if reduce not in _REDUCES:
        raise ValueError(
            f"tree_tsqr reduce={reduce!r}: valid values are {_REDUCES}")
    p = policy if policy is not None else tsmm.current_policy()
    if p.shard_map != "local":
        p = p.with_(shard_map="local")
    q0, r0 = _local_tsqr(a, policy=p, passes=passes, shift_rel=shift_rel)
    q0 = q0.astype(jnp.float32)
    size = int(lax.psum(1, axis))
    if size == 1:
        return q0.astype(a.dtype), r0
    r_dim = a.shape[-1]
    if reduce == "auto":
        reduce = "butterfly" if size & (size - 1) == 0 else "gather"
    idx = lax.axis_index(axis)

    if reduce == "butterfly":
        if size & (size - 1) != 0:
            raise ValueError(
                f"tree_tsqr reduce='butterfly' needs a power-of-two axis "
                f"size; axis {axis!r} has {size} shards (use 'gather')")
        t_acc = None
        r_cur = r0
        for level in range(size.bit_length() - 1):
            bit = 1 << level
            perm = [(i, i ^ bit) for i in range(size)]
            r_other = lax.ppermute(r_cur, axis, perm)
            lower = (idx & bit) == 0
            top = jnp.where(lower, r_cur, r_other)
            bot = jnp.where(lower, r_other, r_cur)
            qs, r_cur = _small_qr(jnp.concatenate([top, bot], axis=0))
            blk = jnp.where(lower, qs[:r_dim], qs[r_dim:])
            t_acc = blk if t_acc is None else t_acc @ blk
    else:
        rs = compat.all_gather(r0, axis)                 # (N*r, r)
        qs, r_cur = _small_qr(rs)
        t_acc = lax.dynamic_slice_in_dim(qs, idx * r_dim, r_dim, axis=0)

    q = tsmm.tsmm(q0, t_acc, policy=p)                   # TSM2L shape
    return q.astype(a.dtype), r_cur
