"""AST-based repo invariant linter: the boundaries PRs 1-5 established by
convention, now enforced (``python -m repro.analysis.lint``).

Rules (stable ids; each can be waived per line with a pragma comment
``# repro: allow-<rule-name> (reason)`` on the offending line or the line
directly above -- the reason is mandatory, waivers are grep-able):

* **RA001 jax-src-import** -- ``jax._src`` is private API and may be
  imported ONLY by ``kernels/compat.py``, the version-shim module whose
  whole job is quarantining such dependencies.
* **RA002 raw-param-matmul** -- inside ``models/``, ``optim/`` and
  ``serve/``, matmuls over *parameter-shaped* operands (``jnp.dot`` /
  ``jnp.einsum`` / ``jnp.matmul`` / ``lax.dot_general`` / the ``@``
  operator where an operand looks like a weight: named ``w``/``w_*``/
  ``*_w``/``wq``-style, or indexed out of a params dict by a weight-ish
  key) must route through ``repro.core.tsmm`` so the policy scope, the
  classifier and the kernels see them. Attention-score/state einsums over
  activations are out of scope by construction (their operands are not
  parameter-shaped).
* **RA003 env-read** -- ``os.environ`` / ``os.getenv`` reads are allowed
  only in the default-policy constructor (``core/tsmm.py::
  _policy_from_env``) and under ``launch/`` (process launchers run before
  tracing). Anywhere else an env read is trace-time hidden state that
  bypasses the GemmPolicy scoping this repo exists to enforce.
* **RA004 executor-contract** -- every ``register_executor`` call in the
  package must declare its reduce contract (``reduce=``); the implicit
  all-modes default is for out-of-tree back-compat only.
* **RA005 raw-linalg-qr** -- inside the same ``models//optim//serve/``
  layers, raw ``jnp.linalg.qr`` / ``jnp.linalg.cholesky`` (and their
  numpy/scipy spellings) are banned: those call sites orthogonalize
  tall-skinny operands and must route through ``repro.linalg`` so the
  Gram/apply GEMMs land on the policy-scoped TSM2X paths. Like RA002 the
  rule is name-scoped, not shape-scoped -- a genuinely small decomposition
  is waived with a documented pragma. ``repro/linalg`` itself is exempt by
  scope: its (r, r) host-shaped factor *is* the sanctioned call site.
* **RA006 undeclared-dimension-semantics** -- every ``pallas_call`` under
  ``kernels/`` must pass explicit ``dimension_semantics`` (directly or via
  ``compiler_params=CompilerParams(dimension_semantics=...)``). An
  undeclared grid silently serializes on TPU (correct but unoccupied) and
  leaves the dataflow verifier (``analysis.kernel_verify``) with no
  parallel/arbitrary labels to prove race freedom against.
  ``kernels/compat.py`` is exempt by scope: its recording shim forwards
  whatever the kernel modules declared.

Import discipline: stdlib only (ast + pathlib), so the linter runs in a
bare CI interpreter with no jax present.
"""

from __future__ import annotations

import ast
import dataclasses
import re
import sys
from pathlib import Path

__all__ = ["LintError", "lint_source", "lint_file", "lint_paths", "main",
           "RULES"]

RULES = {
    "jax-src-import": "jax._src imported outside kernels/compat.py",
    "raw-param-matmul": "raw matmul on parameter-shaped operands in "
                        "models//optim//serve/ (route through tsmm)",
    "env-read": "os.environ/getenv read outside the default-policy "
                "constructor or launch/",
    "executor-contract": "register_executor without an explicit reduce= "
                         "contract declaration",
    "raw-linalg-qr": "raw qr/cholesky factorization in models//optim//"
                     "serve/ (route through repro.linalg)",
    "undeclared-dimension-semantics": "pallas_call under kernels/ without "
                                      "explicit dimension_semantics",
}

# Directories (relative to the package root) where RA002 applies: the
# layers whose matmuls carry model parameters and must be policy-routed.
_PARAM_MATMUL_DIRS = ("models", "optim", "serve")

# RA003 allowlist: (path suffix, enclosing function) pairs, plus whole dirs.
_ENV_READ_FUNC_ALLOW = (("core/tsmm.py", "_policy_from_env"),)
_ENV_READ_DIR_ALLOW = ("launch",)

# A name is parameter-shaped when it matches the repo's weight-naming
# convention: bare "w", "w"+head-letters (wq/wk/wv/wo/wuk/wukv...), w_*/
# *_w, weight(s), or a params "table". Deliberately name-based -- the
# linter has no type information; false positives are waived with a
# documented pragma, which is the point (the waiver records WHY the site
# is exempt).
_PARAM_NAME = re.compile(r"^(w|w[a-z]{1,3}|w_\w+|\w+_w|weights?|table)$")
_PARAM_KEY = re.compile(r"^(w|w[a-z]{1,3}|w_\w+|\w+_w|weights?|table|embed\w*)$")
_PARAM_CONTAINERS = ("params", "param", "weights", "ew")

_PRAGMA = re.compile(r"#\s*repro:\s*allow-([a-z0-9-]+)\s*(\(.*\))?")


@dataclasses.dataclass(frozen=True)
class LintError:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _waivers(source: str, tree: ast.AST) -> dict[int, set[str]]:
    """line number -> rule names waived there.

    A pragma on line L waives L itself (trailing-comment form) and the
    whole *statement* that starts at the next non-comment line (leading-
    comment form) -- multi-line calls and continuation comments included,
    so a waiver above ``g = maybe_wsc(jnp.einsum(...\\n...))`` covers the
    einsum on the wrapped line.
    """
    lines = source.splitlines()
    # statement start line -> largest end line of a statement starting there
    stmt_end: dict[int, int] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.stmt):
            end = node.end_lineno or node.lineno
            stmt_end[node.lineno] = max(stmt_end.get(node.lineno, 0), end)
    out: dict[int, set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _PRAGMA.search(text)
        if not m:
            continue
        rule = m.group(1)
        out.setdefault(i, set()).add(rule)
        # find the first non-comment, non-blank line below the pragma
        j = i + 1
        while j <= len(lines) and (not lines[j - 1].strip()
                                   or lines[j - 1].lstrip().startswith("#")):
            j += 1
        for ln in range(j, stmt_end.get(j, j) + 1):
            out.setdefault(ln, set()).add(rule)
    return out


def _unwrap(node: ast.AST) -> ast.AST:
    """Strip .T/.astype(...)/.reshape(...)/slicing wrappers so the
    underlying operand expression is what gets name-matched."""
    while True:
        if isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Attribute):
            if node.attr in ("T", "astype", "reshape", "mT", "transpose",
                             "swapaxes"):
                node = node.value
            else:
                break
        elif isinstance(node, ast.Subscript):
            # peel positional slicing (x[..., :n]) but KEEP string-keyed
            # subscripts -- those are the params["w_*"] accesses the
            # heuristic matches directly.
            if _string_key(node) is not None:
                break
            node = node.value
        else:
            break
    return node


def _string_key(node: ast.Subscript) -> str | None:
    s = node.slice
    if isinstance(s, ast.Constant) and isinstance(s.value, str):
        return s.value
    return None


def _is_param_shaped(node: ast.AST) -> bool:
    node = _unwrap(node)
    if isinstance(node, ast.Name):
        return bool(_PARAM_NAME.match(node.id))
    if isinstance(node, ast.Subscript):
        key = _string_key(node)
        if key is not None and _PARAM_KEY.match(key):
            return True
        base = _unwrap(node.value)
        if (key is not None and isinstance(base, ast.Name)
                and base.id in _PARAM_CONTAINERS):
            return True
    return False


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a call target ('jnp.einsum', ...)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


_MATMUL_CALLS = {
    "jnp.dot", "jnp.matmul", "jnp.einsum", "jnp.tensordot",
    "np.dot", "numpy.dot",
    "lax.dot_general", "lax.dot", "jax.lax.dot_general", "jax.lax.dot",
    "jax.numpy.dot", "jax.numpy.matmul", "jax.numpy.einsum",
}

# RA005: the dense-factorization spellings that belong on repro.linalg
# inside the parameter layers (the operands there are the tall-skinny
# factors the QR subsystem exists for).
_LINALG_FACTOR_CALLS = {
    "jnp.linalg.qr", "jnp.linalg.cholesky",
    "jax.numpy.linalg.qr", "jax.numpy.linalg.cholesky",
    "np.linalg.qr", "np.linalg.cholesky",
    "numpy.linalg.qr", "numpy.linalg.cholesky",
    "jsp.linalg.cholesky", "jsp_linalg.cholesky",
    "jax.scipy.linalg.cholesky", "scipy.linalg.cholesky",
}


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, rel: str, waivers: dict[int, set[str]]):
        self.path = path
        self.rel = rel  # path relative to the package root, '/'-separated
        self.waivers = waivers
        self.errors: list[LintError] = []
        self._func_stack: list[str] = []
        self.check_param_matmul = any(
            f"/{d}/" in f"/{rel}" for d in _PARAM_MATMUL_DIRS)
        self.env_read_allowed_file = any(
            f"/{d}/" in f"/{rel}" for d in _ENV_READ_DIR_ALLOW)
        # RA006 scope: the kernel modules, minus the compat shim (whose
        # pallas_call wrapper forwards the callers' declarations).
        self.check_kernel_launch = ("/kernels/" in f"/{rel}"
                                    and not rel.endswith("kernels/compat.py"))

    # -- plumbing -----------------------------------------------------------

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        if rule in self.waivers.get(line, ()):
            return
        self.errors.append(LintError(rule, self.path, line, message))

    def visit_FunctionDef(self, node):
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- RA001: jax._src confinement ----------------------------------------

    def _check_import(self, node, module: str) -> None:
        if module == "jax._src" or module.startswith("jax._src."):
            if not self.rel.endswith("kernels/compat.py"):
                self._emit("jax-src-import", node,
                           f"import of private API {module!r} outside "
                           "kernels/compat.py")

    def visit_Import(self, node):
        for alias in node.names:
            self._check_import(node, alias.name)
        self.generic_visit(node)

    def visit_ImportFrom(self, node):
        if node.module and node.level == 0:
            self._check_import(node, node.module)
        self.generic_visit(node)

    # -- RA002 + RA003 + RA004: calls ---------------------------------------

    def visit_Call(self, node):
        name = _dotted(node.func)

        if self.check_param_matmul and name in _MATMUL_CALLS:
            operands = [a for a in node.args
                        if not (isinstance(a, ast.Constant)
                                and isinstance(a.value, str))]
            hits = [a for a in operands if _is_param_shaped(a)]
            if hits:
                self._emit(
                    "raw-param-matmul", node,
                    f"{name} over parameter-shaped operand "
                    f"{ast.unparse(hits[0])!r}: route through "
                    "repro.core.tsmm (or waive with a documented pragma)")

        if self.check_param_matmul and name in _LINALG_FACTOR_CALLS:
            self._emit(
                "raw-linalg-qr", node,
                f"{name} in a parameter layer: orthogonalization/"
                "factorization of tall operands must route through "
                "repro.linalg (qr/tsqr/tree_tsqr) so the Gram and apply "
                "GEMMs hit the policy-scoped kernels (or waive with a "
                "documented pragma)")

        if name in ("os.getenv", "getenv"):
            self._check_env_read(node)
        if name.endswith("environ.get") and name.startswith("os"):
            self._check_env_read(node)

        if (self.check_kernel_launch
                and name.split(".")[-1] == "pallas_call"):
            kw = {k.arg: k.value for k in node.keywords if k.arg}
            declared = "dimension_semantics" in kw
            cp = kw.get("compiler_params")
            if not declared and isinstance(cp, ast.Call):
                declared = any(k.arg == "dimension_semantics"
                               for k in cp.keywords)
            if not declared:
                self._emit(
                    "undeclared-dimension-semantics", node,
                    f"{name} without explicit dimension_semantics: declare "
                    "parallel/arbitrary per grid dim (via compiler_params="
                    "CompilerParams(dimension_semantics=...)) so Mosaic "
                    "parallelizes and kernel_verify can prove race freedom "
                    "(or waive with a documented pragma)")

        if name.split(".")[-1] == "register_executor":
            kw = {k.arg for k in node.keywords}
            if "reduce" not in kw:
                self._emit(
                    "executor-contract", node,
                    "register_executor without reduce=: every in-repo "
                    "executor must declare which GemmPolicy.reduce modes "
                    "it implements")

        self.generic_visit(node)

    def visit_BinOp(self, node):
        # the `@` operator form of RA002
        if self.check_param_matmul and isinstance(node.op, ast.MatMult):
            hits = [a for a in (node.left, node.right)
                    if _is_param_shaped(a)]
            if hits:
                self._emit(
                    "raw-param-matmul", node,
                    f"@ over parameter-shaped operand "
                    f"{ast.unparse(hits[0])!r}: route through "
                    "repro.core.tsmm (or waive with a documented pragma)")
        self.generic_visit(node)

    def _env_read_allowed(self) -> bool:
        if self.env_read_allowed_file:
            return True
        return any(self.rel.endswith(suffix) and fn in self._func_stack
                   for suffix, fn in _ENV_READ_FUNC_ALLOW)

    def _check_env_read(self, node) -> None:
        if not self._env_read_allowed():
            self._emit(
                "env-read", node,
                "os.environ read outside the default-policy constructor "
                "(core/tsmm.py::_policy_from_env) or launch/: env state "
                "must flow through GemmPolicy, not be read at trace time")

    def visit_Subscript(self, node):
        # os.environ["X"] reads (writes are assignments -- visit context).
        if (_dotted(node.value) == "os.environ"
                and isinstance(node.ctx, ast.Load)):
            self._check_env_read(node)
        self.generic_visit(node)

    def visit_Attribute(self, node):
        # bare `os.environ` passed around (e.g. dict(os.environ)) -- only
        # flag Load contexts that are not the subscript/call cases above
        # (those recurse here, so keep this to the exact dotted match).
        self.generic_visit(node)


def lint_source(source: str, path: str, rel: str | None = None
                ) -> list[LintError]:
    """Lint one file's source text. ``rel`` is the path relative to the
    scanned package root ('/'-separated); defaults to ``path``."""
    rel = (rel if rel is not None else path).replace("\\", "/")
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [LintError("syntax-error", path, e.lineno or 0, str(e.msg))]
    v = _Visitor(path, rel, _waivers(source, tree))
    v.visit(tree)
    return sorted(v.errors, key=lambda e: (e.path, e.line, e.rule))


def lint_file(path) -> list[LintError]:
    p = Path(path)
    return lint_source(p.read_text(), str(p), None)


def _package_root(root) -> Path:
    return Path(root) if root is not None else (
        Path(__file__).resolve().parents[1])


def lint_paths(root=None) -> list[LintError]:
    """Lint every ``*.py`` under ``root`` (default: the ``repro`` package
    this module is installed in). ``rel`` paths are computed against
    ``root`` so the directory-scoped rules fire correctly."""
    rootp = _package_root(root)
    errors: list[LintError] = []
    for p in sorted(rootp.rglob("*.py")):
        rel = p.relative_to(rootp).as_posix()
        errors.extend(lint_source(p.read_text(), str(p), rel))
    return errors


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    roots = args or [None]
    errors: list[LintError] = []
    for r in roots:
        errors.extend(lint_paths(r))
    for e in errors:
        print(e)
    n = len(errors)
    print(f"repro.analysis.lint: {n} violation(s)"
          + ("" if n else " -- clean"))
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
