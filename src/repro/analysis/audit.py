"""Offline kernel-contract auditor: sweep everything the choosers can emit.

``analysis.contracts`` holds the predicates; this module drives them over
the whole reachable configuration space and emits a machine-readable
report, so a contract regression (a chooser emitting an unlaunchable
block, a stale tuning-table commit, a backward-policy drift) is caught by
CI instead of by a Mosaic compile error -- or worse, a silently padded
kernel -- at dispatch time.

Sections (one report entry each):

* ``candidate-grids`` -- every (kind, shape, dtype, spec) candidate the
  perf model enumerates passes :func:`contracts.check_kernel_config`.
* ``resolved-configs`` -- the analytic picks AND ``ops.resolve_params``
  outputs (the exact trace-time resolution, including pinned-split and
  "never" arms) are contract-clean, and the zero-padded operand shapes
  they imply satisfy the grid-divisibility contract.
* ``tuning-table`` -- every committed TuningTable record re-checks under
  the table's *fitted* spec (``TuningTable.fitted_spec``: explore-budget
  winners are legal exactly when calibration widened ``vmem_usable``),
  names a registered executor, and sits in the bucket its shape hashes to.
* ``policies`` -- ``tsmm.backward_policy`` honors the VJP re-dispatch
  invariants for every reachable GemmPolicy field combo, and every
  registered executor declares a well-formed reduce contract.
* ``bench-dispatch`` -- the committed ``BENCH_*.json`` dispatch-sanity
  arms observed only registered executors, matched their expectations,
  and scatter arms ran on a divisible output axis.
* ``quant-resolved`` -- the int8 operand path
  (``GemmPolicy.quant="int8"``): quantized candidate grids and resolved
  configs re-check under the effective int8 operand dtype (32-row
  sublane quantum, 1-byte tiles, caller-dtype output window), grid
  exactly, and pass the grid-dataflow verifier -- so the
  f32-accumulator rule provably covers the q8 kernels.
* ``abft-resolved`` -- the online-ABFT surface (``GemmPolicy.abft``):
  every (abft, quant, reduce) policy combo passes the backward-policy
  contract (the guard mode survives into the VJP re-dispatch), and every
  checksum-GEMM shape the wrap can emit
  (:func:`contracts.abft_stage_shapes`) classifies dense or resolves to
  a launchable, grid-exact config across specs and split arms.
* ``qr-resolved`` -- every GEMM stage the ``repro.linalg`` QR subsystem
  can hand the resolver (:func:`contracts.qr_stage_shapes`: the Gram
  ``tsmt`` and apply ``tsm2l`` of CholeskyQR2, replicated and per-shard
  under the tree-TSQR shard counts) resolves to a launchable, grid-exact
  configuration under every spec/split arm. QR compute is f32 by
  construction (bf16 operands are upcast before the Gram), so the sweep
  pins f32.
* ``kernel-dataflow`` -- the grid-dataflow verifier
  (``analysis.kernel_verify``): every unique (kernel, padded shape,
  params, dtype) the resolver sweep reaches -- all five kernels plus the
  ``reduce.py`` split-partials epilogue -- is captured abstractly and
  checked for write races, revisit init/flush guard discipline,
  index-map bounds, f32 accumulators, and launch-metadata drift. The
  section's report entry additionally lists configs whose grids were
  corner-sampled rather than exhaustively enumerated.

CLI::

    python -m repro.analysis.audit [--strict] [--json PATH]
                                   [--bench PATH] [--table PATH]

``--strict`` exits 1 on any violation (the CI mode). ``run_audit`` is the
API the tests drive; it never raises on violations, it reports them.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax.numpy as jnp

from repro.analysis import contracts, kernel_verify
from repro.core import autotune, perf_model, tsmm
from repro.kernels import ops
from repro.kernels import reduce as kreduce

__all__ = [
    "AUDIT_SCHEMA",
    "SWEEP_SHAPES",
    "audit_candidate_grids",
    "audit_resolved_configs",
    "audit_kernel_dataflow",
    "audit_quant_configs",
    "audit_qr_configs",
    "audit_abft_configs",
    "audit_tuning_table",
    "audit_policies",
    "audit_bench",
    "run_audit",
    "main",
]

AUDIT_SCHEMA = "repro-analysis-audit/1"

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_BENCH = _REPO_ROOT / "benchmarks" / "BENCH_baseline.json"

# Paper shapes plus deliberately awkward ones (odd dims, non-lane/sublane
# multiples) -- the configurations most likely to expose clamp/quantization
# drift between the model and the resolver.
SWEEP_SHAPES: dict[str, tuple[tuple[int, int, int], ...]] = {
    "tsm2r": ((2048, 512, 8), (4096, 4096, 16), (20480, 20480, 16),
              (4100, 130, 3), (2048, 512, 130), (1000, 100, 2)),
    "tsm2l": ((8192, 16, 16), (100000, 8, 8), (65536, 130, 4),
              (10001, 3, 5)),
    "tsmt": ((4096, 64, 8), (65536, 16, 16), (8200, 130, 8),
             (4096, 64, 512), (100000, 2, 2)),
}
SWEEP_DTYPES = (jnp.bfloat16, jnp.float32)
SWEEP_SPECS = (perf_model.V5E, perf_model.V5P)
# Policy split-knob arms the resolver audit exercises ("auto", a pinned S,
# and the sequential pin).
SWEEP_SPLITS = ("auto", 2, "never")

# The bench mesh arms run on the CI host topology (2 virtual devices); the
# scatter arms' output axis must tile over that many shards to exist.
BENCH_MESH_SHARDS = 2

# QR sweep: (m, r) operands the linalg subsystem plausibly factors
# (PowerSGD P factors, k-means centers, sketching bases) including odd /
# non-lane-multiple columns, crossed with tree-TSQR shard counts. Stages
# are derived by contracts.qr_stage_shapes; shard counts that don't tile
# an m are skipped (tree_tsqr's own precondition).
QR_SWEEP_SHAPES = ((8192, 16), (65536, 16), (1 << 20, 32), (4096, 3),
                   (100000, 64), (16384, 130))
QR_SWEEP_SHARDS = (1, 2, 8)


def _candidate_dicts(kind, m, d1, d2, spec, dtype):
    if kind == "tsm2r":
        return [{"block_m": bm, "block_k": bk, "splits": s}
                for bm, bk, s in perf_model.tsm2r_candidates(m, d1, d2, spec,
                                                            dtype)]
    if kind == "tsm2l":
        return [{"block_m": bm}
                for bm in perf_model.tsm2l_candidates(m, d1, d2, spec, dtype)]
    return [{"block_m": bm, "block_a": ba, "splits": s}
            for bm, ba, s in perf_model.tsmt_candidates(m, d1, d2, spec,
                                                        dtype)]


def _chooser_pick(kind, m, d1, d2, spec, dtype):
    if kind == "tsm2r":
        bm, bk, s = perf_model.choose_params_tsm2r(m, d1, d2, spec, dtype)
        return {"block_m": bm, "block_k": bk, "splits": s}
    if kind == "tsm2l":
        return {"block_m": perf_model.choose_params_tsm2l(m, d1, d2, spec,
                                                          dtype)}
    bm, ba, s = perf_model.choose_params_tsmt(m, d1, d2, spec, dtype)
    return {"block_m": bm, "block_a": ba, "splits": s}


def _padded_shape(kind, shape, params):
    """The operand shape ``ops``'s zero-padding produces for ``params`` --
    re-derived here so the audit proves the grid contract holds for what
    actually launches (see ``_tsm2r_impl``/``_tsmt_impl`` padding)."""
    m, d1, d2 = shape
    p = dict(params)
    s = p.get("splits", 1)
    if kind == "tsm2r":
        return (contracts.ceil_mult(m, p["block_m"]),
                contracts.ceil_mult(d1, s * p["block_k"]), d2)
    if kind == "tsm2l":
        return (contracts.ceil_mult(m, p["block_m"]), d1, d2)
    return (contracts.ceil_mult(m, s * p["block_m"]),
            contracts.ceil_mult(d1, p["block_a"]), d2)


def audit_candidate_grids(shapes=None, dtypes=SWEEP_DTYPES,
                          specs=SWEEP_SPECS):
    """Every enumerated candidate must be contract-clean (the enumerators
    filter with ``contracts.feasible``, so any violation here means the
    filter and the checker have drifted apart)."""
    shapes = shapes or SWEEP_SHAPES
    checked, out = 0, []
    for kind, kshapes in shapes.items():
        for shape in kshapes:
            for dtype in dtypes:
                for spec in specs:
                    for params in _candidate_dicts(kind, *shape, spec, dtype):
                        checked += 1
                        out.extend(v for v in contracts.check_kernel_config(
                            kind, shape, params, dtype, spec)
                            if v.rule != "accumulator-limit")
    return checked, out


def _epilogue_config(kind, padded, params, spec):
    """The split-partials epilogue launch a resolved split config implies:
    ``("reduce", (S, rows, cols), {"block_r": ...})``, or None when
    ``reduce_partials`` takes the fused jnp.sum path. Mirrors the
    ``ops._tsm*_impl`` call sites exactly (rows = the padded dim the
    partials stack over, block_r = that dim's block)."""
    s = dict(params).get("splits", 1)
    if s <= 1 or kind == "tsm2l":
        return None
    rows = padded[0] if kind == "tsm2r" else padded[1]
    cols = padded[2]
    blk = params["block_m"] if kind == "tsm2r" else params["block_a"]
    br = kreduce.epilogue_block_r(
        s, rows, cols, block_r=blk,
        vmem_budget=int(contracts.vmem_budget(spec)))
    if br is None:
        return None
    return ("reduce", (s, rows, cols), {"block_r": br})


def audit_resolved_configs(shapes=None, dtypes=SWEEP_DTYPES,
                           specs=SWEEP_SPECS, splits=SWEEP_SPLITS):
    """Analytic picks and ``ops.resolve_params`` outputs (every policy
    split arm) are launchable, and their padded shapes grid exactly --
    including the reduce epilogue grid each split config implies."""
    shapes = shapes or SWEEP_SHAPES
    checked, out = 0, []
    for kind, kshapes in shapes.items():
        for shape in kshapes:
            for dtype in dtypes:
                for spec in specs:
                    configs = [_chooser_pick(kind, *shape, spec, dtype)]
                    for split in splits:
                        if kind == "tsm2l" and split != "auto":
                            continue  # tsm2l has no split dimension
                        pol = tsmm.GemmPolicy(spec=spec, split=split)
                        configs.append(ops.resolve_params(
                            kind, *shape, dtype, pol, interpret=True))
                    for params in configs:
                        checked += 1
                        out.extend(v for v in contracts.check_kernel_config(
                            kind, shape, params, dtype, spec,
                            max_b=tsmm.GemmPolicy().max_skinny_t)
                            if v.rule != "accumulator-limit")
                        padded = _padded_shape(kind, shape, params)
                        out.extend(contracts.check_grid(kind, padded,
                                                        params))
                        epi = _epilogue_config(kind, padded, params, spec)
                        if epi is not None:
                            checked += 1
                            out.extend(contracts.check_grid(*epi))
    return checked, out


def audit_kernel_dataflow(shapes=None, dtypes=SWEEP_DTYPES,
                          specs=SWEEP_SPECS, splits=SWEEP_SPLITS):
    """Grid-dataflow verification of every unique launch the resolver
    sweep reaches (``analysis.kernel_verify``): the five committed kernels
    at their resolved configs plus the reduce epilogues the split configs
    imply. Returns ``(checked, violations, meta)``; ``meta`` documents the
    corner-sampling bound and which configs were sampled."""
    shapes = shapes or SWEEP_SHAPES
    checked, out = 0, []
    seen: set = set()
    sampled: list = []

    def _verify(kind, padded, params, dtype):
        nonlocal checked
        key = (kind, tuple(padded), tuple(sorted(dict(params).items())),
               jnp.dtype(dtype).name)
        if key in seen:
            return
        seen.add(key)
        checked += 1
        vios, info = kernel_verify.verify_kernel_config(
            kind, padded, params, dtype)
        out.extend(vios)
        if not info["exhaustive"]:
            sampled.append({"subject": info["subject"],
                            "grid": list(info["grid"]),
                            "cells": info["cells"]})

    for kind, kshapes in shapes.items():
        for shape in kshapes:
            for dtype in dtypes:
                for spec in specs:
                    configs = [_chooser_pick(kind, *shape, spec, dtype)]
                    for split in splits:
                        if kind == "tsm2l" and split != "auto":
                            continue  # tsm2l has no split dimension
                        pol = tsmm.GemmPolicy(spec=spec, split=split)
                        configs.append(ops.resolve_params(
                            kind, *shape, dtype, pol, interpret=True))
                    for params in configs:
                        padded = _padded_shape(kind, shape, params)
                        _verify(kind, padded, params, dtype)
                        epi = _epilogue_config(kind, padded, params, spec)
                        if epi is not None:
                            _verify(*epi, dtype)
    meta = {"cell_limit": kernel_verify.EXHAUSTIVE_CELL_LIMIT,
            "sampled": sampled}
    return checked, out, meta


def audit_quant_configs(shapes=None, dtypes=SWEEP_DTYPES,
                        specs=SWEEP_SPECS, splits=SWEEP_SPLITS):
    """Quantized (``GemmPolicy.quant="int8"``) candidate grids and
    resolved configs are launchable, grid-exact, and dataflow-clean.

    The int8 operand path changes both the bytes the VMEM footprint
    prices and the sublane quantum (32 rows vs 8), so the sweep re-runs
    the candidate-grid and resolved-config checks at the *effective*
    operand dtype (``jnp.int8``) with the caller dtype as ``out_dtype``
    (the output window stays at the caller's width), then pushes every
    unique quantized launch through the grid-dataflow verifier so the
    f32-accumulator rule covers the q8 kernels too. Returns
    ``(checked, violations, meta)`` like ``kernel-dataflow``."""
    shapes = shapes or SWEEP_SHAPES
    checked, out = 0, []
    seen: set = set()
    sampled: list = []

    def _verify(kind, padded, params, dtype):
        nonlocal checked
        key = (kind, tuple(padded), tuple(sorted(dict(params).items())),
               jnp.dtype(dtype).name)
        if key in seen:
            return
        seen.add(key)
        checked += 1
        vios, info = kernel_verify.verify_kernel_config(
            kind, padded, params, dtype, quant="int8")
        out.extend(vios)
        if not info["exhaustive"]:
            sampled.append({"subject": info["subject"],
                            "grid": list(info["grid"]),
                            "cells": info["cells"]})

    for kind, kshapes in shapes.items():
        for shape in kshapes:
            for spec in specs:
                # Candidate enumeration is operand-dtype driven; price the
                # output window at the widest caller dtype (f32).
                for params in _candidate_dicts(kind, *shape, spec, jnp.int8):
                    checked += 1
                    out.extend(v for v in contracts.check_kernel_config(
                        kind, shape, params, jnp.int8, spec,
                        out_dtype=jnp.float32)
                        if v.rule != "accumulator-limit")
                for dtype in dtypes:
                    configs = []
                    for split in splits:
                        if kind == "tsm2l" and split != "auto":
                            continue  # tsm2l has no split dimension
                        pol = tsmm.GemmPolicy(spec=spec, split=split,
                                              quant="int8")
                        configs.append(ops.resolve_params(
                            kind, *shape, dtype, pol, interpret=True))
                    for params in configs:
                        checked += 1
                        out.extend(v for v in contracts.check_kernel_config(
                            kind, shape, params, jnp.int8, spec,
                            max_b=tsmm.GemmPolicy().max_skinny_t,
                            out_dtype=dtype)
                            if v.rule != "accumulator-limit")
                        padded = _padded_shape(kind, shape, params)
                        out.extend(contracts.check_grid(kind, padded,
                                                        params))
                        _verify(kind, padded, params, dtype)
                        epi = _epilogue_config(kind, padded, params, spec)
                        if epi is not None:
                            checked += 1
                            out.extend(contracts.check_grid(*epi))
    meta = {"cell_limit": kernel_verify.EXHAUSTIVE_CELL_LIMIT,
            "sampled": sampled}
    return checked, out, meta


def audit_qr_configs(qr_shapes=QR_SWEEP_SHAPES, shards=QR_SWEEP_SHARDS,
                     specs=SWEEP_SPECS, splits=SWEEP_SPLITS):
    """Every (kind, shape) stage tall-skinny QR can dispatch -- per
    :func:`contracts.qr_stage_shapes`, replicated and per-shard --
    resolves launchable and grids exactly, across specs and split arms."""
    dtype = jnp.float32  # QR compute dtype by construction
    checked, out = 0, []
    for m, r in qr_shapes:
        for n_shards in shards:
            if n_shards > 1 and m % n_shards != 0:
                continue
            stages = contracts.qr_stage_shapes(m, r, shards=n_shards)
            for kind, shape in stages:
                for spec in specs:
                    for split in splits:
                        if kind == "tsm2l" and split != "auto":
                            continue  # tsm2l has no split dimension
                        pol = tsmm.GemmPolicy(spec=spec, split=split)
                        params = ops.resolve_params(
                            kind, *shape, dtype, pol, interpret=True)
                        checked += 1
                        out.extend(v for v in contracts.check_kernel_config(
                            kind, shape, params, dtype, spec,
                            max_b=tsmm.GemmPolicy().max_skinny_t)
                            if v.rule != "accumulator-limit")
                        out.extend(contracts.check_grid(
                            kind, _padded_shape(kind, shape, params),
                            params))
    return checked, out


def audit_abft_configs(shapes=None, specs=SWEEP_SPECS,
                       splits=("auto", "never")):
    """The online-ABFT surface: policy derivation and checksum shapes.

    Two sweeps. (1) Every reachable (abft, quant, reduce) GemmPolicy combo
    passes ``check_backward_policy`` against its derived backward -- the
    ``abft-policy`` rule proves the guard mode survives into the VJP
    re-dispatch. (2) Every checksum-GEMM shape the wrap can hand the
    dispatcher (:func:`contracts.abft_stage_shapes` over the sweep
    shapes) either classifies dense or resolves to a launchable,
    grid-exact kernel config under every spec/split arm. Checksums are
    f32 by construction (``ft.abft.checksum_weights``), so the sweep
    pins f32; split arms are the ones the wrap's checksum policy can
    carry -- "auto" and "never" (a pinned int split is neutralized to
    "auto" by the wrap: the caller pinned S for the *protected* shape,
    not the skinny checksum shapes)."""
    shapes = shapes if shapes is not None else SWEEP_SHAPES
    dtype = jnp.float32
    checked, out = 0, []
    for abft in ("none", "verify", "correct"):
        for quant in ("none", "int8"):
            for reduce_ in ("psum", "psum_scatter", "none"):
                checked += 1
                p = tsmm.GemmPolicy(abft=abft, quant=quant, reduce=reduce_)
                out.extend(contracts.check_backward_policy(
                    p, tsmm.backward_policy(p)))
    for kind, kind_shapes in shapes.items():
        for shape in kind_shapes:
            for entry, stage in contracts.abft_stage_shapes(kind, shape):
                for spec in specs:
                    for split in splits:
                        pol = tsmm.GemmPolicy(spec=spec, split=split)
                        m, a_, b_ = stage
                        kindc = (tsmm.classify_gemm(m, a_, b_, pol)
                                 if entry == "mm"
                                 else tsmm.classify_gemm_t(m, a_, b_, pol))
                        checked += 1
                        if kindc == "dense":
                            continue  # XLA dot: no launch contract to check
                        if kindc == "tsm2l" and split != "auto":
                            continue  # tsm2l has no split dimension
                        params = ops.resolve_params(
                            kindc, m, a_, b_, dtype, pol, interpret=True)
                        out.extend(v for v in contracts.check_kernel_config(
                            kindc, stage, params, dtype, spec,
                            max_b=tsmm.GemmPolicy().max_skinny_t)
                            if v.rule != "accumulator-limit")
                        out.extend(contracts.check_grid(
                            kindc, _padded_shape(kindc, stage, params),
                            params))
    return checked, out


def audit_tuning_table(table: autotune.TuningTable):
    """Every committed record re-checks under the table's fitted spec."""
    known = tuple(tsmm.executors())
    checked, out = 0, []
    for r in table.records:
        checked += 1
        try:
            spec = perf_model.get_spec(r.spec_name)
        except ValueError:
            out.append(contracts.Violation(
                "unknown-spec", r.key,
                f"record names unknown TPU spec {r.spec_name!r}"))
            continue
        eff = table.fitted_spec(r.kind, *r.shape, dtype=r.dtype, spec=spec)
        out.extend(contracts.check_tuning_record(
            r.kind, r.shape, r.params_dict, r.dtype, eff,
            executor=r.executor, known_executors=known))
        want_bucket = autotune.bucket_shape(*r.shape)
        if tuple(r.bucket) != want_bucket:
            out.append(contracts.Violation(
                "bucket-mismatch", r.key,
                f"record bucket {tuple(r.bucket)} != bucket_shape{r.shape}"
                f"={want_bucket}: lookups will never hit this entry"))
    return checked, out


# Reachable field combos for the backward-policy sweep: every mode class
# (auto, the dense pin, a forward-kind force), every reduce mode, every
# split-knob class, and executor pinned/unpinned.
_POLICY_MODES = ("auto", "dense", "tsm2r", "tsm2l")
_POLICY_SPLITS = ("auto", "never", 4)
_POLICY_EXECUTORS = (None, "pallas-tpu", "shard_map", "shard_map-scatter")


def audit_policies():
    """backward_policy invariants over the reachable GemmPolicy combos,
    plus well-formedness of every registered executor's reduce contract."""
    checked, out = 0, []
    for mode in _POLICY_MODES:
        for reduce_ in ("psum", "psum_scatter", "none"):
            for split in _POLICY_SPLITS:
                for executor in _POLICY_EXECUTORS:
                    checked += 1
                    p = tsmm.GemmPolicy(mode=mode, reduce=reduce_,
                                        split=split, executor=executor)
                    out.extend(contracts.check_backward_policy(
                        p, tsmm.backward_policy(p)))
    for name in tsmm.executors():
        checked += 1
        declared = tsmm.executor_reduce_contract(name)
        bad = [m for m in declared if m not in ("psum", "psum_scatter",
                                                "none")]
        if bad or not declared:
            out.append(contracts.Violation(
                "executor-contract-modes", f"executor {name!r}",
                f"declared reduce contract {declared!r} is "
                f"{'empty' if not declared else f'invalid: {bad}'}"))
    return checked, out


def audit_bench(bench: dict):
    """Dispatch-sanity arms of a committed BENCH_*.json report."""
    known = tuple(tsmm.executors())
    checked, out = 0, []
    for arm in bench.get("dispatch_sanity", ()):
        checked += 1
        name = arm.get("arm", "?")
        subject = f"dispatch_sanity arm {name!r}"
        observed = arm.get("observed", [])
        observed = [observed] if isinstance(observed, str) else list(observed)
        expected = arm.get("expected", [])
        expected = [expected] if isinstance(expected, str) else list(expected)
        if not arm.get("ok", False):
            out.append(contracts.Violation(
                "bench-dispatch-failed", subject,
                f"arm recorded ok={arm.get('ok')!r}: the committed baseline "
                "contains a failed dispatch assertion"))
        if observed != expected:
            out.append(contracts.Violation(
                "bench-dispatch-mismatch", subject,
                f"observed executors {observed} != expected {expected}"))
        for ex in observed:
            if ex not in known:
                out.append(contracts.Violation(
                    "unknown-executor", subject,
                    f"observed executor {ex!r} is not registered "
                    f"(known: {sorted(known)})"))
        if "shard_map-scatter" in observed:
            _, d1, _ = arm.get("shape", (0, 0, 0))
            for v in contracts.check_scatter(d1, BENCH_MESH_SHARDS):
                out.append(contracts.Violation(v.rule, subject, v.detail))
    return checked, out


def _load_table(table_path, bench):
    if table_path is not None:
        return autotune.TuningTable.load(table_path)
    embedded = (bench or {}).get("autotune", {}).get("table")
    if embedded:
        return autotune.TuningTable.from_json(embedded)
    return None


def run_audit(*, bench_path=None, table_path=None, shapes=None) -> dict:
    """Run every section; return the machine-readable report."""
    bench = None
    path = bench_path if bench_path is not None else (
        DEFAULT_BENCH if DEFAULT_BENCH.exists() else None)
    if path is not None:
        with open(path) as f:
            bench = json.load(f)
    table = _load_table(table_path, bench)

    # Section values are (checked, violations) or (checked, violations,
    # meta) -- meta keys merge into the section's report entry.
    sections: dict[str, tuple] = {
        "candidate-grids": audit_candidate_grids(shapes=shapes),
        "resolved-configs": audit_resolved_configs(shapes=shapes),
        "kernel-dataflow": audit_kernel_dataflow(shapes=shapes),
        "quant-resolved": audit_quant_configs(shapes=shapes),
        "qr-resolved": audit_qr_configs(),
        "abft-resolved": audit_abft_configs(shapes=shapes),
        "policies": audit_policies(),
    }
    if table is not None:
        sections["tuning-table"] = audit_tuning_table(table)
    if bench is not None:
        sections["bench-dispatch"] = audit_bench(bench)

    report = {
        "schema": AUDIT_SCHEMA,
        "bench": str(path) if path is not None else None,
        "sections": {
            name: {"checked": sec[0],
                   "violations": [v.to_json() for v in sec[1]],
                   **(sec[2] if len(sec) > 2 else {})}
            for name, sec in sections.items()
        },
    }
    report["checked"] = sum(sec[0] for sec in sections.values())
    report["violations"] = sum(len(sec[1]) for sec in sections.values())
    report["ok"] = report["violations"] == 0
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="Audit kernel-launch contracts over the full "
                    "configuration space (see repro.analysis.contracts).")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any violation (CI mode)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--bench", metavar="PATH", default=None,
                    help="BENCH_*.json to audit (default: the committed "
                         "benchmarks/BENCH_baseline.json)")
    ap.add_argument("--table", metavar="PATH", default=None,
                    help="tuning-table JSON to audit (default: the table "
                         "embedded in the bench report)")
    args = ap.parse_args(argv)

    report = run_audit(bench_path=args.bench, table_path=args.table)
    for name, sec in report["sections"].items():
        status = "ok" if not sec["violations"] else (
            f"{len(sec['violations'])} violation(s)")
        print(f"{name}: {sec['checked']} checked, {status}")
        if sec.get("sampled"):
            print(f"  (corner-sampled {len(sec['sampled'])} grid(s) above "
                  f"{sec['cell_limit']} cells -- see the JSON report)")
        for v in sec["violations"]:
            print(f"  [{v['rule']}] {v['subject']}: {v['detail']}")
    print(f"repro.analysis.audit: {report['checked']} checked, "
          f"{report['violations']} violation(s)"
          + (" -- clean" if report["ok"] else ""))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"report written to {args.json}")
    return 1 if (args.strict and not report["ok"]) else 0


if __name__ == "__main__":
    sys.exit(main())
