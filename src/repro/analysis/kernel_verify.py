"""Grid-dataflow verifier for the Pallas kernel layer.

``analysis.contracts`` proves the *numbers* of a launch configuration
(VMEM budgets, quantization, divisibility). This module proves the
*dataflow*: that the index maps, ``dimension_semantics`` and ``pl.when``
guard structure of every committed kernel actually implement the
race-free, initialized, f32-accumulated schedule the paper's algorithms
assume. A swapped index-map lambda, a dropped init guard, or a
``parallel`` tag on a reduction dim all pass the config auditor clean and
all corrupt results on real TPU while interpret-mode tests (which
serialize the grid) stay green -- this is the layer that catches them
statically.

How it works
------------

1. **Capture.** Kernel entry points route their ``pl.pallas_call``
   through ``kernels.compat.pallas_call``; :func:`capture_kernel` invokes
   an entry under ``jax.eval_shape`` inside ``compat.capture_launches``,
   so each launch's grid, BlockSpec block shapes + index-map callables,
   ``dimension_semantics``, operand/out avals, and scratch
   ShapeDtypeStructs are recorded without touching a device. The jit
   wrapper is bypassed (``__wrapped__``) so the capture cannot be
   swallowed by a warm jit cache.
2. **Cell enumeration.** Index maps are plain Python callables on int
   grid coordinates, so they are evaluated directly: exhaustively up to
   :data:`EXHAUSTIVE_CELL_LIMIT` grid cells, corner-sampled above it
   (first/second/middle/last-two coordinates per dim -- the values where
   ``s * steps + j``-style arithmetic drifts first). Sampled runs are
   flagged in the audit report (``sampled``): a clean sampled result is
   evidence, not proof.
3. **Invariant families** (one stable rule id each):

   ====================  ==================================================
   ``write-race``        two cells with different ``parallel`` coordinates
                         map an output to the same block
   ``revisit-init`` /    an output/scratch block revisited along
   ``revisit-flush``     ``arbitrary`` dims must be zero-initialized under
                         ``pl.when(program_id(d) == 0)`` (accumulators)
                         and flushed under ``pl.when(program_id(d) ==
                         num_programs(d) - 1)`` (scratch-staged outputs) --
                         detected by AST inspection of the kernel fn
   ``index-bounds``      block_index x block_shape must lie inside the
                         padded operand dims for every cell
   ``accumulator-dtype`` scratch/partial accumulators are f32 regardless
                         of operand dtype
   ====================  ==================================================

   Supporting rules: ``semantics-invalid``, ``index-map-error``,
   ``index-map-arity``, ``kernel-arity``, ``guard-unverifiable``,
   ``capture-empty``, ``capture-count``, and ``launch-meta-drift`` (the
   captured grid/semantics must equal the pure
   ``contracts.launch_grid`` derivation that ``kernels/ops.py`` stamps
   onto ``DispatchEvent.launches``).

``analysis/audit.py`` sweeps :func:`verify_kernel_config` over the same
resolved-config space as the existing sections (all five kernels plus the
``kernels/reduce.py`` epilogue) as the ``kernel-dataflow`` report section,
enforced under ``--strict`` in CI.
"""

from __future__ import annotations

import ast
import functools
import inspect
import itertools
import math
import operator
import textwrap

import jax
import jax.numpy as jnp

from repro.analysis import contracts
from repro.analysis.contracts import Violation
from repro.kernels import compat

__all__ = [
    "EXHAUSTIVE_CELL_LIMIT",
    "sample_cells",
    "capture_kernel",
    "verify_capture",
    "verify_kernel_config",
]

# Above this many grid cells the index-map evaluation corner-samples
# instead of enumerating. Committed kernels' grids are products of
# dim/block quotients -- a few thousand cells at the paper shapes -- so
# the exhaustive path is the common one.
EXHAUSTIVE_CELL_LIMIT = 4096


def sample_cells(grid) -> tuple[list[tuple[int, ...]], bool]:
    """Grid cells to evaluate: ``(cells, exhaustive)``.

    Exhaustive product under :data:`EXHAUSTIVE_CELL_LIMIT`; otherwise the
    per-dim corner set {0, 1, mid, last-1, last} (<= 5^ndim cells) --
    enough to catch offset/stride drift in affine index maps, documented
    as a sample (not a proof) in the audit report.
    """
    total = math.prod(grid)
    if total <= EXHAUSTIVE_CELL_LIMIT:
        return list(itertools.product(*[range(g) for g in grid])), True
    axes = []
    for g in grid:
        axes.append(sorted({v for v in (0, 1, g // 2, g - 2, g - 1)
                            if 0 <= v < g}))
    return list(itertools.product(*axes)), False


# ---------------------------------------------------------------------------
# Capture: abstract invocation of the committed kernel entry points
# ---------------------------------------------------------------------------

def _unjit(fn):
    """The traced function under a ``jax.jit`` wrapper. Bypassing jit is
    what makes capture reliable: a warm jit cache would skip re-tracing
    (and therefore skip the pallas_call construction being recorded)."""
    return getattr(fn, "__wrapped__", fn)


def capture_kernel(kind, padded_shape, params, dtype, *, quant="none"
                   ) -> list[compat.LaunchCapture]:
    """Launch captures of the committed ``kind`` entry at ``padded_shape``.

    ``padded_shape`` follows the ``check_grid`` convention -- the operand
    shape after ``ops``' zero-padding (``audit._padded_shape``), or the
    ``(splits, rows, cols)`` partials stack for ``kind="reduce"`` -- so
    the abstract invocation is exactly the launch dispatch performs.

    ``quant="int8"`` captures the quantized entry instead: int8 operand
    avals plus the f32 scale sidecars (``(m//block_m, 1)`` per-row-block
    for streamed operands, ``(1, 1)`` per-tensor for the resident B of
    tsm2r/tsm2l), with ``dtype`` becoming the kernel's ``out_dtype``.
    ``kind="reduce"`` has no quantized variant (split partials are f32
    either way).
    """
    from repro.kernels import quant as kquant
    from repro.kernels import reduce as kreduce
    from repro.kernels import tsm2l, tsm2r, tsmt

    p = dict(params)
    s = p.get("splits", 1)
    dtype = jnp.dtype(dtype)
    q8 = quant == "int8"
    if q8 and kind == "reduce":
        raise ValueError("kind='reduce' has no quantized variant")
    f32 = jnp.float32
    if kind == "tsm2r":
        m, k, n = padded_shape
        if q8:
            args = (jax.ShapeDtypeStruct((m, k), jnp.int8),
                    jax.ShapeDtypeStruct((k, n), jnp.int8),
                    jax.ShapeDtypeStruct((m // p["block_m"], 1), f32),
                    jax.ShapeDtypeStruct((1, 1), f32))
            if s == 1:
                fn = functools.partial(_unjit(kquant.tsm2r_q8_pallas),
                                       out_dtype=dtype,
                                       block_m=p["block_m"],
                                       block_k=p["block_k"], interpret=True)
            else:
                # Split partials are f32 regardless of caller dtype.
                fn = functools.partial(_unjit(kquant.tsm2r_q8_pallas_split),
                                       block_m=p["block_m"],
                                       block_k=p["block_k"], splits=s,
                                       interpret=True)
        else:
            args = (jax.ShapeDtypeStruct((m, k), dtype),
                    jax.ShapeDtypeStruct((k, n), dtype))
            if s == 1:
                fn = functools.partial(_unjit(tsm2r.tsm2r_pallas),
                                       block_m=p["block_m"],
                                       block_k=p["block_k"], interpret=True)
            else:
                fn = functools.partial(_unjit(tsm2r.tsm2r_pallas_split),
                                       block_m=p["block_m"],
                                       block_k=p["block_k"], splits=s,
                                       interpret=True)
    elif kind == "tsm2l":
        m, k, n = padded_shape
        if q8:
            args = (jax.ShapeDtypeStruct((m, k), jnp.int8),
                    jax.ShapeDtypeStruct((k, n), jnp.int8),
                    jax.ShapeDtypeStruct((m // p["block_m"], 1), f32),
                    jax.ShapeDtypeStruct((1, 1), f32))
            fn = functools.partial(_unjit(kquant.tsm2l_q8_pallas),
                                   out_dtype=dtype, block_m=p["block_m"],
                                   interpret=True)
        else:
            args = (jax.ShapeDtypeStruct((m, k), dtype),
                    jax.ShapeDtypeStruct((k, n), dtype))
            fn = functools.partial(_unjit(tsm2l.tsm2l_pallas),
                                   block_m=p["block_m"], interpret=True)
    elif kind == "tsmt":
        m, a, b = padded_shape
        if q8:
            args = (jax.ShapeDtypeStruct((m, a), jnp.int8),
                    jax.ShapeDtypeStruct((m, b), jnp.int8),
                    jax.ShapeDtypeStruct((m // p["block_m"], 1), f32),
                    jax.ShapeDtypeStruct((m // p["block_m"], 1), f32))
            if s == 1:
                fn = functools.partial(_unjit(kquant.tsmt_q8_pallas),
                                       out_dtype=dtype,
                                       block_m=p["block_m"],
                                       block_a=p["block_a"], interpret=True)
            else:
                # Split partials are f32 regardless of caller dtype.
                fn = functools.partial(_unjit(kquant.tsmt_q8_pallas_split),
                                       block_m=p["block_m"],
                                       block_a=p["block_a"], splits=s,
                                       interpret=True)
        else:
            args = (jax.ShapeDtypeStruct((m, a), dtype),
                    jax.ShapeDtypeStruct((m, b), dtype))
            if s == 1:
                fn = functools.partial(_unjit(tsmt.tsmt_pallas),
                                       block_m=p["block_m"],
                                       block_a=p["block_a"], interpret=True)
            else:
                fn = functools.partial(_unjit(tsmt.tsmt_pallas_split),
                                       block_m=p["block_m"],
                                       block_a=p["block_a"], splits=s,
                                       interpret=True)
    elif kind == "reduce":
        stack, rows, cols = padded_shape
        args = (jax.ShapeDtypeStruct((stack, rows, cols), jnp.float32),)
        fn = functools.partial(_unjit(kreduce.sum_partials_pallas),
                               block_r=p["block_r"], out_dtype=dtype,
                               interpret=True)
    else:
        raise ValueError(f"unknown kernel kind {kind!r}")

    with compat.capture_launches() as log:
        jax.eval_shape(fn, *args)
    return list(log)


# ---------------------------------------------------------------------------
# AST guard inspection (pl.when init/flush patterns)
# ---------------------------------------------------------------------------

def _dotted(node) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _grid_fn_dim(node, suffix) -> int | None:
    """Dim argument of a ``pl.program_id(d)`` / ``pl.num_programs(d)``
    call node, else None."""
    if (isinstance(node, ast.Call)
            and _dotted(node.func).split(".")[-1] == suffix
            and len(node.args) == 1
            and isinstance(node.args[0], ast.Constant)
            and isinstance(node.args[0].value, int)):
        return node.args[0].value
    return None


def _classify_cond(cond):
    """Guard class of a ``pl.when`` condition: ``("first", d)`` for
    ``program_id(d) == 0``, ``("last", d)`` for
    ``program_id(d) == num_programs(d) - 1``, else ``("other", None)``."""
    if (isinstance(cond, ast.Compare) and len(cond.ops) == 1
            and isinstance(cond.ops[0], ast.Eq)):
        for a, b in ((cond.left, cond.comparators[0]),
                     (cond.comparators[0], cond.left)):
            d = _grid_fn_dim(a, "program_id")
            if d is None:
                continue
            if isinstance(b, ast.Constant) and b.value == 0:
                return ("first", d)
            if (isinstance(b, ast.BinOp) and isinstance(b.op, ast.Sub)
                    and isinstance(b.right, ast.Constant)
                    and b.right.value == 1
                    and _grid_fn_dim(b.left, "num_programs") == d):
                return ("last", d)
    return ("other", None)


def _classify_when(deco):
    """Guard class of a ``@pl.when(cond)`` decorator node, else None."""
    if (isinstance(deco, ast.Call)
            and _dotted(deco.func).split(".")[-1] == "when"
            and len(deco.args) == 1):
        return _classify_cond(deco.args[0])
    return None


def _collect_writes(stmts, guard, writes):
    """Record (kind, guard) per ref-subscript write, descending into
    ``pl.when``-decorated inner defs (which set the guard) and ordinary
    compound statements (which inherit it)."""
    for st in stmts:
        if isinstance(st, (ast.Assign, ast.AugAssign)):
            targets = st.targets if isinstance(st, ast.Assign) else [st.target]
            for t in targets:
                if isinstance(t, ast.Subscript) and isinstance(t.value,
                                                               ast.Name):
                    kind = ("accum" if isinstance(st, ast.AugAssign)
                            else "assign")
                    writes.setdefault(t.value.id, []).append((kind, guard))
        elif isinstance(st, ast.FunctionDef):
            g = guard
            for deco in st.decorator_list:
                cls = _classify_when(deco)
                if cls is not None:
                    g = cls
                    break
            _collect_writes(st.body, g, writes)
        elif isinstance(st, (ast.If, ast.With, ast.For, ast.While)):
            _collect_writes(st.body, guard, writes)
            _collect_writes(st.orelse, guard, writes)


def _guard_summary(kernel_fn) -> dict | None:
    """``{ref_name: [(write_kind, guard), ...]}`` from the kernel source,
    or None when the source is unavailable (lambdas, C extensions)."""
    try:
        src = textwrap.dedent(inspect.getsource(kernel_fn))
        tree = ast.parse(src)
    except (OSError, TypeError, SyntaxError, IndentationError):
        return None
    fdef = next((n for n in tree.body if isinstance(n, ast.FunctionDef)),
                None)
    if fdef is None:
        return None
    writes: dict = {}
    _collect_writes(fdef.body, None, writes)
    return writes


def _param_roles(cap) -> tuple[list, list] | None:
    """``(output_names, scratch_names)`` of the kernel fn's ref params by
    pallas position convention (inputs, outputs, scratch), or None when
    the signature is unreadable."""
    try:
        names = list(inspect.signature(cap.kernel).parameters)
    except (TypeError, ValueError):
        return None
    n_in, n_out = len(cap.in_specs), len(cap.out_specs)
    if len(names) != n_in + n_out + len(cap.scratch_shapes):
        return None
    return names[n_in:n_in + n_out], names[n_in + n_out:]


# ---------------------------------------------------------------------------
# The verifier
# ---------------------------------------------------------------------------

def _eval_maps(cap, cells, sub):
    """Evaluate every BlockSpec's index map over ``cells``.

    Returns ``(violations, out_maps)``; ``out_maps`` is a list of
    ``(out_index, {cell: block_index})`` for the output specs that
    evaluated clean (bounds violations are reported once per spec, at the
    first offending cell).
    """
    out: list[Violation] = []
    out_maps = []
    specs = ([(f"in[{i}]", s, op.shape) for i, (s, op)
              in enumerate(zip(cap.in_specs, cap.operands))]
             + [(f"out[{i}]", s, o.shape) for i, (s, o)
                in enumerate(zip(cap.out_specs, cap.out_shapes))])
    for label, spec, oshape in specs:
        mapping: dict = {}
        clean = True
        for cell in cells:
            try:
                idx = spec.index_map(*cell)
            except Exception as e:  # noqa: BLE001 - report, don't crash
                out.append(Violation(
                    "index-map-error", sub,
                    f"{label} index map raised at cell {cell}: {e!r}"))
                clean = False
                break
            if not isinstance(idx, tuple):
                idx = (idx,)
            try:
                idx = tuple(operator.index(v) for v in idx)
            except TypeError:
                out.append(Violation(
                    "index-map-error", sub,
                    f"{label} index map returned non-integer block index "
                    f"{idx!r} at cell {cell}"))
                clean = False
                break
            block = tuple(spec.block_shape)
            if len(idx) != len(block) or len(block) != len(oshape):
                out.append(Violation(
                    "index-map-arity", sub,
                    f"{label}: block index {idx} / block shape {block} / "
                    f"operand rank {len(oshape)} disagree"))
                clean = False
                break
            oob = False
            for a, bi in enumerate(idx):
                bs = block[a] if block[a] is not None else oshape[a]
                if bi < 0 or (bi + 1) * bs > oshape[a]:
                    out.append(Violation(
                        "index-bounds", sub,
                        f"{label} cell {cell}: block {idx} x shape {block} "
                        f"reaches outside operand dims {tuple(oshape)} "
                        f"(axis {a})"))
                    oob = True
                    break
            if oob:
                clean = False
                break
            mapping[cell] = idx
        if label.startswith("out") and clean:
            out_maps.append((int(label[4:-1]), mapping))
    return out, out_maps


def verify_capture(cap, *, subject: str | None = None) -> list[Violation]:
    """All dataflow violations of one captured launch (empty == clean)."""
    sub = subject or cap.name
    out: list[Violation] = []
    grid = tuple(int(g) for g in cap.grid)
    ndim = len(grid)
    sem = cap.dimension_semantics
    if sem is None:
        # Undeclared semantics serialize the whole grid (safe); the RA006
        # lint rule separately requires committed kernels to declare.
        sem = ("arbitrary",) * ndim
    if len(sem) != ndim or any(x not in ("parallel", "arbitrary")
                               for x in sem):
        return [Violation(
            "semantics-invalid", sub,
            f"dimension_semantics {sem} does not label grid {grid} "
            "(one 'parallel'/'arbitrary' per dim)")]

    # accumulator dtype: scratch is f32, always
    for i, sds in enumerate(cap.scratch_shapes):
        if jnp.dtype(sds.dtype) != jnp.float32:
            out.append(Violation(
                "accumulator-dtype", sub,
                f"scratch[{i}] accumulates in "
                f"{jnp.dtype(sds.dtype).name}; partial accumulators must "
                "be float32 regardless of operand dtype"))

    cells, _ = sample_cells(grid)
    map_vios, out_maps = _eval_maps(cap, cells, sub)
    out.extend(map_vios)

    par_dims = [d for d in range(ndim) if sem[d] == "parallel"]
    roles = _param_roles(cap)
    summary = _guard_summary(cap.kernel)

    for i_out, mapping in out_maps:
        groups: dict = {}
        for cell, idx in mapping.items():
            groups.setdefault(idx, []).append(cell)
        raced = False
        revisit: set[int] = set()
        for idx, cs in groups.items():
            if len(cs) < 2:
                continue
            projs: dict = {}
            for c in cs:
                projs.setdefault(tuple(c[d] for d in par_dims),
                                 c)
            if len(projs) > 1 and not raced:
                raced = True
                c1, c2 = list(projs.values())[:2]
                out.append(Violation(
                    "write-race", sub,
                    f"out[{i_out}]: cells {c1} and {c2} differ in parallel "
                    f"dims {par_dims} but both write block {idx} -- "
                    "concurrent grid cells race on the output"))
            for d in range(ndim):
                if len({c[d] for c in cs}) > 1:
                    revisit.add(d)
        if raced or not revisit:
            continue

        # Revisits happen only along arbitrary dims here (no race), so the
        # kernel body must carry the init/flush guard discipline.
        if roles is None:
            out.append(Violation(
                "kernel-arity", sub,
                f"kernel fn params do not match "
                f"{len(cap.in_specs)} in + {len(cap.out_specs)} out + "
                f"{len(cap.scratch_shapes)} scratch refs"))
            continue
        out_names, scratch_names = roles
        if summary is None:
            out.append(Violation(
                "guard-unverifiable", sub,
                f"out[{i_out}] is revisited along dims {sorted(revisit)} "
                "but the kernel source is unavailable for pl.when guard "
                "inspection"))
            continue
        ref = out_names[i_out]
        writes = summary.get(ref, [])
        accum_guards = [g for k, g in writes if k == "accum"]
        assign_guards = [g for k, g in writes if k == "assign"]

        if accum_guards:
            # Direct accumulation (split kernels): the output block must be
            # zero-initialized on the first step of each revisit dim, and
            # accumulate in f32.
            for d in sorted(revisit):
                if ("first", d) not in assign_guards:
                    out.append(Violation(
                        "revisit-init", sub,
                        f"out[{i_out}] ({ref}) accumulates across revisits "
                        f"along dim {d} without a "
                        f"pl.when(pl.program_id({d}) == 0) zero-init"))
            odt = jnp.dtype(cap.out_shapes[i_out].dtype)
            if odt != jnp.float32:
                out.append(Violation(
                    "accumulator-dtype", sub,
                    f"out[{i_out}] ({ref}) is a revisited accumulator of "
                    f"dtype {odt.name}; partial accumulators must be "
                    "float32"))
        else:
            # Scratch-staged pattern: every write to the revisited output
            # must sit under the last-step flush guard...
            for d in sorted(revisit):
                if not assign_guards or any(g != ("last", d)
                                            for g in assign_guards):
                    out.append(Violation(
                        "revisit-flush", sub,
                        f"out[{i_out}] ({ref}) is revisited along dim {d} "
                        "but written outside a pl.when(pl.program_id"
                        f"({d}) == pl.num_programs({d}) - 1) flush guard"))
            # ...and the scratch accumulators feeding it need first-step
            # init on the same dims.
            for sname in scratch_names:
                swrites = summary.get(sname, [])
                if not any(k == "accum" for k, _ in swrites):
                    continue
                sassigns = [g for k, g in swrites if k == "assign"]
                for d in sorted(revisit):
                    if ("first", d) not in sassigns:
                        out.append(Violation(
                            "revisit-init", sub,
                            f"scratch {sname} accumulates across dim {d} "
                            "revisits without a pl.when(pl.program_id"
                            f"({d}) == 0) zero-init"))
    return out


def verify_kernel_config(kind, padded_shape, params, dtype, *, quant="none"
                         ) -> tuple[list[Violation], dict]:
    """Capture + verify one committed kernel configuration.

    Returns ``(violations, info)``; ``info`` reports the grid, whether the
    cell enumeration was exhaustive, and the capture count -- the audit
    section logs non-exhaustive entries. Beyond :func:`verify_capture`'s
    families this proves ``launch-meta-drift``: the captured grid and
    semantics equal the pure ``contracts.launch_grid`` derivation the
    dispatcher stamps onto ``DispatchEvent.launches`` (quantized launches
    share the unquantized grid derivation -- the scale sidecars add
    BlockSpecs, not grid dims).
    """
    p = dict(params)
    tag = " int8" if quant == "int8" else ""
    sub = (f"{kind} padded {tuple(padded_shape)} "
           f"{jnp.dtype(dtype).name}{tag} {p}")
    caps = capture_kernel(kind, padded_shape, p, dtype, quant=quant)
    if not caps:
        return ([Violation(
            "capture-empty", sub,
            "entry point constructed no pallas_call under capture -- is "
            "the kernel routed through compat.pallas_call?")],
            {"subject": sub, "grid": (), "cells": 0, "exhaustive": True,
             "launches": 0})
    out: list[Violation] = []
    if len(caps) != 1:
        out.append(Violation(
            "capture-count", sub,
            f"entry point launched {len(caps)} pallas_calls; kernel "
            "entries launch exactly one (epilogues are separate entries)"))
    for cap in caps:
        out.extend(verify_capture(cap, subject=sub))
    want_grid, want_sem = contracts.launch_grid(kind, padded_shape, p)
    got = caps[0]
    got_sem = got.dimension_semantics
    if (tuple(got.grid) != tuple(want_grid)
            or tuple(got_sem or ()) != tuple(want_sem)):
        out.append(Violation(
            "launch-meta-drift", sub,
            f"captured grid {tuple(got.grid)} / semantics {got_sem} != "
            f"contracts.launch_grid {tuple(want_grid)} / {want_sem}: the "
            "DispatchEvent launch metadata no longer describes the real "
            "launch"))
    cells, exhaustive = sample_cells(tuple(int(g) for g in got.grid))
    info = {"subject": sub, "grid": tuple(int(g) for g in got.grid),
            "cells": len(cells), "exhaustive": exhaustive,
            "launches": len(caps)}
    return out, info
