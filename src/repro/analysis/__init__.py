"""Static analysis for the TSM2X framework: decidable-offline guarantees.

Four layers, all consumed by CI (the ``analysis`` job) and by tests:

* :mod:`repro.analysis.contracts` -- the single source of truth for every
  kernel-feasibility predicate the runtime choosers enforce (VMEM
  footprint, lane/sublane quantization, split-K whole-slice feasibility,
  grid divisibility, the TSMT accumulator limit, psum_scatter
  divisibility, backward-policy semantics). ``core.perf_model`` and
  ``kernels.ops`` call these predicates instead of carrying private
  copies, so the model can never again score a block the kernel won't run
  (the PR-3 lane-mismatch class).
* :mod:`repro.analysis.audit` -- the standalone auditor
  (``python -m repro.analysis.audit``): sweeps the full candidate grids,
  committed tuning tables, reachable GemmPolicy combinations, the
  executor registry and the benchmark baseline's dispatch-sanity arms
  against the contracts, emitting a machine-readable violations report.
* :mod:`repro.analysis.kernel_verify` -- the grid-dataflow verifier:
  captures every ``pallas_call`` the committed kernels construct (via the
  ``kernels.compat`` recording shim under ``jax.eval_shape``) and proves
  write-disjointness across ``parallel`` grid dims, ``pl.when``
  init/flush guard discipline on revisited blocks, index-map bounds, and
  f32 accumulator dtype -- the invariants that corrupt results on real
  TPU while interpret-mode tests stay green. Runs as the auditor's
  ``kernel-dataflow`` section; imported lazily (it pulls in the kernel
  modules).
* :mod:`repro.analysis.lint` -- AST-based repo invariant linter (layer
  boundaries: ``jax._src`` confinement, tsmm-routed parameter matmuls,
  env reads, executor reduce-contract declarations, explicit
  ``dimension_semantics`` on every kernel launch).
"""

from repro.analysis import contracts
from repro.analysis.contracts import Violation

__all__ = ["contracts", "Violation"]
