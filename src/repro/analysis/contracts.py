"""Kernel-launch contracts: every feasibility predicate, in one pure module.

The paper's method is choosing launch parameters analytically; the price is
that the *model*, the *dispatcher* and the *kernels* must agree on what a
legal configuration is. PR 3 found the cost of disagreement the hard way (a
sublane-quantized clamp in ``ops.py`` against a lane-quantized filter in
``perf_model`` silently launched blocks the model never scored). This module
is the fix-by-construction: the predicates live HERE, side-effect-free, and
both halves import them --

* ``core.perf_model`` builds its candidate grids from :func:`feasible`,
* ``kernels/ops.py`` clamps resolved params with :func:`ceil_mult` and
  (under ``GemmPolicy.verify_contracts``) asserts the chosen config with
  :func:`check_kernel_config`,
* ``analysis/audit.py`` sweeps everything the choosers can emit through the
  same checks offline.

Import discipline: stdlib + ``jax.numpy`` ONLY (jnp is used for dtype
introspection, never for arrays). Nothing from ``repro.*`` -- the contract
layer must be importable by every other layer without cycles. ``spec`` and
``policy`` arguments are duck-typed (``TPUSpec`` / ``GemmPolicy`` satisfy
them) for the same reason.

Shapes are ``(m, d1, d2)`` triples in the tuning-table convention:
``(m, k, n)`` for tsm2r/tsm2l, ``(m, a, b)`` for tsmt (m is the tall dim;
the *reduction* is k for tsm2r, m for tsmt, and VMEM-resident for tsm2l).
Params are the kwargs dicts the ops take: ``block_m``/``block_k``/
``block_a``/``splits``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = [
    "KINDS",
    "Violation",
    "ceil_mult",
    "bytes_per_elem",
    "min_sublane",
    "vmem_budget",
    "tsm2r_footprint",
    "tsm2l_footprint",
    "tsmt_footprint",
    "kernel_footprint",
    "reduction_axis",
    "feasible",
    "check_kernel_config",
    "check_grid",
    "launch_grid",
    "scatter_divisible",
    "check_scatter",
    "check_backward_policy",
    "check_tuning_record",
    "executor_reduce_ok",
    "qr_stage_shapes",
    "abft_stage_shapes",
    "TSMT_MAX_B",
    "ABFT_TOL_FACTOR",
]

KINDS = ("tsm2r", "tsm2l", "tsmt")

# The TSMT kernels keep their (block_a, b) f32 accumulator as ONE unblocked
# VMEM tile; this is the hard cap on the small output dim (kernels/ops.py
# re-exports it -- the value is a contract, so it lives here).
TSMT_MAX_B = 512

# Safety margin on the online-ABFT detection tolerance (``ft/abft.py``'s
# ``tolerance``): the threshold is ABFT_TOL_FACTOR * eps * (sqrt(rows) +
# sqrt(reduction) + 32) * column_magnitude. The sqrt terms are random-walk
# rounding growth over the checksum reduction and the protected GEMM's own
# contraction; the factor absorbs the distribution's tail (tuned against
# the clean-run false-positive tests -- a genuine high-order bit flip sits
# many orders of magnitude above this line, so the margin is cheap).
ABFT_TOL_FACTOR = 16.0

# Required param keys per kind (schema half of the tuning-record contract).
PARAM_KEYS = {
    "tsm2r": ("block_m", "block_k"),
    "tsm2l": ("block_m",),
    "tsmt": ("block_m", "block_a"),
}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken contract: which rule, on what subject, and why."""

    rule: str        # stable rule id, e.g. "vmem-budget", "lane-quant"
    subject: str     # what was checked, e.g. "tsm2r (4096, 4096, 16) f32"
    detail: str      # human-readable explanation with the numbers

    def to_json(self) -> dict:
        return {"rule": self.rule, "subject": self.subject,
                "detail": self.detail}


def ceil_mult(x: int, q: int) -> int:
    """Smallest multiple of ``q`` >= ``x`` (the quantization primitive)."""
    return ((x + q - 1) // q) * q


def bytes_per_elem(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def min_sublane(spec, dtype) -> int:
    """Dtype-aware sublane quantum for ``block_m``.

    4- and 2-byte dtypes keep the spec's f32 sublane granularity -- the
    historical contract: every kernel accumulator is f32, so 8-row
    quantization is what the pipeline actually stages. 1-byte operands
    (the int8 quantized path) have a ``(4 * sublane, lane)`` native tile:
    a block_m off that quantum still compiles but Mosaic pads every int8
    window 4x -- exactly the silent drift class these contracts kill, so
    int8 configs quantize to the full 32-row tile.
    """
    if bytes_per_elem(dtype) == 1:
        return spec.sublane * 4
    return spec.sublane


def vmem_budget(spec) -> float:
    """Bytes of VMEM the pipeliner may use under ``spec``."""
    return spec.vmem_bytes * spec.vmem_usable


# ---------------------------------------------------------------------------
# Per-grid-cell VMEM footprints (moved verbatim from core/perf_model --
# perf_model now delegates here, so there is exactly one copy of this math)
# ---------------------------------------------------------------------------

def tsm2r_footprint(bm: int, bk: int, n: int, dtype, out_dtype=None) -> int:
    """VMEM bytes for one TSM2R grid cell: double-buffered in-streams,
    f32 accumulator scratch, output window.

    ``out_dtype`` prices the output window separately from the streamed
    operands -- the quantized kernels load int8 tiles but store the
    caller's dtype (None = same as ``dtype``, the unquantized case). The
    quantized kernels' (1, 1) scale windows are a few bytes and ignored.
    """
    b = bytes_per_elem(dtype)
    ob = bytes_per_elem(out_dtype if out_dtype is not None else dtype)
    n_pad = ceil_mult(n, 128)
    a_win = 2 * bm * bk * b          # double-buffered A window
    b_win = 2 * bk * n_pad * b       # double-buffered B window
    acc = bm * n_pad * 4             # f32 accumulator scratch
    out = bm * n_pad * ob            # output window
    return a_win + b_win + acc + out


def tsm2l_footprint(bm: int, k: int, n: int, dtype, out_dtype=None) -> int:
    """VMEM bytes for one TSM2L grid cell: double-buffered A window, the
    whole (k, n) B operand resident, f32 accumulator + output window
    (priced at ``out_dtype`` when it differs -- see tsm2r_footprint)."""
    b = bytes_per_elem(dtype)
    ob = bytes_per_elem(out_dtype if out_dtype is not None else dtype)
    return (2 * bm * ceil_mult(k, 128) * b
            + ceil_mult(k, 8) * ceil_mult(n, 128) * b
            + bm * ceil_mult(n, 128) * (4 + ob))


def tsmt_footprint(bm: int, ba: int, bdim: int, dtype, out_dtype=None) -> int:
    """VMEM bytes for one TSMT grid cell: double-buffered X and Y windows
    plus the unblocked (ba, bdim) f32 accumulator (``out_dtype`` accepted
    for signature uniformity; the output rides the accumulator tile and
    was never priced separately here)."""
    del out_dtype
    b = bytes_per_elem(dtype)
    return (2 * bm * ba * b + 2 * bm * ceil_mult(bdim, 128) * b
            + ba * ceil_mult(bdim, 128) * 4)


def kernel_footprint(kind: str, shape, params, dtype, out_dtype=None) -> int:
    """Per-grid-cell VMEM bytes of ``params`` for ``kind`` at ``shape``.

    Split-invariant by construction: the split kernels stage the same
    windows and accumulator per cell, S only re-partitions the grid.
    """
    m, d1, d2 = shape
    p = dict(params)
    if kind == "tsm2r":
        return tsm2r_footprint(p["block_m"], p["block_k"], d2, dtype,
                               out_dtype)
    if kind == "tsm2l":
        return tsm2l_footprint(p["block_m"], d1, d2, dtype, out_dtype)
    if kind == "tsmt":
        return tsmt_footprint(p["block_m"], p["block_a"], d2, dtype,
                              out_dtype)
    raise ValueError(f"unknown kernel kind {kind!r}: valid kinds are "
                     f"{', '.join(KINDS)}")


def reduction_axis(kind: str, shape) -> tuple[str, int]:
    """(param name of the reduction block, reduction dim size) for the
    kinds whose reduction axis is gridded; tsm2l keeps its contraction
    VMEM-resident and has no split dimension."""
    m, d1, _ = shape
    if kind == "tsm2r":
        return "block_k", d1
    if kind == "tsmt":
        return "block_m", m
    raise ValueError(f"kind {kind!r} has no gridded reduction axis")


# ---------------------------------------------------------------------------
# Feasibility (the candidate-filter predicate, shared with perf_model)
# ---------------------------------------------------------------------------

def feasible(kind: str, shape, params, dtype, spec,
             out_dtype=None) -> bool:
    """True iff ``params`` is a launchable configuration for ``kind`` at
    ``shape`` under ``spec`` -- the exact predicate the perf model's
    candidate enumerators filter with (so the model's search space and the
    kernels' legal space are one set by construction):

    * parallel blocks never exceed the quantized dim (pure-padding blocks
      are not candidates): ``block_m <= ceil_mult(m, sublane)``, and the
      lane-axis block <= ``ceil_mult(dim, lane)``;
    * the per-cell VMEM footprint fits ``spec``'s budget;
    * S > 1 only when every reduction slice owns >= one whole block
      (``s * block <= ceil_mult(reduction, q)``); tsm2l admits no split.

    The TSMT accumulator limit is deliberately NOT part of this predicate:
    it is a dispatch-level contract on the *shape* (``ops.tsmt`` refuses
    before parameter resolution), not a per-candidate constraint, so it
    must not prune the candidate grid the perf model scores.
    """
    return not [v for v in check_kernel_config(kind, shape, params, dtype,
                                               spec, out_dtype=out_dtype)
                if v.rule != "accumulator-limit"]


def check_kernel_config(kind: str, shape, params, dtype, spec, *,
                        max_b: int | None = None,
                        out_dtype=None) -> list[Violation]:
    """Every contract violation of ``params`` (empty list == feasible).

    ``max_b`` overrides the TSMT accumulator limit (``GemmPolicy.
    max_skinny_t`` scopes can raise it past :data:`TSMT_MAX_B`).
    ``out_dtype`` is the quantized-path split: ``dtype`` is what the
    operand tiles stream as (int8 under ``GemmPolicy.quant="int8"``, which
    also widens the sublane quantum -- :func:`min_sublane`), ``out_dtype``
    what the kernel stores. None = same dtype, the unquantized case.
    """
    m, d1, d2 = shape
    p = dict(params)
    subject = f"{kind} {tuple(shape)} {jnp.dtype(dtype).name}"
    if out_dtype is not None:
        subject += f"->{jnp.dtype(out_dtype).name}"
    subject += f" {p}"
    out: list[Violation] = []

    missing = [k for k in PARAM_KEYS.get(kind, ()) if k not in p]
    if kind not in KINDS:
        return [Violation("unknown-kind", subject,
                          f"unknown kernel kind {kind!r}")]
    if missing:
        return [Violation("missing-params", subject,
                          f"missing required params {missing}")]

    bm = p["block_m"]
    splits = p.get("splits", 1)
    lane, sub = spec.lane, min_sublane(spec, dtype)

    # -- positivity / integrality -------------------------------------------
    blocks = {k: v for k, v in p.items() if k.startswith("block")}
    for name, v in {**blocks, "splits": splits}.items():
        if not isinstance(v, int) or isinstance(v, bool) or v < 1:
            out.append(Violation(
                "bad-param", subject,
                f"{name}={v!r} must be a positive int"))
    if any(v.rule == "bad-param" for v in out):
        return out

    # -- hardware quantization ----------------------------------------------
    # block_m tiles the sublane (second-minor) axis of every kernel's tall
    # operand; the lane-axis block (block_k for tsm2r, block_a for tsmt)
    # must be a whole number of 128-wide lane tiles. A misquantized block
    # still compiles but pads every window inside Mosaic -- the silent
    # model-vs-kernel drift class this module exists to kill.
    if bm % sub != 0:
        out.append(Violation(
            "sublane-quant", subject,
            f"block_m={bm} is not a multiple of sublane={sub}"))
    lane_block = {"tsm2r": "block_k", "tsmt": "block_a"}.get(kind)
    if lane_block is not None and p[lane_block] % lane != 0:
        out.append(Violation(
            "lane-quant", subject,
            f"{lane_block}={p[lane_block]} is not a multiple of "
            f"lane={lane}"))

    # -- parallel blocks must not exceed the quantized dim ------------------
    if bm > ceil_mult(m, sub):
        out.append(Violation(
            "block-exceeds-dim", subject,
            f"block_m={bm} > ceil_mult(m={m}, {sub})={ceil_mult(m, sub)}: "
            "the block is pure padding"))
    if kind == "tsm2r" and p["block_k"] > ceil_mult(d1, lane):
        out.append(Violation(
            "block-exceeds-dim", subject,
            f"block_k={p['block_k']} > ceil_mult(k={d1}, {lane})="
            f"{ceil_mult(d1, lane)}"))
    if kind == "tsmt" and p["block_a"] > ceil_mult(d1, lane):
        out.append(Violation(
            "block-exceeds-dim", subject,
            f"block_a={p['block_a']} > ceil_mult(a={d1}, {lane})="
            f"{ceil_mult(d1, lane)}"))

    # -- VMEM budget --------------------------------------------------------
    fp = kernel_footprint(kind, shape, p, dtype, out_dtype)
    budget = vmem_budget(spec)
    if fp > budget:
        out.append(Violation(
            "vmem-budget", subject,
            f"footprint {fp} B > budget {int(budget)} B "
            f"({spec.vmem_bytes} B x vmem_usable={spec.vmem_usable})"))

    # -- split-K whole-slice feasibility ------------------------------------
    if kind == "tsm2l":
        if splits != 1:
            out.append(Violation(
                "split-unsupported", subject,
                f"splits={splits}: tsm2l keeps its whole contraction "
                "VMEM-resident and has no split dimension"))
    elif splits > 1:
        rname, rdim = reduction_axis(kind, shape)
        q = lane if rname == "block_k" else sub
        if splits * p[rname] > ceil_mult(rdim, q):
            out.append(Violation(
                "split-whole-slice", subject,
                f"splits={splits} x {rname}={p[rname]} > "
                f"ceil_mult({rdim}, {q})={ceil_mult(rdim, q)}: slices past "
                "the reduction are pure zero-padding work"))

    # -- TSMT unblocked accumulator limit -----------------------------------
    if kind == "tsmt":
        limit = max(TSMT_MAX_B, max_b or 0)
        if d2 > limit:
            out.append(Violation(
                "accumulator-limit", subject,
                f"tsmt small output dim b={d2} exceeds the unblocked f32 "
                f"accumulator limit ({limit})"))

    return out


def check_grid(kind: str, padded_shape, params) -> list[Violation]:
    """Grid-divisibility contract of the raw kernels' padded operands.

    ``kernels/ops.py`` zero-pads so these hold by construction (zero
    padding is exact for GEMM); calling the ``*_pallas`` kernels directly
    asserts the same conditions at trace time. The auditor re-derives the
    padded shape from the resolver's output and proves exactness here.

    ``kind="reduce"`` is the split-partials epilogue
    (``kernels/reduce.py``): ``padded_shape`` is the ``(splits, rows,
    cols)`` partials stack and ``params`` carries ``block_r`` (as resolved
    by ``reduce.epilogue_block_r``); the contract is ``rows % block_r``.
    """
    p = dict(params)
    s = p.get("splits", 1)
    subject = f"{kind} padded {tuple(padded_shape)} {p}"
    out = []
    if kind == "reduce":
        _, rows, _ = padded_shape
        if rows % p["block_r"] != 0:
            out.append(Violation(
                "grid-divisibility", subject,
                f"partials rows={rows} is not a multiple of "
                f"block_r={p['block_r']}"))
        return out
    m, d1, _ = padded_shape
    if m % p["block_m"] != 0:
        out.append(Violation(
            "grid-divisibility", subject,
            f"padded m={m} is not a multiple of block_m={p['block_m']}"))
    if kind == "tsm2r" and d1 % (s * p["block_k"]) != 0:
        out.append(Violation(
            "grid-divisibility", subject,
            f"padded k={d1} is not a multiple of splits*block_k="
            f"{s * p['block_k']}"))
    if kind == "tsmt" and m % (s * p["block_m"]) != 0:
        out.append(Violation(
            "grid-divisibility", subject,
            f"padded m={m} is not a multiple of splits*block_m="
            f"{s * p['block_m']}"))
    return out


def launch_grid(kind: str, padded_shape, params
                ) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """``(grid, dimension_semantics)`` of the launch ``kind`` runs.

    The dataflow half of the grid contract (:func:`check_grid` is the
    divisibility half): this is the single statement of which grid each
    kernel launches at a padded operand shape, consumed by

    * ``kernels/ops.py`` -- stamps it onto ``DispatchEvent.launches`` so
      trace-time spies can assert grid shape;
    * ``analysis/kernel_verify`` -- proves the *captured* ``pallas_call``
      grid/semantics equal this derivation (``launch-meta-drift``).

    ``kind="reduce"`` follows the :func:`check_grid` convention:
    ``padded_shape=(splits, rows, cols)``, ``params={"block_r": ...}``.
    """
    p = dict(params)
    s = p.get("splits", 1)
    if kind == "tsm2r":
        m, k, _ = padded_shape
        if s == 1:
            return ((m // p["block_m"], k // p["block_k"]),
                    ("parallel", "arbitrary"))
        return ((s, m // p["block_m"], k // (s * p["block_k"])),
                ("parallel", "parallel", "arbitrary"))
    if kind == "tsm2l":
        return ((padded_shape[0] // p["block_m"],), ("arbitrary",))
    if kind == "tsmt":
        m, a, _ = padded_shape
        if s == 1:
            return ((a // p["block_a"], m // p["block_m"]),
                    ("parallel", "arbitrary"))
        return ((s, a // p["block_a"], m // (s * p["block_m"])),
                ("parallel", "parallel", "arbitrary"))
    if kind == "reduce":
        return ((padded_shape[1] // p["block_r"],), ("parallel",))
    raise ValueError(f"unknown kernel kind {kind!r}: valid kinds are "
                     f"{', '.join(KINDS + ('reduce',))}")


# ---------------------------------------------------------------------------
# Tall-skinny QR stage contracts
# ---------------------------------------------------------------------------

def qr_stage_shapes(m: int, r: int, *, shards: int = 1
                    ) -> tuple[tuple[str, tuple[int, int, int]], ...]:
    """The GEMM-stage (kind, shape) pairs one tall-skinny QR resolves.

    ``repro.linalg``'s CholeskyQR2 factors an ``(m, r)`` operand through
    exactly two kernel dispatches per pass -- the Gram matrix ``A^T A``
    (a ``tsmt`` at ``(m, r, r)``) and the ``R^{-1}`` apply (a ``tsm2l``
    at ``(m, r, r)``); the small Cholesky/triangular solves between them
    are (r, r) host-shaped and never touch the kernels. ``shards > 1``
    describes the tree-TSQR variant, whose local factor runs the same two
    stages on the per-shard row count (``m`` must tile over the shards --
    the same divisibility the shard_map executors require).

    This is the contract the auditor sweeps (``audit_qr_configs``): every
    shape the QR subsystem can hand ``ops.resolve_params`` must resolve to
    a launchable configuration.
    """
    if shards < 1 or (shards > 1 and m % shards != 0):
        raise ValueError(
            f"qr_stage_shapes: m={m} does not tile over shards={shards} "
            "(tree-TSQR requires the tall dim to divide the shard count)")
    m_loc = m // shards
    return (("tsmt", (m_loc, r, r)), ("tsm2l", (m_loc, r, r)))


# ---------------------------------------------------------------------------
# Online-ABFT stage contracts
# ---------------------------------------------------------------------------

def abft_stage_shapes(kind: str, shape, s: int = 2
                      ) -> tuple[tuple[str, tuple[int, int, int]], ...]:
    """The checksum-GEMM (entry, shape) triples the online ABFT wrap
    dispatches around one protected ``(kind, (m, d1, d2))`` GEMM, with
    ``s`` checksum columns (>= 2: plain + ramp -- fewer cannot localize).

    For ``tsm2r``/``tsm2l`` (``A(m,k) @ B(k,n)``, shape ``(m, k, n)``):
    ``u = A^T e`` (mmt over m), ``c_ref = B^T u`` (mmt over k),
    ``c_out = C^T e`` (mmt over m). For ``tsmt``
    (``X(m,a)^T Y(m,b)``, shape ``(m, a, b)``): ``v = X e`` (mm over m),
    ``c_ref^T = v^T Y`` (mmt over m), ``c_out = C^T e`` (mmt over a).

    This is the contract ``audit_abft_configs`` sweeps: every checksum
    shape the wrap can hand the dispatcher must classify, and when it
    classifies to a kernel kind must resolve to a launchable config.
    """
    if s < 2:
        raise ValueError(
            f"abft_stage_shapes: s={s} checksum columns cannot localize "
            "(need the plain column AND the ramp: s >= 2)")
    m, d1, d2 = shape
    if kind in ("tsm2r", "tsm2l"):
        return (("mmt", (m, d1, s)),       # u = A^T e
                ("mmt", (d1, d2, s)),      # c_ref = B^T u
                ("mmt", (m, d2, s)))       # c_out = C^T e
    if kind == "tsmt":
        return (("mm", (m, d1, s)),        # v = X e
                ("mmt", (m, s, d2)),       # c_ref^T = v^T Y
                ("mmt", (d1, d2, s)))      # c_out = C^T e
    raise ValueError(
        f"abft_stage_shapes: unknown kind {kind!r}: the online wrap only "
        f"protects {', '.join(KINDS)}")


# ---------------------------------------------------------------------------
# Collective-layout contracts
# ---------------------------------------------------------------------------

def scatter_divisible(rows: int, shards: int) -> bool:
    """psum_scatter's existence condition: the scattered output rows must
    tile exactly over the DP shards (the dispatcher falls back to dense
    otherwise; a pinned scatter executor raises)."""
    return shards >= 1 and rows % shards == 0


def check_scatter(rows: int, shards: int) -> list[Violation]:
    if scatter_divisible(rows, shards):
        return []
    return [Violation(
        "psum-scatter-divisibility", f"rows={rows} shards={shards}",
        f"psum_scatter output rows ({rows}) do not divide the {shards} "
        "shards: the row-sharded layout cannot exist")]


def executor_reduce_ok(declared, reduce: str) -> bool:
    """Does an executor whose declared reduce contract is ``declared``
    (an iterable of mode names) implement ``reduce``?"""
    return reduce in tuple(declared)


# ---------------------------------------------------------------------------
# Policy contracts
# ---------------------------------------------------------------------------

def check_backward_policy(fwd, bwd) -> list[Violation]:
    """The VJP re-dispatch invariants ``tsmm.backward_policy`` must honor
    (duck-typed on the GemmPolicy fields so this layer stays pure):

    * ``reduce`` is preserved, except "none" -> "psum" (stacked partials
      would change the cotangent shape, which custom_vjp forbids);
    * an int ``split`` pin is stripped to "auto" (shape-specific), while
      "auto"/"never" are preserved (scope-wide intent);
    * the executor pin is dropped (a pinned shard_map executor must not
      recurse per-shard);
    * a forward-kind force degrades to "auto"; "dense"/"auto" survive;
    * ``quant`` is preserved verbatim (scope-wide numeric intent: an int8
      scope keeps its cotangent GEMMs quantizable);
    * ``abft`` is preserved verbatim (scope-wide integrity intent: the
      cotangent GEMMs of a verify/correct scope get their own checksums).
    """
    subject = f"backward_policy({fwd!r})"
    out = []
    want_reduce = "psum" if fwd.reduce == "none" else fwd.reduce
    if bwd.reduce != want_reduce:
        out.append(Violation(
            "backward-reduce", subject,
            f"backward reduce={bwd.reduce!r}, expected {want_reduce!r} "
            f"(forward reduce={fwd.reduce!r})"))
    want_split = "auto" if isinstance(fwd.split, int) else fwd.split
    if bwd.split != want_split:
        out.append(Violation(
            "backward-split", subject,
            f"backward split={bwd.split!r}, expected {want_split!r} "
            f"(forward split={fwd.split!r})"))
    if bwd.executor is not None:
        out.append(Violation(
            "backward-executor", subject,
            f"backward keeps executor pin {bwd.executor!r}; the VJP must "
            "re-select (a pinned shard_map executor would recurse)"))
    want_mode = fwd.mode if fwd.mode in ("auto", "dense") else "auto"
    if bwd.mode != want_mode:
        out.append(Violation(
            "backward-mode", subject,
            f"backward mode={bwd.mode!r}, expected {want_mode!r} "
            f"(forward mode={fwd.mode!r})"))
    want_quant = getattr(fwd, "quant", "none")
    if getattr(bwd, "quant", "none") != want_quant:
        out.append(Violation(
            "backward-quant", subject,
            f"backward quant={getattr(bwd, 'quant', 'none')!r}, expected "
            f"{want_quant!r}: quant is scope-wide numeric intent and must "
            "survive the VJP re-dispatch"))
    want_abft = getattr(fwd, "abft", "none")
    if getattr(bwd, "abft", "none") != want_abft:
        out.append(Violation(
            "abft-policy", subject,
            f"backward abft={getattr(bwd, 'abft', 'none')!r}, expected "
            f"{want_abft!r}: abft is scope-wide integrity intent and must "
            "survive the VJP re-dispatch"))
    return out


# ---------------------------------------------------------------------------
# Tuning-table contracts
# ---------------------------------------------------------------------------

def check_tuning_record(kind: str, shape, params, dtype, spec, *,
                        executor: str = "", known_executors=()) -> list[Violation]:
    """Contract check of one committed TuningTable entry.

    ``spec`` should be the table's *effective* spec for the record's bucket
    (``TuningTable.fitted_spec``): winners measured under the relaxed
    ``explore_vmem`` budget are legal exactly when calibration widened
    ``vmem_usable`` to cover them -- an entry over even the widened budget
    is a stale or corrupted commit.
    """
    out = check_kernel_config(kind, shape, params, dtype, spec)
    if known_executors and executor not in known_executors:
        out.append(Violation(
            "unknown-executor",
            f"{kind} {tuple(shape)} executor={executor!r}",
            f"record's executor {executor!r} is not registered "
            f"(known: {sorted(known_executors)})"))
    return out
