"""ABFT (algorithm-based fault tolerance) checksums via the TSM2X kernels.

This is the paper's own headline application [refs 10-20 in the paper]:
checksum encoding multiplies the protected matrix by a skinny weight
matrix -- a tall-and-skinny GEMM. We protect optimizer/parameter state
against silent data corruption (SDC):

    encode:  c = W^T e          e: (d1, s) skinny checksum weights
    verify:  c' = W'^T e ; SDC detected iff ||c' - c|| > tol

Both encode and verify are the TSMT kernel shape (reduction over the huge
matrix dim, s in {2..8} output columns). Weighted checksums (e columns:
ones + ramp) localize single-fault rows, as in classic Huang-Abraham
schemes.

Cost: one TSMT pass over the params -- at the HBM-roofline that is
params_bytes / 819 GB/s per verification (e.g. 8 ms for a 3B model across
a pod), cheap enough to run at checkpoint boundaries. With s <= 8 output
columns the TSMT grid has ONE parallel cell: on multi-core parts scope
``with tsmm.policy(split=...)`` around encode/verify so the split-
reduction kernels keep every core on the stream (the default "auto"
engages exactly when the perf model's occupancy term says it pays).

Beyond the detect-only checkpoint-boundary tree API, this module owns the
*locate-and-correct* math shared with the dispatcher's online wrap
(``GemmPolicy.abft``, ``core/tsmm._abft_guard``):

* :func:`tolerance` -- the detection threshold, derived from shape and
  dtype rather than guessed: checksum accumulation over ``reduction``
  terms plus the protected GEMM's own rounding carry error that scales
  like ``eps * (sqrt(rows) + sqrt(reduction))`` times the column
  magnitude (random-walk rounding); ``ABFT_TOL_FACTOR`` (in
  ``analysis/contracts``) is the safety margin on top. A genuine bit
  flip in a high-order bit moves the checksum by order the *value*, many
  orders above this.
* :func:`locate_and_correct` -- compare the output's checksums against
  the operand-side reference; on deviation, the ratio of the
  ramp-weighted to the plain checksum delta identifies the faulty row
  (``d1/d0 = (i+1)/rows``), the plain delta gives the per-column error
  estimate, and a nearest-single-bit-flip snap repairs bit flips
  *bit-exactly* (the snapped candidate must agree with the estimate to
  within noise, else the correction falls back to the analytic estimate
  or, when the residual check still fails, to a NaN poison of the whole
  output -- never a silently wrong "repair").
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis import contracts as _contracts
from repro.core import tsmm


def checksum_weights(d1: int, s: int = 2) -> jnp.ndarray:
    """Huang-Abraham style: [1, (i+1)/d, ((i+1)/d)^2, ...] columns, f32.

    Column 0 (ones) carries the error magnitude; column 1 (the ramp)
    carries it scaled by the row position, so ``delta1/delta0 = (i+1)/d``
    localizes a single faulty row. f32 always: a low-precision ramp would
    blur exactly the ratio the locate step divides."""
    i = jnp.arange(d1, dtype=jnp.float32)
    cols = [jnp.ones((d1,), jnp.float32), (i + 1.0) / d1]
    while len(cols) < s:
        cols.append(jnp.square(cols[-1]))
    return jnp.stack(cols[:s], axis=1)


# Internal alias kept for call sites that predate the public name.
_checksum_weights = checksum_weights


def tolerance_eps(dtype, quant: str = "none") -> float:
    """The unit roundoff driving :func:`tolerance` for a protected GEMM
    producing ``dtype`` under quantization mode ``quant``. Floored at f32
    eps (checksums accumulate in f32); under ``quant="int8"`` widened to
    the 1/127 quantization step -- the protected product is quantized but
    the checksum reference is exact f32, so their gap is quant noise, not
    rounding noise."""
    dt = jnp.dtype(dtype)
    f32_eps = float(jnp.finfo(jnp.float32).eps)
    eps = float(jnp.finfo(dt).eps) if jnp.issubdtype(dt, jnp.floating) \
        else f32_eps
    eps = max(eps, f32_eps)
    if quant == "int8":
        eps = max(eps, 1.0 / 127.0)
    return eps


def tolerance(rows: int, reduction: int, eps: float, amax) -> jnp.ndarray:
    """Per-column detection threshold for checksum deviations.

    ``amax`` is the per-column max |value| of the protected output (f32).
    The ``sqrt(rows) + sqrt(reduction)`` term is the random-walk growth of
    rounding error over the checksum reduction and the GEMM's own
    contraction; the +32 floor covers short reductions where the error is
    a few ulps regardless; ``contracts.ABFT_TOL_FACTOR`` is the safety
    margin (tuned against the false-positive tests). The base is made
    robust to the very corruption it guards against: a faulty cell sits
    in ``amax`` itself, so a raw per-column max would let a huge flip
    inflate its own threshold past its own deviation (fatal under int8,
    where ``eps`` alone is 1/127) -- capping each column at 64x the
    cross-column *median* keeps a single damaged column from out-voting
    the clean ones, while the ``1e-3 * median`` leak keeps all-zero
    columns from demanding exactness the kernels never promised."""
    amax = jnp.asarray(amax, jnp.float32)
    med = jnp.median(amax)
    base = jnp.minimum(amax, 64.0 * med) + 1e-3 * med + jnp.float32(1e-30)
    scale = _contracts.ABFT_TOL_FACTOR * eps * (
        math.sqrt(rows) + math.sqrt(reduction) + 32.0)
    return jnp.float32(scale) * base


def detect(c_out, c_ref, *, rows: int, reduction: int, eps: float, amax):
    """Per-column fault mask from the two checksum computations.

    Returns ``(bad, tol)``: ``bad[j]`` is True when column j's plain OR
    ramp checksum deviates beyond ``tol[j]`` -- written as the negation
    of the pass condition so a NaN deviation (non-finite wreckage in the
    output) counts as bad."""
    d = c_out[:, :2] - c_ref[:, :2]
    tol = tolerance(rows, reduction, eps, amax)
    ok = (jnp.abs(d[:, 0]) <= tol) & (jnp.abs(d[:, 1]) <= tol)
    return ~ok, tol


def encode_leaf(x, s: int = 2, *, policy=None, interpret=None):
    """Checksum of one 2-D (or reshaped) array: (cols, s) f32.

    ``policy`` pins a GemmPolicy for the TSMT pass (defaults to the active
    ``tsmm.policy(...)`` scope); ``interpret=`` is the deprecated alias.
    """
    m = x.reshape(x.shape[0], -1) if x.ndim != 2 else x
    if m.ndim == 1:
        m = m[:, None]
    e = _checksum_weights(m.shape[0], s)
    # c[s_, cols] via TSMT: e^T m  -> orient as tsmm_t(m_as_x? ...): we use
    # tsmm_t(e_like? ) -- X^T Y with X=m (m rows huge) gives (cols, s):
    return tsmm.tsmm_t(m.astype(jnp.float32), e, policy=policy,
                       interpret=interpret)


def encode_tree(tree, s: int = 2, *, policy=None, interpret=None):
    """Checksums for every leaf with >= 2 dims and >= 2^16 elements."""
    def one(x):
        if x.ndim < 1 or x.size < 65536:
            return None
        return encode_leaf(x, s, policy=policy, interpret=interpret)
    return jax.tree.map(one, tree)


def verify_tree(tree, checksums, *, rtol: float = 1e-3, policy=None,
                interpret=None):
    """Returns (ok: bool array, per-leaf max relative deviation tree)."""
    devs = []

    def one(x, c):
        if c is None:
            return None
        c2 = encode_leaf(x, c.shape[1], policy=policy, interpret=interpret)
        denom = jnp.maximum(jnp.abs(c), 1e-6)
        dev = jnp.max(jnp.abs(c2 - c) / denom)
        devs.append(dev)
        return dev

    dev_tree = jax.tree.map(one, tree, checksums,
                            is_leaf=lambda x: x is None)
    if not devs:
        return jnp.bool_(True), dev_tree
    worst = jnp.stack(devs).max()
    return worst <= rtol, dev_tree


# ---------------------------------------------------------------------------
# Locate-and-correct (shared by the online dispatch wrap and the tree API)
# ---------------------------------------------------------------------------

def _snap_to_bitflip(row, est, snap_tol):
    """Per column: the single-bit-flip neighbor of ``row`` nearest to the
    f32 estimate ``est``, when one agrees with it to within ``snap_tol``;
    else ``est`` cast to the row's dtype.

    A genuine bit flip leaves the true value among the ``nbits``
    candidates ``bitcast(row ^ (1 << b))``, and the analytic estimate
    (true value + checksum rounding noise) sits within noise of exactly
    one of them -- snapping recovers the pre-flip bits exactly. Arbitrary
    (non-bit-flip) corruption matches no candidate, so the agreement gate
    keeps the snap from quantizing a legitimate estimate onto a wrong
    neighbor. Everything is stop_gradient'ed: bitcasts carry no tangent,
    and the caller discards gradients through the repaired row anyway."""
    row = lax.stop_gradient(row)
    est = lax.stop_gradient(est)
    nbits = jnp.dtype(row.dtype).itemsize * 8
    u = jnp.dtype(f"uint{nbits}")
    ri = lax.bitcast_convert_type(row, u)[:, None]
    masks = (jnp.ones((), u) << jnp.arange(nbits, dtype=u))[None, :]
    cand = lax.bitcast_convert_type(ri ^ masks, row.dtype)
    dist = jnp.abs(cand.astype(jnp.float32) - est[:, None])
    dist = jnp.where(jnp.isfinite(dist), dist, jnp.inf)
    kbest = jnp.argmin(dist, axis=1)
    best = jnp.take_along_axis(cand, kbest[:, None], axis=1)[:, 0]
    dbest = jnp.take_along_axis(dist, kbest[:, None], axis=1)[:, 0]
    ok = jnp.isfinite(est) & (dbest <= snap_tol)
    return jnp.where(ok, best, est.astype(row.dtype))


def locate_and_correct(out, c_out, c_ref, *, rows: int, reduction: int,
                       mode: str, eps: float, ref_row=None):
    """Verify ``out`` (2-D, ``(rows, cols)``) against its checksums; on a
    detected fault either poison or repair it. Trace-safe (pure lax/jnp,
    no host callback), gradient-transparent on the clean path.

    ``c_out`` is the checksum computed FROM the output, ``c_ref`` the
    reference pushed through the operands -- both ``(cols, s>=2)`` f32
    with plain weights in column 0 and the ramp in column 1. ``mode``:

    * "verify"  -- clean: return ``out`` unchanged (bit-identical);
      fault: return ``out`` fully NaN-poisoned, so any downstream
      finiteness check (loss guards, ``step_ok``) trips.
    * "correct" -- localize the single faulty row from the ramp/plain
      deviation ratio of the worst column, estimate each bad column's
      true value, snap to the nearest single-bit-flip candidate
      (bit-exact repair for flip faults), and accept the repair only if
      it explains the deviations (residual re-check) -- otherwise
      NaN-poison exactly as "verify" would.

    ``ref_row`` (correct mode): optional trace-safe callback
    ``i -> (cols,) f32`` recomputing the TRUE content of output row ``i``
    from the operands (the online wrap passes a dynamic-slice dense
    recompute -- one ``(1, red) @ (red, cols)`` dot). With it, the snap
    reference is accurate at the value's own scale regardless of how
    large the corruption is, so even astronomically wrong cells (a
    flipped exponent MSB) snap back bit-exactly, and same-row
    multi-column damage repairs wholesale. Without it, the estimate
    falls back to ``row - d0`` (checksum linearity), which is exact only
    down to f32 cancellation at the *corrupted* value's magnitude --
    fine for the offline leaf path's moderate flips, ambiguous for
    magnitude-exploding ones.
    """
    if mode not in ("verify", "correct"):
        raise ValueError(
            f"[abft-mode] locate_and_correct mode {mode!r}: valid modes "
            "are 'verify', 'correct'")
    f32 = jnp.float32
    out_f = lax.stop_gradient(out).astype(f32)
    c_out = lax.stop_gradient(jnp.asarray(c_out, f32))
    c_ref = lax.stop_gradient(jnp.asarray(c_ref, f32))
    amax = jnp.max(jnp.abs(out_f), axis=0)
    bad, tol = detect(c_out, c_ref, rows=rows, reduction=reduction,
                      eps=eps, amax=amax)
    any_bad = jnp.any(bad)
    poisoned = jnp.where(any_bad, jnp.full_like(out, jnp.nan), out)
    if mode == "verify":
        return poisoned

    d0 = c_out[:, 0] - c_ref[:, 0]
    d1 = c_out[:, 1] - c_ref[:, 1]
    # Anchor on the worst finite bad column; its ramp/plain ratio is the
    # faulty row's weight (i+1)/rows.
    mag = jnp.where(bad & jnp.isfinite(d0), jnp.abs(d0), -jnp.inf)
    j = jnp.argmax(mag)
    ratio = d1[j] / d0[j]
    i_f = jnp.round(ratio * rows) - 1.0
    i_ok = jnp.isfinite(ratio) & (i_f >= 0.0) & (i_f <= rows - 1.0)
    i = jnp.clip(jnp.where(jnp.isfinite(i_f), i_f, 0.0), 0,
                 rows - 1).astype(jnp.int32)
    row = lax.dynamic_slice_in_dim(out, i, 1, axis=0)[0]
    if ref_row is None:
        est = row.astype(f32) - d0      # checksum is linear in the row
    else:
        est = lax.stop_gradient(jnp.asarray(ref_row(i), f32))
    fix_cols = bad & jnp.isfinite(est)
    snapped = _snap_to_bitflip(row, est, 4.0 * tol)
    fixed = lax.stop_gradient(jnp.where(fix_cols, snapped, row))
    # Residual: a correct single-row repair must cancel BOTH deviations
    # in every column (bad and clean alike -- a multi-row fault leaves
    # the other rows' contribution standing and fails here). The gate
    # widens by the f32 cancellation floor of the quantities it
    # subtracts: d0 and delta are each rounded at their own magnitude,
    # so their sum is only meaningful down to ~eps * (|d0| + |delta|).
    delta = fixed.astype(f32) - row.astype(f32)
    w_ramp = (i.astype(f32) + 1.0) / rows
    f32_eps = jnp.float32(jnp.finfo(jnp.float32).eps)
    cancel0 = 32.0 * f32_eps * (jnp.abs(d0) + jnp.abs(delta))
    cancel1 = 32.0 * f32_eps * (jnp.abs(d1) + jnp.abs(w_ramp * delta))
    res_ok = jnp.all((jnp.abs(d0 + delta) <= 4.0 * tol + cancel0)
                     & (jnp.abs(d1 + w_ramp * delta) <= 4.0 * tol + cancel1))
    corrected = lax.dynamic_update_slice_in_dim(out, fixed[None, :], i,
                                                axis=0)
    good = i_ok & res_ok
    return jnp.where(any_bad,
                     jnp.where(good, corrected,
                               jnp.full_like(out, jnp.nan)),
                     out)


def correct_leaf(x, c, *, policy=None, interpret=None):
    """Offline locate-and-correct for one checksummed leaf: re-encode,
    compare against the stored checksum ``c``, repair a single faulty row
    (bit-exact for flip faults) or NaN-poison. Returns
    ``(ok_before, corrected)`` -- ``ok_before`` False means the leaf HAD
    a detected fault (the corrected copy may still be the repair or the
    poison; poison forces the caller to a checkpoint restore)."""
    m = x.reshape(x.shape[0], -1) if x.ndim != 2 else x
    if m.ndim == 1:
        m = m[:, None]
    c2 = encode_leaf(x, c.shape[1], policy=policy, interpret=interpret)
    rows = m.shape[0]
    eps = tolerance_eps(x.dtype)
    amax = jnp.max(jnp.abs(m.astype(jnp.float32)), axis=0)
    bad, _ = detect(c2, c, rows=rows, reduction=rows, eps=eps, amax=amax)
    fixed = locate_and_correct(m, c2, c, rows=rows, reduction=rows,
                               mode="correct", eps=eps)
    return ~jnp.any(bad), fixed.reshape(x.shape)


def verify_and_correct_tree(tree, checksums, *, policy=None,
                            interpret=None):
    """Tree-wide offline locate-and-correct against stored checksums.

    Returns ``(ok_before, corrected_tree)``: ``ok_before`` is True when
    no leaf deviated (the corrected tree is then value-identical to the
    input); on single-row faults the corrected tree carries the repaired
    leaves (bit-exact for flip faults); uncorrectable leaves come back
    NaN-poisoned so downstream finiteness checks force a restore instead
    of silently training on damage. Un-checksummed leaves (``None`` in
    the checksum tree) pass through untouched."""
    oks = []

    def one(x, c):
        if c is None:
            return x
        ok, fixed = correct_leaf(x, c, policy=policy, interpret=interpret)
        oks.append(ok)
        return fixed

    corrected = jax.tree.map(one, tree, checksums,
                             is_leaf=lambda v: v is None)
    ok_before = jnp.all(jnp.stack(oks)) if oks else jnp.bool_(True)
    return ok_before, corrected
