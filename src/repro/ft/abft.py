"""ABFT (algorithm-based fault tolerance) checksums via the TSM2X kernels.

This is the paper's own headline application [refs 10-20 in the paper]:
checksum encoding multiplies the protected matrix by a skinny weight
matrix -- a tall-and-skinny GEMM. We protect optimizer/parameter state
against silent data corruption (SDC):

    encode:  c = W^T e          e: (d1, s) skinny checksum weights
    verify:  c' = W'^T e ; SDC detected iff ||c' - c|| > tol

Both encode and verify are the TSMT kernel shape (reduction over the huge
matrix dim, s in {2..8} output columns). Weighted checksums (e columns:
ones + ramp) localize single-fault rows, as in classic Huang-Abraham
schemes.

Cost: one TSMT pass over the params -- at the HBM-roofline that is
params_bytes / 819 GB/s per verification (e.g. 8 ms for a 3B model across
a pod), cheap enough to run at checkpoint boundaries. With s <= 8 output
columns the TSMT grid has ONE parallel cell: on multi-core parts scope
``with tsmm.policy(split=...)`` around encode/verify so the split-
reduction kernels keep every core on the stream (the default "auto"
engages exactly when the perf model's occupancy term says it pays).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tsmm


def _checksum_weights(d1: int, s: int = 2) -> jnp.ndarray:
    """Huang-Abraham style: [1, i, i^2/d, ...] columns, f32."""
    i = jnp.arange(d1, dtype=jnp.float32)
    cols = [jnp.ones((d1,), jnp.float32), (i + 1.0) / d1]
    while len(cols) < s:
        cols.append(jnp.square(cols[-1]))
    return jnp.stack(cols[:s], axis=1)


def encode_leaf(x, s: int = 2, *, policy=None, interpret=None):
    """Checksum of one 2-D (or reshaped) array: (cols, s) f32.

    ``policy`` pins a GemmPolicy for the TSMT pass (defaults to the active
    ``tsmm.policy(...)`` scope); ``interpret=`` is the deprecated alias.
    """
    m = x.reshape(x.shape[0], -1) if x.ndim != 2 else x
    if m.ndim == 1:
        m = m[:, None]
    e = _checksum_weights(m.shape[0], s)
    # c[s_, cols] via TSMT: e^T m  -> orient as tsmm_t(m_as_x? ...): we use
    # tsmm_t(e_like? ) -- X^T Y with X=m (m rows huge) gives (cols, s):
    return tsmm.tsmm_t(m.astype(jnp.float32), e, policy=policy,
                       interpret=interpret)


def encode_tree(tree, s: int = 2, *, policy=None, interpret=None):
    """Checksums for every leaf with >= 2 dims and >= 2^16 elements."""
    def one(x):
        if x.ndim < 1 or x.size < 65536:
            return None
        return encode_leaf(x, s, policy=policy, interpret=interpret)
    return jax.tree.map(one, tree)


def verify_tree(tree, checksums, *, rtol: float = 1e-3, policy=None,
                interpret=None):
    """Returns (ok: bool array, per-leaf max relative deviation tree)."""
    devs = []

    def one(x, c):
        if c is None:
            return None
        c2 = encode_leaf(x, c.shape[1], policy=policy, interpret=interpret)
        denom = jnp.maximum(jnp.abs(c), 1e-6)
        dev = jnp.max(jnp.abs(c2 - c) / denom)
        devs.append(dev)
        return dev

    dev_tree = jax.tree.map(one, tree, checksums,
                            is_leaf=lambda x: x is None)
    if not devs:
        return jnp.bool_(True), dev_tree
    worst = jnp.stack(devs).max()
    return worst <= rtol, dev_tree
