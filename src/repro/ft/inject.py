"""Deterministic fault injection: seeded, replayable chaos for the FT stack.

Two fault families, matching the two halves the fault-tolerance layer
protects:

* **GEMM faults** (``GemmFault`` + ``with faults(...)``): flip a chosen bit
  of a chosen element of the Nth dispatched GEMM's operands or output. The
  hook lives at the executor-registry boundary in ``core/tsmm.py`` (every
  registered executor -- pallas-tpu, interpret, dense-xla, shard_map,
  scatter, quantized scopes -- is invoked through :func:`tap_executor`), so
  any arm the dispatcher can reach is injectable. Flips are trace-safe
  ``bitcast ^ mask`` ops: under ``jax.jit`` they are baked into the traced
  computation, so build a fresh trace (or call eagerly) per fault plan --
  a cached jit function replays whatever plan it was traced under. Site
  numbers count executor invocations in trace order within the scope; an
  ABFT-wrapped entry dispatches its protected GEMM *before* its checksum
  GEMMs, so the protected GEMM always takes the lower site.

* **Checkpoint corruptors** (:func:`corrupt_checkpoint`): host-side damage
  to a committed ``checkpoint/checkpointer.py`` directory -- a torn
  ``.tmp`` dir (preempted writer), a truncated array file, or a bit-flipped
  payload the manifest's crc32 must catch. All driven by a
  ``random.Random(seed)`` instance: no wall clock, no global RNG state.

``poison_tree`` is the train-loop chaos hook: overwrite one element of one
float leaf (NaN by default) to model a transient in-memory fault that the
step's non-finite detection must catch and roll back.

Import discipline: jax + stdlib only, nothing from ``repro.*`` -- the
dispatcher imports this module at the top level, so it must sit below
every other layer.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import os
import random

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "GemmFault",
    "FaultScope",
    "faults",
    "active",
    "current_scope",
    "flip_bit",
    "tap_executor",
    "poison_tree",
    "corrupt_checkpoint",
]

_OPERANDS = ("a", "b", "out")


@dataclasses.dataclass(frozen=True)
class GemmFault:
    """One planned bit flip: at dispatch ``site``, flip ``bit`` of element
    ``(row, col)`` of the named ``operand`` ("a" | "b" | "out"). ``row`` and
    ``col`` index the 2-D view the executor sees (N-d lhs operands are
    already collapsed to ``(tall, minor)`` at the tap)."""

    site: int
    operand: str = "out"
    row: int = 0
    col: int = 0
    bit: int = 29

    def __post_init__(self):
        if self.operand not in _OPERANDS:
            raise ValueError(
                f"[inject-operand] unknown operand {self.operand!r}: valid "
                f"targets are {', '.join(_OPERANDS)}"
            )
        if self.site < 0 or self.row < 0 or self.col < 0 or self.bit < 0:
            raise ValueError(
                f"[inject-fault] site/row/col/bit must be >= 0, got {self!r}"
            )


class FaultScope:
    """Mutable per-scope state: the plan, the trace-order site counter, and
    the faults actually applied (for assertions and replay logs)."""

    def __init__(self, plan):
        self.plan = tuple(plan)
        self.sites_seen = 0
        self.applied: list[GemmFault] = []

    def next_site(self) -> int:
        site = self.sites_seen
        self.sites_seen += 1
        return site


_SCOPE: contextvars.ContextVar[FaultScope | None] = contextvars.ContextVar(
    "repro_fault_scope", default=None
)


def active() -> bool:
    """Is a fault plan currently in scope?"""
    return _SCOPE.get() is not None


def current_scope() -> FaultScope | None:
    return _SCOPE.get()


@contextlib.contextmanager
def faults(*plan: GemmFault):
    """Activate a deterministic GEMM fault plan for the scope.

    Yields the :class:`FaultScope` (``.applied`` lists the faults whose
    sites were actually reached). Scopes nest and restore on exit. The
    site counter is per-scope: re-running the same computation under a
    fresh scope with the same plan replays the same faults.
    """
    for f in plan:
        if not isinstance(f, GemmFault):
            raise TypeError(
                f"[inject-plan] fault plans take GemmFault entries, got "
                f"{type(f).__name__}"
            )
    scope = FaultScope(plan)
    token = _SCOPE.set(scope)
    try:
        yield scope
    finally:
        _SCOPE.reset(token)


def flip_bit(x, row: int, col: int, bit: int):
    """Flip one bit of ``x[row, col]`` (2-D view), trace-safe for any fixed
    width dtype: bitcast to the matching uint, XOR, bitcast back."""
    nbits = jnp.dtype(x.dtype).itemsize * 8
    if not 0 <= bit < nbits:
        raise ValueError(
            f"[inject-bit] bit {bit} outside [0, {nbits}) for dtype {x.dtype}"
        )
    udtype = jnp.dtype(f"uint{nbits}")
    flat = x if x.ndim == 2 else x.reshape(-1, x.shape[-1])
    u = lax.bitcast_convert_type(flat, udtype)
    mask = jnp.asarray(1 << bit, udtype)
    u = u.at[row, col].set(u[row, col] ^ mask)
    return lax.bitcast_convert_type(u, x.dtype).reshape(x.shape)


def tap_executor(ex, entry, kind, a, b, policy):
    """Invoke executor ``ex`` with the active plan's faults for this
    dispatch site applied: operand flips before the call, output flips
    after. Returns ``(out, applied_faults)``; with no scope active this is
    exactly ``(ex(...), ())``."""
    scope = _SCOPE.get()
    if scope is None:
        return ex(entry, kind, a, b, policy), ()
    site = scope.next_site()
    hits = tuple(f for f in scope.plan if f.site == site)
    for f in hits:
        if f.operand == "a":
            a = flip_bit(a, f.row, f.col, f.bit)
        elif f.operand == "b":
            b = flip_bit(b, f.row, f.col, f.bit)
    out = ex(entry, kind, a, b, policy)
    for f in hits:
        if f.operand == "out":
            out = flip_bit(out, f.row, f.col, f.bit)
    if hits:
        scope.applied.extend(hits)
    return out, hits


def poison_tree(tree, *, leaf_index: int = 0, value: float = float("nan")):
    """Overwrite element 0 of the ``leaf_index``-th float array leaf with
    ``value`` (NaN by default): the train-loop chaos hook for a transient
    in-memory fault the step's non-finite detection must catch."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    float_ix = [
        i
        for i, x in enumerate(leaves)
        if hasattr(x, "dtype")
        and getattr(x, "size", 0) > 0
        and jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
    ]
    if not float_ix:
        raise ValueError("[inject-poison] tree has no non-empty float leaves")
    i = float_ix[leaf_index % len(float_ix)]
    x = jnp.asarray(leaves[i])
    flat = x.reshape(-1)
    leaves[i] = flat.at[0].set(jnp.asarray(value, flat.dtype)).reshape(x.shape)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Host-side checkpoint corruptors
# ---------------------------------------------------------------------------

_CKPT_MODES = ("torn-tmp", "truncate", "bitflip")


def _committed_steps(root: str) -> list[int]:
    out = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(root, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def corrupt_checkpoint(root: str, *, mode: str, seed: int = 0,
                       step: int | None = None) -> str:
    """Deterministically damage a checkpoint directory; returns the damaged
    path. Modes:

    * ``"torn-tmp"``  -- create a partial ``step_*.tmp`` dir (a preempted
      writer); restore must ignore it and the next save garbage-collects.
    * ``"truncate"``  -- truncate one committed ``arr_*.npy`` to half size;
      ``np.load`` / crc32 must fail the restore of that step.
    * ``"bitflip"``   -- flip one payload bit of one committed array file;
      the manifest crc32 must catch it.

    ``seed`` drives every choice through ``random.Random`` -- same seed,
    same damage. ``step=None`` targets the newest committed step (for
    "torn-tmp": one past it).
    """
    if mode not in _CKPT_MODES:
        raise ValueError(
            f"[inject-ckpt-mode] unknown mode {mode!r}: valid modes are "
            f"{', '.join(_CKPT_MODES)}"
        )
    rng = random.Random(seed)
    steps = _committed_steps(root)
    if mode == "torn-tmp":
        torn_step = step if step is not None else (steps[-1] + 1 if steps else 0)
        d = os.path.join(root, f"step_{torn_step:09d}.tmp")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "arr_00000.npy"), "wb") as f:
            f.write(bytes(rng.getrandbits(8) for _ in range(64)))
        return d
    if step is None:
        if not steps:
            raise FileNotFoundError(
                f"[inject-ckpt] no committed checkpoints under {root}"
            )
        step = steps[-1]
    d = os.path.join(root, f"step_{step:09d}")
    arrs = sorted(n for n in os.listdir(d) if n.endswith(".npy"))
    target = os.path.join(d, arrs[rng.randrange(len(arrs))])
    size = os.path.getsize(target)
    if mode == "truncate":
        with open(target, "r+b") as f:
            f.truncate(max(1, size // 2))
        return target
    # bitflip: stay past the .npy header so the array *payload* is hit and
    # only the crc32 (not the header parse) can catch it.
    lo = 128 if size > 128 else 0
    off = rng.randrange(lo, size)
    with open(target, "r+b") as f:
        f.seek(off)
        byte = f.read(1)[0]
        f.seek(off)
        f.write(bytes([byte ^ (1 << rng.randrange(8))]))
    return target
