"""Elastic rescale: resume training on a different device count.

Flow after a pod loss / grow event:
  1. the launcher re-execs with the surviving device set;
  2. ``rescale_plan`` recomputes mesh + batch split (global batch is
     preserved by rebalancing per-host batch; data pipeline replays the
     exact global stream because batches are pure functions of step);
  3. ``Checkpointer.restore(shardings=...)`` device_puts the full-view
     arrays onto the new mesh (checkpoints are mesh-agnostic by design).

Invariant (tested): loss/params trajectory is bit-comparable (up to fp
reduction order) across a 1-host -> 2-host rescale.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.data.pipeline import DataConfig
from repro.distributed import sharding
from repro.kernels import compat


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    mesh: object
    host_index: int
    host_count: int


def rescale_plan(*, devices=None, model_axis: int = 1,
                 host_index: int = 0, host_count: int = 1) -> RescalePlan:
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    # ValueError, not assert: this runs in the relaunch path after a pod
    # loss, exactly where `python -O` would have stripped an assert.
    if model_axis < 1 or n % model_axis != 0:
        raise ValueError(
            f"[rescale-mesh] {n} surviving devices not divisible by "
            f"model_axis={model_axis}; pick a model axis that divides the "
            "device count (or shrink it before re-exec)")
    if host_count < 1 or not 0 <= host_index < host_count:
        raise ValueError(
            f"[rescale-hosts] host_index={host_index} outside "
            f"[0, host_count={host_count})")
    mesh = compat.make_mesh((n // model_axis, model_axis), ("data", "model"))
    return RescalePlan(mesh=mesh, host_index=host_index, host_count=host_count)


def rescale_data_config(dcfg: DataConfig, plan: RescalePlan) -> DataConfig:
    return dataclasses.replace(dcfg, host_index=plan.host_index,
                               host_count=plan.host_count)


def restore_state(ckpt, cfg, plan: RescalePlan, state_shape):
    """Restore the latest checkpoint re-sharded for the new mesh."""
    p_specs = sharding.make_param_specs(cfg, state_shape["params"], plan.mesh)
    state_specs = {"params": p_specs, "opt": sharding.make_opt_specs(p_specs)}
    named = sharding.named(plan.mesh, state_specs)
    return ckpt.restore(shardings=named)
