"""Straggler / hang mitigation for the synchronous SPMD training loop.

In a synchronous pjit world a slow or dead host stalls everyone; what a
launcher CAN do is (a) notice, fast, (b) checkpoint proactively when step
times degrade (a straggler often precedes a failure), (c) kill + relaunch
elastically (ft/elastic.py). The watchdog implements (a) and (b):

* EWMA step-time tracking with a deviation threshold => ``straggler``
  signal (telemetry + proactive checkpoint callback);
* a hard wall-clock hang deadline on each step => ``hang`` callback
  (launcher responds by re-forming the job, possibly minus a pod).

Preemption: SIGTERM flips a flag the training loop checks at step
boundaries -- the loop checkpoints and exits cleanly (tested by sending
the signal in-process); a previously installed handler is chained, not
clobbered.

``StepWatchdog`` is a context manager: ``with wd: step()`` records the
step on clean exit and cancels the hang timer on an exception (a raising
step must not leave a live timer to fire ``on_hang`` spuriously). It also
counts ``fault_events`` -- the rollback/retry loop calls ``note_fault()``
per detected step fault, so hang/straggler/fault telemetry lives in one
place.
"""

from __future__ import annotations

import signal
import threading
import time


class StepWatchdog:
    def __init__(self, *, ewma_alpha: float = 0.1, straggler_factor: float = 2.0,
                 hang_timeout_s: float = 1800.0, on_straggler=None, on_hang=None):
        self.alpha = ewma_alpha
        self.factor = straggler_factor
        self.hang_timeout = hang_timeout_s
        self.on_straggler = on_straggler
        self.on_hang = on_hang
        self.ewma = None
        self.straggler_events = 0
        self.fault_events = 0
        self.last_metrics = None
        self._timer = None
        self._t0 = None

    def step_begin(self):
        self._t0 = time.monotonic()
        if self.on_hang:
            self._timer = threading.Timer(self.hang_timeout, self.on_hang)
            self._timer.daemon = True
            self._timer.start()
        return self

    def cancel(self):
        """Stop the hang timer without recording the step (the step never
        finished; a raise must not leave a live timer that later fires
        ``on_hang`` against a loop that already moved on)."""
        if self._timer:
            self._timer.cancel()
            self._timer = None

    def note_fault(self):
        """Telemetry: the loop detected a step fault (ABFT hit, non-finite
        loss) and is rolling back. Counted separately from stragglers."""
        self.fault_events += 1

    # Context-manager form: ``with wd: step()``. A clean exit records the
    # step (metrics land on ``last_metrics``); an exception cancels the
    # hang timer and records nothing.
    def __enter__(self):
        return self.step_begin()

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.cancel()
        else:
            self.last_metrics = self.step_end()
        return False

    def step_end(self) -> dict:
        dt = time.monotonic() - self._t0
        if self._timer:
            self._timer.cancel()
            self._timer = None
        is_straggler = self.ewma is not None and dt > self.factor * self.ewma
        if is_straggler:
            self.straggler_events += 1
            if self.on_straggler:
                self.on_straggler(dt, self.ewma)
        # stragglers don't poison the EWMA
        if self.ewma is None:
            self.ewma = dt
        elif not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        self.last_metrics = {"step_time_s": dt, "step_time_ewma_s": self.ewma,
                             "straggler": is_straggler,
                             "fault_events": self.fault_events}
        return self.last_metrics


class PreemptionHandler:
    """SIGTERM/SIGINT -> graceful stop flag for the training loop.

    ``chain=True`` (default) also invokes whatever handler was installed
    before us -- cluster runtimes (and pytest plugins) often hang their
    own SIGTERM hooks, and silently replacing them breaks *their*
    cleanup. ``restore()`` puts the previous handlers back."""

    def __init__(self, signals=(signal.SIGTERM,), chain: bool = True):
        self.requested = False
        self.chain = chain
        self._prev = {}
        for s in signals:
            self._prev[s] = signal.signal(s, self._handle)

    def _handle(self, signum, frame):
        self.requested = True
        prev = self._prev.get(signum)
        if self.chain and callable(prev):
            prev(signum, frame)

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)
