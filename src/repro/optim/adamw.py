"""AdamW from scratch (no optax in this environment), pytree-native.

Features needed at scale:
* decoupled weight decay, bias-correction;
* optional reduced-precision moments (``state_dtype='bfloat16'``) -- the
  memory trick that lets deepseek-v3-671b's optimizer state fit the mesh
  (DESIGN.md §6); master arithmetic stays f32;
* global-norm clipping (fused into the update);
* state pytree mirrors the param pytree, so GSPMD shards it with the same
  PartitionSpecs (ZeRO-1 = those specs plus a 'data' axis, see
  distributed/sharding.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str | None = None   # None = match param dtype promoted to f32


def _state_dtype(cfg: AdamWConfig, p):
    if cfg.state_dtype is not None:
        return jnp.dtype(cfg.state_dtype)
    return jnp.float32


def init(cfg: AdamWConfig, params):
    def zeros(p):
        return {
            "m": jnp.zeros(p.shape, _state_dtype(cfg, p)),
            "v": jnp.zeros(p.shape, _state_dtype(cfg, p)),
        }

    return {
        "step": jnp.zeros((), jnp.int32),
        "moments": jax.tree.map(zeros, params),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(cfg: AdamWConfig, params, grads, state, update_specs=None):
    """Returns (new_params, new_state, metrics).

    ``update_specs``: optional per-param PartitionSpec for the f32 update
    arithmetic (ZeRO-1: with replicated params + mesh-sharded moments, the
    pins keep g/m/v/delta in the sharded domain so the only full-size
    tensor is the final all-gathered new_p -- 25 GiB/device of f32 temps
    otherwise, measured)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip_coef = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    lr = cfg.lr(step) if callable(cfg.lr) else jnp.float32(cfg.lr)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mom, spec):
        from repro.distributed.sharding import maybe_wsc_spec
        pin = (lambda x: x) if spec is None else (
            lambda x: maybe_wsc_spec(x, spec))
        g = pin(g.astype(jnp.float32) * clip_coef)
        m = pin(mom["m"].astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1))
        v = pin(mom["v"].astype(jnp.float32) * cfg.b2
                + jnp.square(g) * (1 - cfg.b2))
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * pin(p.astype(jnp.float32))
        new_p = pin(p.astype(jnp.float32)) - lr * pin(delta)
        sd = mom["m"].dtype
        return new_p.astype(p.dtype), {"m": m.astype(sd), "v": v.astype(sd)}

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["moments"])
    if update_specs is None:
        flat_s = [None] * len(flat_p)
    else:
        flat_s = treedef.flatten_up_to(update_specs)
    out = [upd(p, g, m, s) for p, g, m, s in
           zip(flat_p, flat_g, flat_m, flat_s)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_moments = treedef.unflatten([o[1] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr, "clip_coef": clip_coef}
    return new_params, {"step": step, "moments": new_moments}, metrics
