"""PowerSGD gradient compression (Vogels et al., NeurIPS'19) built on the
TSM2X kernels -- the framework's flagship *application* of the paper.

Each 2-D gradient G (d1 x d2) is compressed to rank r << 16:

    P = G  @ Q          # (d1, r)  -- TSM2R shape (d1 ~ d2 >> r)
    Q' = G^T @ P_orth   # (d2, r)  -- TSMT shape (reduction over huge d1)

Only P and Q (skinny!) cross the DP axis (psum'd), shrinking all-reduce
bytes by ~d2/(2r); error feedback keeps the residual so compression error
accumulates into the *next* step instead of being lost (contraction
property covered by tests/test_optim.py).

The kernels are engaged through ``repro.core.tsmm`` so shapes that don't
qualify (small layers, 1-D params) fall back to dense all-reduce. Both
projections are differentiable (the ops carry custom_vjp rules), so
compression can sit inside traced/differentiated train steps. Routing
follows the active ``tsmm.policy(...)`` scope (or an explicit ``policy=``
passed here); ``with tsmm.policy(mode="dense")`` A/Bs the whole protocol
against stock XLA dots.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import tsmm


@dataclasses.dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 4
    min_size: int = 256 * 256      # params smaller than this stay dense
    ef_decay: float = 1.0          # error-feedback retention


def _compressible(p) -> bool:
    return p.ndim == 2


def init(cfg: PowerSGDConfig, params, key):
    """Per-param state: error-feedback buffer + warm-started Q."""
    def one(path, p):
        if not _compressible(p) or p.size < cfg.min_size:
            return None
        k = jax.random.fold_in(key, hash(str(path)) % (2 ** 31))
        q = jax.random.normal(k, (p.shape[1], cfg.rank), jnp.float32)
        return {"err": jnp.zeros(p.shape, jnp.float32), "q": q}
    return jax.tree_util.tree_map_with_path(one, params)


def _orthonormalize(m):
    """Gram-Schmidt on skinny (d, r): r is tiny so the loop unrolls."""
    cols = []
    for i in range(m.shape[1]):
        c = m[:, i]
        for prev in cols:
            c = c - jnp.dot(prev, c) * prev
        cols.append(c / jnp.maximum(jnp.linalg.norm(c), 1e-8))
    return jnp.stack(cols, axis=1)


def compress_one(cfg: PowerSGDConfig, grad, st, *, psum=None, policy=None,
                 interpret=None):
    """Vogels et al. protocol order (matters across replicas!):

        P_local = (G+e) Q_prev ; P = mean_psum(P) ; P = orth(P)
        Q_local = (G+e)^T P    ; Q = mean_psum(Q)
        approx  = P Q^T        ; e = (G+e) - approx

    ``psum`` must be a MEAN over the DP group (or identity locally).
    ``policy`` pins a GemmPolicy for both projections (defaults to the
    active scope); ``interpret=`` is the deprecated per-call alias.
    """
    g = grad.astype(jnp.float32) + st["err"] * cfg.ef_decay
    p = tsmm.tsmm(g, st["q"], policy=policy, interpret=interpret)   # TSM2R
    if psum:
        p = psum(p)
    p = _orthonormalize(p)
    q = tsmm.tsmm_t(g, p, policy=policy, interpret=interpret)       # TSMT
    if psum:
        q = psum(q)
    approx = p @ q.T
    err = g - approx
    return approx, dict(st, err=err, q=q)


def compress_tree(cfg: PowerSGDConfig, grads, state, *, psum=None,
                  policy=None, interpret=None):
    """End-to-end: compress each eligible grad, (optionally) reduce factors
    across DP with ``psum`` (a MEAN-reduce callable), decompress.
    Non-eligible leaves are reduced dense. Returns (grads, state, metrics)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state)
    out_g, out_s = [], []
    bytes_dense = bytes_sent = 0
    for g, st in zip(flat_g, flat_s):
        bytes_dense += g.size * 4
        if st is None:
            g2 = psum(g) if psum else g
            bytes_sent += g.size * 4
            out_g.append(g2)
            out_s.append(None)
            continue
        approx, st2 = compress_one(cfg, g, st, psum=psum, policy=policy,
                                   interpret=interpret)
        bytes_sent += (st2["q"].size + approx.shape[0] * cfg.rank) * 4
        out_g.append(approx.astype(g.dtype))
        out_s.append(st2)
    metrics = {"powersgd_compression": bytes_dense / max(bytes_sent, 1)}
    return treedef.unflatten(out_g), treedef.unflatten(out_s), metrics
