"""PowerSGD gradient compression (Vogels et al., NeurIPS'19) built on the
TSM2X kernels -- the framework's flagship *application* of the paper.

Each 2-D gradient G (d1 x d2) is compressed to rank r << 16:

    P = G  @ Q          # (d1, r)  -- TSM2R shape (d1 ~ d2 >> r)
    Q' = G^T @ P_orth   # (d2, r)  -- TSMT shape (reduction over huge d1)

Only P and Q (skinny!) cross the DP axis (psum'd), shrinking all-reduce
bytes by ~d2/(2r); error feedback keeps the residual so compression error
accumulates into the *next* step instead of being lost (contraction
property covered by tests/test_optim.py).

The kernels are engaged through ``repro.core.tsmm`` so shapes that don't
qualify (small layers, 1-D params) fall back to dense all-reduce. Both
projections are differentiable (the ops carry custom_vjp rules), so
compression can sit inside traced/differentiated train steps. Routing
follows the active ``tsmm.policy(...)`` scope (or an explicit ``policy=``
passed here); ``with tsmm.policy(mode="dense")`` A/Bs the whole protocol
against stock XLA dots.

The second projection is THE occupancy-starved kernel shape of the
framework (r <= 16 collapses the TSMT grid's parallel dim to one cell):
``with tsmm.policy(split=...)`` around the compress step engages the
split-reduction kernels -- per shard, inside the op's epilogue, so the
sharded variants' psum_scatter schedule below is byte-for-byte unchanged.

Two executions of the same protocol:

* ``compress_one``/``compress_tree`` -- the replicated oracle: the caller
  supplies a mean-``psum`` and both factors come back replicated on every
  DP rank. Works anywhere (also single-device with ``psum=None``).
* ``compress_one_sharded``/``compress_tree_sharded`` -- for call sites
  living *inside* their own ``shard_map`` over the DP axis: the big Q
  factor (d2 x r) is mean-reduced with ``psum_scatter`` and its state
  stays row-sharded end-to-end (1/N of the factor memory per rank, and
  the blocking factor reduction halves to the scatter half of the
  all-reduce; the gather halves ride the points that need full Q anyway).
  Numerically identical to the oracle -- psum == psum_scatter + all_gather
  -- which tests/test_scatter_shard_map.py pins under a real 2-device
  mesh.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro import linalg
from repro.core import tsmm
from repro.kernels import compat
from repro.kernels import quant as kquant

_ORTH_MODES = ("gram_schmidt", "tsqr")
_COMPRESS_MODES = ("none", "int8")


@dataclasses.dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 4
    min_size: int = 256 * 256      # params smaller than this stay dense
    ef_decay: float = 1.0          # error-feedback retention
    # How the P factor is orthonormalized each step:
    #   "gram_schmidt" -- the unrolled classical GS below (default; the
    #     historical behavior, now with a degenerate-column reseed guard).
    #   "tsqr" -- repro.linalg CholeskyQR2, i.e. the orthogonalization
    #     itself runs on the TSM2X kernels (Gram=tsmt, apply=tsm2l) and
    #     the sharded variant keeps even this stage row-sharded via
    #     tree-TSQR. Both produce the unique positive-diagonal QR basis,
    #     so the knob is an implementation choice, not a protocol change.
    orth: str = "gram_schmidt"
    # Wire compression stacked on the rank-r factorization:
    #   "none" -- factors cross the DP axis in f32 (historical behavior).
    #   "int8" -- each local P/Q projection is symmetric-quantized
    #     (kernels.quant.fake_quant: per-tensor int8 + one f32 scale)
    #     immediately before its DP collective, cutting factor all-reduce
    #     bytes ~4x on top of the ~d2/(2r) rank compression. Applied
    #     unconditionally (also with psum=None) so single-device numerics
    #     match the replicated protocol; error feedback absorbs the
    #     quantization residual exactly like the rank truncation.
    compress: str = "none"

    def __post_init__(self):
        if self.orth not in _ORTH_MODES:
            raise ValueError(
                f"unknown PowerSGDConfig orth {self.orth!r}: valid values "
                f"are {', '.join(_ORTH_MODES)}")
        if self.compress not in _COMPRESS_MODES:
            raise ValueError(
                f"unknown PowerSGDConfig compress {self.compress!r}: valid "
                f"values are {', '.join(_COMPRESS_MODES)}")


def _compressible(p) -> bool:
    return p.ndim == 2


def init(cfg: PowerSGDConfig, params, key):
    """Per-param state: error-feedback buffer + warm-started Q."""
    def one(path, p):
        if not _compressible(p) or p.size < cfg.min_size:
            return None
        k = jax.random.fold_in(key, hash(str(path)) % (2 ** 31))
        q = jax.random.normal(k, (p.shape[1], cfg.rank), jnp.float32)
        return {"err": jnp.zeros(p.shape, jnp.float32), "q": q}
    return jax.tree_util.tree_map_with_path(one, params)


def _orthonormalize(m):
    """Gram-Schmidt on skinny (d, r): r is tiny so the loop unrolls.

    Degenerate columns -- zero, or numerically dependent on the columns
    already processed (the projection residual loses >= ~4 digits of the
    column's original norm) -- are replaced by a deterministic fresh
    direction: a fixed per-column-index PRNG draw, projected against the
    basis built so far. The old ``1e-8`` norm floor instead *normalized
    the rounding noise*, silently emitting near-duplicate columns that
    broke the orthonormality every downstream step assumes (P^T P = I is
    what makes ``approx = P Q^T`` a projection). Selection is via
    ``jnp.where`` so the guard is trace-safe and branch-free.
    """
    d = m.shape[0]
    tiny = jnp.asarray(jnp.finfo(jnp.float32).tiny, m.dtype)
    cols = []
    for i in range(m.shape[1]):
        c = m[:, i]
        norm0 = jnp.linalg.norm(c)
        fresh = jax.random.normal(jax.random.PRNGKey(i), (d,), m.dtype)
        for prev in cols:
            c = c - jnp.dot(prev, c) * prev
            fresh = fresh - jnp.dot(prev, fresh) * prev
        resid = jnp.linalg.norm(c)
        degenerate = resid <= 1e-4 * norm0 + tiny
        unit = c / jnp.maximum(resid, tiny)
        fresh_unit = fresh / jnp.maximum(jnp.linalg.norm(fresh), tiny)
        cols.append(jnp.where(degenerate, fresh_unit, unit))
    return jnp.stack(cols, axis=1)


def _orth_factor(cfg: PowerSGDConfig, p, policy=None):
    """Orthonormalize the replicated P factor per ``cfg.orth``."""
    if cfg.orth == "tsqr":
        q, _ = linalg.tsqr(p, policy=policy)
        return q
    return _orthonormalize(p)


def compress_one(cfg: PowerSGDConfig, grad, st, *, psum=None, policy=None,
                 interpret=None):
    """Vogels et al. protocol order (matters across replicas!):

        P_local = (G+e) Q_prev ; P = mean_psum(P) ; P = orth(P)
        Q_local = (G+e)^T P    ; Q = mean_psum(Q)
        approx  = P Q^T        ; e = (G+e) - approx

    ``psum`` must be a MEAN over the DP group (or identity locally).
    ``policy`` pins a GemmPolicy for both projections (defaults to the
    active scope); ``interpret=`` is the deprecated per-call alias.
    """
    g = grad.astype(jnp.float32) + st["err"] * cfg.ef_decay
    p = tsmm.tsmm(g, st["q"], policy=policy, interpret=interpret)   # TSM2R
    if cfg.compress == "int8":
        p = kquant.fake_quant(p)
    if psum:
        p = psum(p)
    p = _orth_factor(cfg, p, policy=policy)
    q = tsmm.tsmm_t(g, p, policy=policy, interpret=interpret)       # TSMT
    if cfg.compress == "int8":
        q = kquant.fake_quant(q)
    if psum:
        q = psum(q)
    approx = p @ q.T
    err = g - approx
    return approx, dict(st, err=err, q=q)


# ---------------------------------------------------------------------------
# Sharded-factor variant (inside the caller's shard_map over the DP axis)
# ---------------------------------------------------------------------------

def shard_state(state, axis):
    """Slice each per-param Q to this rank's row shard (call INSIDE the
    shard_map body, once, e.g. on the first step): (d2, r) -> (d2/N, r).
    Error-feedback buffers stay full (they are rank-local state). Q rows
    that don't divide the axis size keep the full Q -- ``compress_one_
    sharded`` then simply gathers a no-op and scatters nothing for it, so
    mixed trees degrade per-leaf, not wholesale."""
    size = lax.psum(1, axis)
    idx = lax.axis_index(axis)

    def one(st):
        if st is None:
            return None
        q = st["q"]
        if q.shape[0] % size != 0:
            return st
        slab = q.shape[0] // size
        return dict(st, q=lax.dynamic_slice_in_dim(q, idx * slab, slab, 0))

    return jax.tree.map(
        one, state,
        is_leaf=lambda x: x is None or (isinstance(x, dict) and "q" in x))


def compress_one_sharded(cfg: PowerSGDConfig, grad, st, *, axis,
                         policy=None):
    """One grad through the protocol with the Q factor kept row-sharded
    over mesh axis ``axis``. Must run inside a ``shard_map`` over that
    axis; ``st["q"]`` holds this rank's (d2/N, r) shard (see
    :func:`shard_state`).

    Collective schedule vs the oracle's two mean-psums:

        gather(Q_prev)                      # full Q for the P projection
        P = pmean(G~ Q_prev); orth          # tiny (d1, r) all-reduce
        Q = psum_scatter(G~^T P) / N        # sharded mean -- the big one
        gather(Q) for the local decompress  # P Q^T needs full rows

    Same bytes as the oracle's psum pair in steady state, but the factor
    *state* is sharded (ZeRO-style) and the latency-critical reduction is
    the scatter half only. The inner GEMMs dispatch with
    ``shard_map="local"`` (this function already lives inside the
    caller's shard_map -- per-shard re-dispatch must not recurse).
    """
    p_loc = (policy if policy is not None
             else tsmm.current_policy()).with_(shard_map="local")
    size = lax.psum(1, axis)
    q_sharded = (st["q"].shape[0] * size == grad.shape[1])
    q_prev = (compat.all_gather(st["q"], axis) if q_sharded
              else st["q"])
    g = grad.astype(jnp.float32) + st["err"] * cfg.ef_decay
    p = tsmm.tsmm(g, q_prev, policy=p_loc)                      # TSM2R
    if cfg.compress == "int8":
        p = kquant.fake_quant(p)
    if cfg.orth == "tsqr" and p.shape[0] % size == 0:
        # Keep even the orthogonalization row-sharded: scatter the mean
        # of the local P projections (same bytes as the pmean's scatter
        # half), factor with tree-TSQR (only (r, r) R blocks travel),
        # gather the orthonormal basis back for the Q projection, which
        # needs full P rows. Equal to pmean + replicated tsqr up to
        # rounding, with the O(d1 r^2) orthogonalization work divided
        # over the shards.
        p_shard = compat.psum_scatter(p, axis) / size
        p_orth, _ = linalg.tree_tsqr(p_shard, axis=axis, policy=p_loc)
        p = compat.all_gather(p_orth, axis)
    else:
        p = lax.pmean(p, axis)
        p = _orth_factor(cfg, p, policy=p_loc)
    q_local = tsmm.tsmm_t(g, p, policy=p_loc)                   # TSMT
    if cfg.compress == "int8":
        q_local = kquant.fake_quant(q_local)
    if q_sharded:
        q_new = compat.psum_scatter(q_local, axis) / size       # sharded
        q_full = compat.all_gather(q_new, axis)
    else:
        q_new = q_full = lax.pmean(q_local, axis)
    approx = p @ q_full.T
    err = g - approx
    return approx, dict(st, err=err, q=q_new)


def compress_tree_sharded(cfg: PowerSGDConfig, grads, state, *, axis,
                          policy=None):
    """``compress_tree`` for shard_map interiors: eligible leaves go
    through :func:`compress_one_sharded` (sharded Q state), the rest are
    mean-psum'd dense over ``axis``. Returns (grads, state, metrics);
    byte accounting counts the scatter+gather pair once (it replaces the
    oracle's Q psum 1:1)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state)
    out_g, out_s = [], []
    bytes_dense = bytes_sent = 0
    for g, st in zip(flat_g, flat_s):
        bytes_dense += g.size * 4
        if st is None:
            out_g.append(lax.pmean(g, axis))
            bytes_sent += g.size * 4
            out_s.append(None)
            continue
        approx, st2 = compress_one_sharded(cfg, g, st, axis=axis,
                                           policy=policy)
        # int8 wire format: 1 byte/elem + one f32 scale per factor.
        fb = 1 if cfg.compress == "int8" else 4
        ov = 2 * 4 if cfg.compress == "int8" else 0
        bytes_sent += (g.shape[1] * cfg.rank
                       + g.shape[0] * cfg.rank) * fb + ov
        out_g.append(approx.astype(g.dtype))
        out_s.append(st2)
    metrics = {"powersgd_compression": bytes_dense / max(bytes_sent, 1)}
    return treedef.unflatten(out_g), treedef.unflatten(out_s), metrics


def compress_tree(cfg: PowerSGDConfig, grads, state, *, psum=None,
                  policy=None, interpret=None):
    """End-to-end: compress each eligible grad, (optionally) reduce factors
    across DP with ``psum`` (a MEAN-reduce callable), decompress.
    Non-eligible leaves are reduced dense. Returns (grads, state, metrics)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state)
    out_g, out_s = [], []
    bytes_dense = bytes_sent = 0
    for g, st in zip(flat_g, flat_s):
        bytes_dense += g.size * 4
        if st is None:
            g2 = psum(g) if psum else g
            bytes_sent += g.size * 4
            out_g.append(g2)
            out_s.append(None)
            continue
        approx, st2 = compress_one(cfg, g, st, psum=psum, policy=policy,
                                   interpret=interpret)
        # int8 wire format: 1 byte/elem + one f32 scale per factor.
        fb = 1 if cfg.compress == "int8" else 4
        ov = 2 * 4 if cfg.compress == "int8" else 0
        bytes_sent += (st2["q"].size + approx.shape[0] * cfg.rank) * fb + ov
        out_g.append(approx.astype(g.dtype))
        out_s.append(st2)
    metrics = {"powersgd_compression": bytes_dense / max(bytes_sent, 1)}
    return treedef.unflatten(out_g), treedef.unflatten(out_s), metrics
