"""Production meshes. Functions, not module constants: importing this must
never touch jax device state (the dry-run sets XLA_FLAGS first)."""

from __future__ import annotations

import jax

from repro.kernels import compat


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod: 16x16 = 256 chips; multi-pod: 2 pods = 512 chips.

    Axes: 'data' carries batch (DP/FSDP/ZeRO), 'model' carries TP/EP/SP.
    The 'pod' axis extends DP across the inter-pod DCN/ICI boundary --
    gradient all-reduces hierarchically decompose (intra-pod reduce-scatter
    + inter-pod all-reduce on the pod axis), which XLA emits automatically
    for P(('pod','data')) sharded batches.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many devices this host exposes (tests)."""
    n = len(jax.devices())
    assert n % model == 0
    return compat.make_mesh((n // model, model), ("data", "model"))
