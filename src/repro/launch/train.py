"""Production training launcher.

Wires together: config registry, mesh + GSPMD sharding, resumable data
pipeline, AdamW + schedule, optional PowerSGD compression, async atomic
checkpointing, ABFT verification, straggler watchdog, preemption handling,
and elastic restore. This is the entry point a cluster scheduler re-execs
on every (re)start; all state recovery is automatic.

Step-fault rollback/retry: each step's ``step_ok`` metric (finite loss +
grad norm; an online-ABFT NaN-poison from ``--abft verify|correct`` trips
it too) gates a retry ladder -- roll back to the last in-memory host
snapshot and replay (bounded by ``--max-step-retries``), then escalate to
``Checkpointer.restore_latest_good``, then give up with a tagged error.
``--chaos-step N`` injects a one-shot NaN into the state before step N to
exercise exactly this path (see tests/test_train_rollback.py).

    python -m repro.launch.train --arch llama3.2-3b --steps 200 \
        --global-batch 8 --seq-len 128 --smoke --ckpt-dir /tmp/run1

On real TPU pods: run under `jax.distributed.initialize()` (flag
--distributed), one process per host; the mesh comes from launch/mesh.py
and XLA latency-hiding flags are set below. On this CPU container the same
code path runs with the host mesh (--smoke uses reduced configs).
"""

from __future__ import annotations

import argparse
import contextlib
import os
import sys
import time

# Async-collective / latency-hiding flags for real TPU runs (no-ops on CPU).
_TPU_PERF_FLAGS = (
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_data_parallel_all_reduce_opt=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced per-arch config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--abft-every", type=int, default=0,
                    help="verify param checksums every N steps (0=off)")
    ap.add_argument("--abft", choices=("none", "verify", "correct"),
                    default="none",
                    help="online per-GEMM checksum guard (GemmPolicy.abft)")
    ap.add_argument("--max-step-retries", type=int, default=2,
                    help="in-memory rollback replays per fault episode "
                         "before escalating to a checkpoint restore")
    ap.add_argument("--snapshot-every", type=int, default=1,
                    help="refresh the rollback host snapshot every N good "
                         "steps (0=never; faults then escalate directly)")
    ap.add_argument("--chaos-step", type=int, default=-1,
                    help="inject a one-shot NaN into the state before this "
                         "step (fault-injection drill; -1=off)")
    ap.add_argument("--powersgd-rank", type=int, default=0,
                    help="gradient compression rank (0=off)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.distributed:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " " + _TPU_PERF_FLAGS)
        import jax
        jax.distributed.initialize()
    import jax
    import jax.numpy as jnp

    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs import registry
    from repro.core import tsmm
    from repro.data import pipeline
    from repro.distributed import sharding
    from repro.ft import abft, elastic, inject, watchdog
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw, powersgd, schedule
    from repro.train import train_step as ts

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(model=args.model_axis)
    host_index = jax.process_index()
    host_count = jax.process_count()

    dcfg = pipeline.DataConfig(
        seed=0, seq_len=args.seq_len, global_batch=args.global_batch,
        vocab_size=cfg.vocab_size, host_index=host_index,
        host_count=host_count,
        mode="frames" if cfg.input_mode == "frames" else "tokens",
        frame_dim=cfg.frame_dim, vision_seq=cfg.vision_seq,
        vision_dim=cfg.vision_dim)

    opt_cfg = adamw.AdamWConfig(
        lr=schedule.linear_warmup_cosine(args.lr, args.warmup, args.steps),
        weight_decay=0.1)

    # --- sharding-aware state init / restore -------------------------------
    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shape = jax.eval_shape(lambda k: ts.init_train_state(
        k, cfg, opt_cfg)["params"], key_s)
    p_specs = sharding.make_param_specs(cfg, params_shape, mesh)
    p_named = sharding.named(mesh, p_specs)
    state_specs = {"params": p_specs, "opt": sharding.make_opt_specs(p_specs)}
    state_named = sharding.named(mesh, state_specs)

    grad_transform = None
    extra = None
    if args.powersgd_rank:
        ps_cfg = powersgd.PowerSGDConfig(rank=args.powersgd_rank)
        params_eval = jax.eval_shape(lambda k: ts.init_train_state(
            k, cfg, opt_cfg)["params"], key_s)
        extra = powersgd.init(ps_cfg, params_eval, jax.random.PRNGKey(17))
        extra = jax.tree.map(
            lambda s: (jax.numpy.zeros(s.shape, s.dtype)
                       if hasattr(s, "shape") else s), extra,
            is_leaf=lambda x: x is None or hasattr(x, "shape"))

        def grad_transform(grads, st):
            return powersgd.compress_tree(ps_cfg, grads, st)

    step_fn = jax.jit(
        ts.make_train_step(cfg, opt_cfg, n_micro=cfg.microbatch,
                           grad_transform=grad_transform,
                           acc_shardings=p_named),
        donate_argnums=(0,))

    ckpt = Checkpointer(args.ckpt_dir, keep_n=3) if args.ckpt_dir else None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        state, start_step = elastic.restore_state(
            ckpt, cfg, elastic.rescale_plan(model_axis=args.model_axis,
                                            host_index=host_index,
                                            host_count=host_count),
            {"params": params_shape})
        print(f"[train] restored checkpoint at step {start_step}")
        start_step += 1
    else:
        with mesh:
            state = jax.jit(
                lambda k: ts.init_train_state(k, cfg, opt_cfg, extra=extra),
                out_shardings=(state_named if extra is None else None),
            )(jax.random.PRNGKey(0))

    wd = watchdog.StepWatchdog(
        on_straggler=lambda dt, ewma: print(
            f"[watchdog] straggler step: {dt:.2f}s vs ewma {ewma:.2f}s "
            "-- scheduling proactive checkpoint"))
    preempt = watchdog.PreemptionHandler()
    prefetch = pipeline.Prefetcher(dcfg, start_step=start_step)

    def refetch(from_step):
        nonlocal prefetch
        prefetch.close()
        prefetch = pipeline.Prefetcher(dcfg, start_step=from_step)

    # Rollback ladder state: last-known-good in-memory snapshot, bounded
    # replays per fault episode, then checkpoint escalation.
    snap = None                       # (step, host pytree)
    retries_left = args.max_step_retries
    total_retries = 0
    chaos_pending = args.chaos_step >= 0
    last_metrics = {}

    abft_scope = (tsmm.policy(abft=args.abft) if args.abft != "none"
                  else contextlib.nullcontext())
    t_start = time.time()
    try:
        with abft_scope:
            cur = start_step
            while cur < args.steps:
                step, host_batch = prefetch.get()
                batch = jax.tree.map(jnp.asarray, host_batch)
                if chaos_pending and step == args.chaos_step:
                    # One-shot drill: a transient in-memory fault the
                    # step_ok gate must catch and the ladder must undo.
                    # Target the params subtree specifically -- the fault
                    # must surface in THIS step's loss, not launder
                    # through the optimizer state into a state the gate
                    # passes (and the snapshot would then preserve).
                    state = {**state,
                             "params": inject.poison_tree(state["params"])}
                    chaos_pending = False
                    print(f"[chaos] poisoned state before step {step}")
                with wd:
                    with mesh:
                        state, metrics = step_fn(state, batch)
                    step_ok = bool(metrics["step_ok"])
                if not step_ok:
                    wd.note_fault()
                    total_retries += 1
                    if retries_left > 0 and snap is not None:
                        retries_left -= 1
                        state = ts.restore_snapshot(snap[1])
                        cur = snap[0] + 1
                        refetch(cur)
                        print(f"[ft] step {step} fault: rolled back to "
                              f"snapshot at step {snap[0]}, replaying "
                              f"({retries_left} retries left)", flush=True)
                        continue
                    if ckpt and ckpt.all_steps():
                        state, rstep = ckpt.restore_latest_good()
                        state = jax.tree.map(jnp.asarray, state)
                        cur = rstep + 1
                        refetch(cur)
                        snap = None
                        retries_left = args.max_step_retries
                        print(f"[ft] step {step} fault: retries exhausted, "
                              f"restored checkpoint step {rstep}", flush=True)
                        continue
                    raise RuntimeError(
                        f"[ft-retries] step {step} faulted with no snapshot "
                        "retries left and no restorable checkpoint")
                # -- good step ------------------------------------------
                retries_left = args.max_step_retries
                last_metrics = metrics
                wm = wd.last_metrics
                if args.snapshot_every and step % args.snapshot_every == 0:
                    snap = (step, ts.host_snapshot(state))
                if step % args.log_every == 0 or step == args.steps - 1:
                    print(f"[train] step {step} "
                          f"loss {float(metrics['loss']):.4f} "
                          f"acc {float(metrics['accuracy']):.3f} "
                          f"gnorm {float(metrics['grad_norm']):.2f} "
                          f"{wm['step_time_s']:.2f}s", flush=True)
                if ckpt and (step % args.ckpt_every == 0
                             or step == args.steps - 1 or preempt.requested):
                    if args.abft_every and step % args.abft_every == 0:
                        # encode -> verify -> save: the verify re-encodes,
                        # catching SDC landing on the params between the
                        # two passes, BEFORE the state is persisted -- a
                        # detected-corrupt tree must never become the
                        # newest checkpoint.
                        checksums = abft.encode_tree(state["params"])
                        ok, _ = abft.verify_tree(state["params"], checksums)
                        if not bool(ok):
                            raise RuntimeError(
                                "[abft] silent data corruption detected in "
                                "params -- refusing to persist; restore + "
                                "replay")
                    ckpt.save(step, state)
                if preempt.requested:
                    print("[train] preemption requested: checkpointed, "
                          "exiting 42")
                    ckpt and ckpt.wait()
                    sys.exit(42)   # scheduler contract: re-exec to resume
                cur = step + 1
    finally:
        prefetch.close()
        preempt.restore()
        if ckpt:
            ckpt.wait()
    dt = time.time() - t_start
    steps_run = args.steps - start_step
    print(f"[train] done: {steps_run} steps in {dt:.1f}s "
          f"({steps_run / max(dt, 1e-9):.2f} steps/s); "
          f"fault retries: {total_retries}")
    return {"final_loss": float(last_metrics.get("loss", float("nan"))),
            "final_step": args.steps - 1,
            "fault_retries": total_retries,
            "fault_events": wd.fault_events}


if __name__ == "__main__":
    main()
