import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 placeholder host
devices. Smoke tests / benchmarks never import this module and keep 1
device.

Per cell this produces (artifacts/dryrun/<arch>__<shape>__<mesh>.json):
  * proof of compile (the deliverable: sharding is coherent),
  * memory_analysis()  -- per-device bytes (argument/temp/output),
  * cost_analysis()    -- HLO FLOPs / bytes (per partition),
  * parsed collective wire bytes (roofline/analyze.py),
  * the three roofline terms + dominant bottleneck + 6ND ratio.

Run one cell:   python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
Run everything: python -m repro.launch.dryrun --all   (subprocess per cell,
                smallest archs first, already-done cells skipped)
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def _cell_path(arch, shape, mesh_kind, out_dir, strategy="tp", variant=None):
    suffix = ("" if strategy == "tp" else f"__{strategy}") + \
        ("" if not variant else f"__{variant}")
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def input_specs(cfg, shape, kind: str):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    import jax
    import jax.numpy as jnp
    b, s = shape.global_batch, shape.seq_len
    if kind == "train":
        batch = {}
        if cfg.input_mode == "frames":
            batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.frame_dim), jnp.bfloat16)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        batch["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_seq, cfg.vision_dim), jnp.bfloat16)
        return batch
    if kind == "prefill":
        if cfg.input_mode == "frames":   # encoder: prefill = full forward
            return {"frames": jax.ShapeDtypeStruct((b, s, cfg.frame_dim),
                                                   jnp.bfloat16)}
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_seq, cfg.vision_dim), jnp.bfloat16)
        return batch
    # decode: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               strategy: str = "tp", variant: str | None = None):
    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.configs.base import SHAPES
    from repro.distributed import sharding
    from repro.launch.mesh import make_production_mesh
    from repro.models import model
    from repro.optim import adamw, schedule
    from repro.roofline import analyze
    from repro.train import train_step as ts

    cfg = registry.get_config(arch)
    if variant == "noabsorb":
        cfg = dataclasses.replace(cfg, mla_absorb=False)
    elif variant and variant.startswith("mb"):
        import re as _re
        cfg = dataclasses.replace(
            cfg, microbatch=int(_re.match(r"mb(\d+)", variant).group(1)))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    if cfg.moe is not None:
        # group-local MoE dispatch: one group per DP shard
        dp = n_chips // mesh.shape["model"]
        groups = dp if (shape.global_batch * shape.seq_len) % dp == 0 else 1
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=groups))
    t0 = time.time()

    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shape = jax.eval_shape(lambda k: model.init(k, cfg), key_s)
    p_specs = sharding.make_param_specs(cfg, params_shape, mesh,
                                        strategy=strategy)
    p_named = sharding.named(mesh, p_specs)

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(
            lr=schedule.linear_warmup_cosine(3e-4, 2000, 100000),
            state_dtype="bfloat16" if cfg.param_count() > 1e11 else None)
        state_shape = jax.eval_shape(
            lambda k: ts.init_train_state(k, cfg, opt_cfg), key_s)
        state_specs = {"params": p_specs,
                       "opt": sharding.make_opt_specs(
                           p_specs, mesh=mesh, params_shape=params_shape,
                           zero1=(strategy == "dp"))}
        state_named = sharding.named(mesh, state_specs)
        batch_shape = input_specs(cfg, shape, "train")
        b_named = sharding.named(
            mesh, sharding.batch_specs(cfg, mesh, batch_shape, strategy))
        n_micro = 0 if strategy == "dp" else cfg.microbatch
        upd_specs = (jax.tree.map(lambda mv: mv["m"],
                                  state_specs["opt"]["moments"],
                                  is_leaf=lambda x: isinstance(x, dict)
                                  and "m" in x)
                     if strategy == "dp" else None)
        step_fn = ts.make_train_step(cfg, opt_cfg, n_micro=n_micro,
                                     acc_shardings=p_named, mesh=mesh,
                                     opt_update_specs=upd_specs)
        with mesh:
            # donate the train state: params/opt buffers alias in-place
            lowered = jax.jit(step_fn,
                              in_shardings=(state_named, b_named),
                              out_shardings=(state_named, None),
                              donate_argnums=(0,)
                              ).lower(state_shape, batch_shape)
    elif shape.kind == "prefill":
        batch_shape = input_specs(cfg, shape, "prefill")
        b_named = sharding.named(
            mesh, sharding.batch_specs(cfg, mesh, batch_shape))
        if cfg.input_mode == "frames":
            # encoder-only: "prefill" = the batched encoder forward pass
            def encode_step(params, batch):
                return model.forward(params, cfg, batch)

            with mesh:
                lowered = jax.jit(encode_step,
                                  in_shardings=(p_named, b_named)
                                  ).lower(params_shape, batch_shape)
        else:
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(cfg, shape.global_batch, shape.seq_len))
            c_named = sharding.named(
                mesh, sharding.cache_specs(cfg, mesh, cache_shape))

            def prefill_step(params, batch, cache):
                return model.prefill(params, cfg, batch, cache)

            with mesh:
                lowered = jax.jit(prefill_step,
                                  in_shardings=(p_named, b_named, c_named),
                                  out_shardings=(None, c_named)
                                  ).lower(params_shape, batch_shape, cache_shape)
    else:  # decode
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(cfg, shape.global_batch, shape.seq_len))
        c_named = sharding.named(
            mesh, sharding.cache_specs(cfg, mesh, cache_shape))
        tok_shape = input_specs(cfg, shape, "decode")["tokens"]
        t_named = sharding.named(
            mesh, sharding.batch_specs(cfg, mesh, {"tokens": tok_shape}))["tokens"]

        def decode_step(params, tokens, pos, cache):
            return model.decode_step(params, cfg, tokens, pos, cache)

        with mesh:
            lowered = jax.jit(decode_step,
                              in_shardings=(p_named, t_named, None, c_named),
                              out_shardings=(None, c_named)
                              ).lower(params_shape, tok_shape,
                                      jax.ShapeDtypeStruct((), jnp.int32),
                                      cache_shape)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    xla_cost = analyze.xla_cost_dict(compiled)
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        mem["total_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                              + mem["temp_bytes"] - mem["alias_bytes"])
        mem["fits_16gb_hbm"] = bool(mem["total_bytes"] <= analyze.V5E["hbm_per_chip"])
    except Exception as e:  # backend without memory analysis
        mem = {"error": repr(e)}

    hlo = compiled.as_text()
    report = analyze_hlo(hlo, cfg, shape, n_chips, xla_cost=xla_cost)
    report.update({
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "kind": shape.kind,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "strategy": strategy, "variant": variant,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "status": "ok",
    })
    return report, hlo


def analyze_hlo(hlo: str, cfg, shape, n_chips: int, xla_cost=None):
    """Roofline terms from optimized HLO (loop-aware; re-runnable offline)."""
    from repro.roofline import analyze

    cost = analyze.hlo_cost(hlo)
    coll = analyze.parse_collectives(hlo)
    terms = analyze.roofline_terms(cost, coll, n_chips)
    mf = analyze.model_flops(cfg, shape)
    terms["model_flops_total"] = mf
    terms["model_flops_per_chip"] = mf / n_chips
    terms["useful_flops_ratio"] = (mf / n_chips) / max(terms["hlo_flops"], 1.0)
    return {
        "cost_flops": terms["hlo_flops"],
        "cost_bytes": terms["hlo_bytes"],
        "xla_cost_flops_unrolled_once": float((xla_cost or {}).get("flops", 0)),
        "roofline": {k: terms[k] for k in
                     ("compute_s", "memory_s", "collective_s", "dominant",
                      "collective_bytes", "useful_flops_ratio")},
        "collective_counts": terms["collective_counts"],
        "collective_by_kind": terms["collective_by_kind"],
    }


def run_cell(arch, shape_name, mesh_kind, out_dir, strategy="tp", variant=None):
    path = _cell_path(arch, shape_name, mesh_kind, out_dir, strategy, variant)
    os.makedirs(out_dir, exist_ok=True)
    try:
        report, hlo = build_cell(arch, shape_name, mesh_kind == "multi",
                                 strategy, variant)
        import gzip
        with gzip.open(path[:-5] + ".hlo.gz", "wt") as f:
            f.write(hlo)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
              f"(compile {report['compile_s']}s, dominant="
              f"{report['roofline']['dominant']})")
        if isinstance(report["memory"], dict) and "total_bytes" in report["memory"]:
            print(f"  memory/device: {report['memory']['total_bytes']/2**30:.2f} GiB "
                  f"(fits 16GiB: {report['memory']['fits_16gb_hbm']})")
        print(f"  flops/chip: {report['cost_flops']:.3e}  bytes/chip: "
              f"{report['cost_bytes']:.3e}  collective bytes/chip: "
              f"{report['roofline']['collective_bytes']:.3e}")
    except Exception:
        report = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "error", "traceback": traceback.format_exc()}
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: FAILED",
              file=sys.stderr)
        print(report["traceback"], file=sys.stderr)
    with open(path, "w") as f:
        json.dump(report, f, indent=2, default=str)
    return report.get("status") == "ok"


# Smallest-compile-first ordering for --all.
_ARCH_ORDER = [
    "rwkv6-1.6b", "zamba2-1.2b", "hubert-xlarge", "chatglm3-6b",
    "llama3.2-3b", "mistral-nemo-12b", "llama-3.2-vision-11b",
    "mixtral-8x7b", "qwen2-72b", "deepseek-v3-671b",
]


def reanalyze(out_dir):
    """Recompute roofline JSONs from saved .hlo.gz (no recompilation)."""
    import glob
    import gzip

    from repro.configs import registry
    from repro.configs.base import SHAPES

    for hf in sorted(glob.glob(os.path.join(out_dir, "*.hlo.gz"))):
        jf = hf[:-7] + ".json"
        if not os.path.exists(jf):
            continue
        with open(jf) as f:
            report = json.load(f)
        if report.get("status") != "ok":
            continue
        cfg = registry.get_config(report["arch"])
        shape = SHAPES[report["shape"]]
        with gzip.open(hf, "rt") as f:
            hlo = f.read()
        report.update(analyze_hlo(hlo, cfg, shape, report["n_chips"]))
        with open(jf, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"[reanalyze] {os.path.basename(jf)}: "
              f"dominant={report['roofline']['dominant']} "
              f"6ND/HLO={report['roofline']['useful_flops_ratio']:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true")
    ap.add_argument("--strategy", default="tp", choices=["tp", "dp"])
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default=os.path.abspath(ARTIFACTS))
    args = ap.parse_args()

    if args.reanalyze:
        reanalyze(args.out)
        return

    from repro.configs import registry

    if args.all:
        cells = []
        for arch in _ARCH_ORDER:
            for shape in ("decode_32k", "long_500k", "train_4k", "prefill_32k"):
                ok, _ = registry.cell_supported(arch, shape)
                if not ok:
                    continue
                for mesh_kind in (("single", "multi") if args.mesh == "both"
                                  else (args.mesh,)):
                    cells.append((arch, shape, mesh_kind))
        todo = [c for c in cells if args.force or
                not os.path.exists(_cell_path(*c, args.out))]
        print(f"[dryrun] {len(todo)}/{len(cells)} cells to run")
        failures = 0
        for arch, shape, mesh_kind in todo:
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                 "--shape", shape, "--mesh", mesh_kind, "--out", args.out],
                env={**os.environ, "PYTHONPATH": os.environ.get("PYTHONPATH", "src")})
            failures += r.returncode != 0
        sys.exit(1 if failures else 0)

    ok = run_cell(args.arch, args.shape, args.mesh, args.out,
                  args.strategy, args.variant)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
