"""Paper Table 3/4 analogue: the parameter-chooser's output per shape,
plus the bound classification (t2^threshold decision) per GPU->TPU port."""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import perf_model, tsmm


def run():
    pol = tsmm.current_policy()
    rows = []
    rows.append(("policy_mode", 0,
                 f"mode={pol.mode};spec={pol.spec.name};"
                 f"shard_map={pol.shard_map}"))
    rows.append(("t2_threshold_v5e_bf16",
                 round(perf_model.t2_threshold(dtype=jnp.bfloat16), 1),
                 "n below => memory-bound (all paper shapes)"))
    rows.append(("t2_threshold_v5e_f32",
                 round(perf_model.t2_threshold(dtype=jnp.float32), 1), ""))
    rows.append(("t2_threshold_v5p_bf16",
                 round(perf_model.t2_threshold(perf_model.V5P,
                                               jnp.bfloat16), 1),
                 "lower ridge: same shape can flip bound class across gens"))
    for (m, k, n) in [(20480, 20480, 2), (20480, 20480, 16), (30720, 30720, 8),
                      (15360, 15360, 16), (10_000_000, 16, 16), (102400, 4, 4),
                      (4096, 4096, 1024)]:
        kind = tsmm.classify_gemm(m, k, n, pol)
        bound = perf_model.classify(m, k, n, pol.spec)
        if kind == "tsm2r":
            bm, bk, s = perf_model.choose_params_tsm2r(m, k, n, pol.spec)
            vmem = perf_model.tsm2r_vmem_usage(bm, bk, n, jnp.bfloat16)
            det = (f"bound={bound};bm={bm};bk={bk};splits={s};"
                   f"vmem_kb={vmem//1024}")
        elif kind == "tsm2l":
            bm = perf_model.choose_params_tsm2l(m, k, n, pol.spec)
            det = f"bound={bound};bm={bm}"
        else:
            det = f"bound={bound};dense-XLA path"
        rows.append((f"params_m{m}_k{k}_n{n}", 0, f"kind={kind};{det}"))
    return emit(rows)


if __name__ == "__main__":
    run()
