"""Paper Fig. 6/10 (TSM2R speedup) + Fig. 7/11 (bandwidth utilization).

Rows per (m=k, n): XLA-dot CPU baseline time; V0 (inner-product, the
paper's cuBLAS-workaround strawman) and V1 (outer-product) CPU times; the
modeled v5e kernel time; modeled bandwidth & compute utilization (the
paper's score metric); and the modeled speedup over an ideal-dense-MXU
baseline at the same shape (the cuBLAS-analogue: min(compute-bound,
memory-bound) time for XLA's generic tiling which re-tiles B per 128-lane
MXU pass -- see derivation in EXPERIMENTS.md)."""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, rand, timeit_arm
from repro.core import perf_model
from repro.kernels import ref

# CPU-timed shapes (scaled) + modeled-only paper shapes.
CPU_SHAPES = [(2048, 2048), (4096, 4096)]
PAPER_SHAPES = [(10240, 10240), (20480, 20480), (30720, 30720)]
NS = (2, 4, 8, 16)


def xla_baseline_model_time(m, k, n, spec=perf_model.V5E, dtype=jnp.bfloat16):
    """v5e model of the vendor-generic GEMM on tall-skinny input: pads n to
    the 128-lane MXU tile => moves/computes 128/n more than useful work."""
    b = perf_model.bytes_per_elem(dtype)
    n_pad = max(n, 128)
    t_mem = (m * k + k * n_pad + m * n_pad) * b / spec.hbm_bw
    t_comp = 2 * m * k * n_pad / spec.peak_flops(dtype)
    return max(t_mem, t_comp)


def run():
    rows = []
    for m, k in CPU_SHAPES:
        for n in NS:
            a = rand(m + n, (m, k))
            b = rand(m - n, (k, n))
            t_dot, _ = timeit_arm(ref.tsm2r_ref, a, b)
            t_v1, _ = timeit_arm(ref.tsm2r_v1_outer, a, b)
            t_v0 = (timeit_arm(ref.tsm2r_v0_inner, a, b)[0]
                    if n <= 8 else float("nan"))
            rows.append((f"tsm2r_cpu_m{m}_n{n}_dot", round(t_dot, 1),
                         f"v0={t_v0:.0f}us;v1={t_v1:.0f}us"))
    for m, k in CPU_SHAPES + PAPER_SHAPES:
        for n in NS:
            bm, bk, s = perf_model.choose_params_tsm2r(m, k, n)
            t_model = perf_model.tsm2r_model_time(m, k, n, bm, bk, splits=s)
            util = perf_model.modeled_bandwidth_utilization(m, k, n, bm, bk,
                                                            splits=s)
            cutil = perf_model.modeled_compute_utilization(m, k, n, bm, bk,
                                                           splits=s)
            t_base = xla_baseline_model_time(m, k, n)
            rows.append((
                f"tsm2r_v5e_m{m}_n{n}", round(t_model * 1e6, 1),
                f"bw_util={util:.3f};comp_util={cutil:.4f};"
                f"speedup_vs_generic={t_base / t_model:.2f};bm={bm};bk={bk};"
                f"splits={s}"))
    return emit(rows)


if __name__ == "__main__":
    run()
