"""Paper Fig. 5 (tcf sweep) + Fig. 13/14 (TSM2L speedup / bandwidth).

The tcf sweep maps to block_m (rows per grid cell): small block_m = many
shallow grid steps (the latency-bound naive port, paper Fig. 4); large
block_m = fat cells that amortize pipeline overhead (paper's tcf=8 best
case at m=1e7)."""

from __future__ import annotations


from benchmarks.common import emit, rand, timeit_arm
from repro.core import perf_model
from repro.kernels import ref

MS = (10_000, 100_000, 1_000_000, 10_000_000)
KNS = ((8, 8), (16, 16))


def run():
    rows = []
    # Fig. 5 analogue: block_m sweep at m=1e7, k=n=16
    m, k, n = 10_000_000, 16, 16
    for bm in (256, 1024, 4096, 16384):
        t = perf_model.tsm2l_model_time(m, k, n, bm)
        util = min(1.0, (m * k + k * n + m * n) * 2 / (t * perf_model.V5E.hbm_bw))
        rows.append((f"tsm2l_tcf_sweep_bm{bm}", round(t * 1e6, 1),
                     f"bw_util={util:.3f}"))
    # Fig. 13/14 analogue
    for m in MS:
        for k, n in KNS:
            bm = perf_model.choose_params_tsm2l(m, k, n)
            t = perf_model.tsm2l_model_time(m, k, n, bm)
            util = min(1.0, (m * k + k * n + m * n) * 2 / (t * perf_model.V5E.hbm_bw))
            # generic-GEMM baseline: pads both k and n to the 128 MXU tile
            b = 2
            t_base = max((m * 128 + 128 * 128 + m * 128) * b / perf_model.V5E.hbm_bw,
                         2 * m * 128 * 128 / perf_model.V5E.peak_flops_bf16)
            rows.append((f"tsm2l_v5e_m{m}_k{k}n{n}", round(t * 1e6, 1),
                         f"bw_util={util:.3f};speedup_vs_generic={t_base/t:.2f};bm={bm}"))
    # CPU-timed reference path at a scaled shape
    for m in (100_000, 1_000_000):
        a, bb = rand(m, (m, 16)), rand(m + 1, (16, 16))
        t_dot, _ = timeit_arm(ref.tsm2l_ref, a, bb)
        rows.append((f"tsm2l_cpu_m{m}_dot", round(t_dot, 1), ""))
    return emit(rows)


if __name__ == "__main__":
    run()
