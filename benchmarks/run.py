"""Benchmark orchestrator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV per section. The roofline tables
(arch x shape cells) are produced separately by launch/dryrun.py +
roofline_report.py since they need the 512-device placeholder runtime.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_ablation, bench_e2e, bench_params,
                            bench_rect, bench_tsm2l, bench_tsm2r)
    sections = [
        ("Fig6/7+10/11: TSM2R speedup + utilization", bench_tsm2r.run),
        ("Fig5+13/14: TSM2L tcf sweep + speedup", bench_tsm2l.run),
        ("Fig12: non-square input", bench_rect.run),
        ("Table3/4: kernel parameters + bound classes", bench_params.run),
        ("Fig6 ladder: V0->V3 ablation", bench_ablation.run),
        ("e2e: train/decode step throughput", bench_e2e.run),
    ]
    failures = 0
    for title, fn in sections:
        print(f"\n# === {title} ===")
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
