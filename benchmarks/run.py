"""Benchmark orchestrator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--json BENCH_out.json]
                                            [--sections SUBSTR]

Prints ``name,us_per_call,derived`` CSV per section. ``--json`` also writes
a machine-readable report (per-section rows, bound classes for the
canonical paper shapes, and the active GemmPolicy) so the perf trajectory
can be tracked across PRs -- CI convention: ``BENCH_<rev>.json``.
``--sections`` runs only sections whose title contains the substring.

The roofline tables (arch x shape cells) are produced separately by
launch/dryrun.py + roofline_report.py since they need the 512-device
placeholder runtime.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

# Canonical paper shapes whose classification is tracked in the JSON
# report (paper cases (i)/(ii), the rect sweep anchor, and a dense control).
CANONICAL_SHAPES = [
    (20480, 20480, 2),
    (20480, 20480, 16),
    (30720, 30720, 8),
    (102400, 4, 4),
    (10_000_000, 16, 16),
    (4096, 4096, 1024),
]


def _num(x):
    try:
        return float(x)
    except (TypeError, ValueError):
        return None


def build_report(section_results):
    """Assemble the machine-readable report from
    ``{title: ("ok"|"error", rows)}``. Pure function (tested)."""
    import jax

    from repro.core import perf_model, tsmm

    pol = tsmm.current_policy()
    report = {
        "schema": "repro-tsm2x-bench/1",
        "backend": jax.default_backend(),
        "policy": {
            "mode": pol.mode,
            "spec": pol.spec.name,
            "interpret": pol.interpret,
            "shard_map": pol.shard_map,
        },
        "sections": {},
        "classification": [],
    }
    for title, (status, rows) in section_results.items():
        report["sections"][title] = {
            "status": status,
            "rows": [
                {"name": str(r[0]),
                 "us_per_call": _num(r[1]),
                 "derived": str(r[2]) if len(r) > 2 else ""}
                for r in rows
            ],
        }
    for m, k, n in CANONICAL_SHAPES:
        report["classification"].append({
            "m": m, "k": k, "n": n,
            "kind": tsmm.classify_gemm(m, k, n),
            "kind_t": tsmm.classify_gemm_t(m, k, n),
            "bound": perf_model.classify(m, k, n),
            "policy_mode": pol.mode,
        })
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", dest="json_out", metavar="OUT.json",
                    help="also write a machine-readable BENCH_*.json report")
    ap.add_argument("--sections", metavar="SUBSTR",
                    help="only run sections whose title contains SUBSTR")
    args = ap.parse_args(argv)

    from benchmarks import (bench_ablation, bench_e2e, bench_params,
                            bench_rect, bench_tsm2l, bench_tsm2r)
    sections = [
        ("Fig6/7+10/11: TSM2R speedup + utilization", bench_tsm2r.run),
        ("Fig5+13/14: TSM2L tcf sweep + speedup", bench_tsm2l.run),
        ("Fig12: non-square input", bench_rect.run),
        ("Table3/4: kernel parameters + bound classes", bench_params.run),
        ("Fig6 ladder: V0->V3 ablation", bench_ablation.run),
        ("e2e: train/decode step throughput", bench_e2e.run),
    ]
    if args.sections:
        sections = [(t, fn) for t, fn in sections if args.sections in t]

    failures = 0
    results = {}
    for title, fn in sections:
        print(f"\n# === {title} ===")
        try:
            results[title] = ("ok", fn() or [])
        except Exception:
            failures += 1
            results[title] = ("error", [])
            traceback.print_exc()

    if args.json_out:
        report = build_report(results)
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"\nwrote {args.json_out}")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
