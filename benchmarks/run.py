"""Benchmark orchestrator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--json BENCH_out.json]
                                            [--sections SUBSTR]
                                            [--autotune]
                                            [--autotune-shapes SPEC]

Prints ``name,us_per_call,derived`` CSV per section. ``--json`` also writes
a machine-readable report (per-section rows, bound classes for the
canonical paper shapes, the active GemmPolicy, and a dispatch-sanity block
asserting each policy arm hit its intended executor) so the perf
trajectory can be tracked across PRs -- CI convention: ``BENCH_<rev>.json``.
``--sections`` runs only sections whose title contains the substring.

``--autotune`` additionally runs the measured-wall-clock autotuner
(``core.autotune``) over a small shape set, emitting the TuningTable, the
per-shape model-vs-measured error, and the calibrated model constants into
the report. Off-TPU the kernels run in interpret mode, so the absolute
times exercise the mechanism only; authoritative tables come from a real
TPU run (README "Autotuning"). ``--autotune-shapes`` overrides the shape
list: semicolon-separated ``kind:m,k,n`` entries, e.g.
``tsm2r:4096,1024,8;tsm2l:8192,16,16``.

The roofline tables (arch x shape cells) are produced separately by
launch/dryrun.py + roofline_report.py since they need the 512-device
placeholder runtime.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

# Canonical paper shapes whose classification is tracked in the JSON
# report (paper cases (i)/(ii), the rect sweep anchor, and a dense control).
CANONICAL_SHAPES = [
    (20480, 20480, 2),
    (20480, 20480, 16),
    (30720, 30720, 8),
    (102400, 4, 4),
    (10_000_000, 16, 16),
    (4096, 4096, 1024),
]

# Default --autotune shape set: one shape per kernel kind, small enough to
# measure in interpret mode on CI's CPU runners.
AUTOTUNE_SHAPES = [
    ("tsm2r", 2048, 512, 8),
    ("tsm2l", 8192, 16, 16),
    ("tsmt", 4096, 64, 8),
]


def _num(x):
    try:
        return float(x)
    except (TypeError, ValueError):
        return None


def parse_autotune_shapes(text):
    """``"tsm2r:4096,1024,8;tsm2l:8192,16,16"`` -> [(kind, m, d1, d2), ...]."""
    shapes = []
    for part in text.split(";"):
        part = part.strip()
        if not part:
            continue
        kind, _, dims = part.partition(":")
        try:
            m, d1, d2 = (int(v) for v in dims.split(","))
        except ValueError:
            raise SystemExit(
                f"--autotune-shapes entry {part!r} is not kind:m,k,n") from None
        shapes.append((kind.strip(), m, d1, d2))
    return shapes


def run_autotune(shapes, reps: int = 3, warmup: int = 1):
    """Autotune + calibrate; return the report payload (also printed)."""
    import jax.numpy as jnp

    from repro.core import autotune, tsmm

    pol = tsmm.current_policy()
    result = autotune.calibrate(shapes, dtype=jnp.float32, policy=pol,
                                reps=reps, warmup=warmup)
    table = result.table
    model_error = []
    print("name,us_per_call,derived")
    for r in table.records:
        m, d1, d2 = r.shape
        model_error.append({
            "kind": r.kind, "m": m, "d1": d1, "d2": d2,
            "executor": r.executor,
            "best_params": dict(r.params),
            "measured_us": r.measured_us,
            "model_us": r.model_us,
            "model_error": r.model_error,
            "model_pick": dict(r.model_pick),
            "model_pick_measured_us": r.model_pick_measured_us,
            "pick_matches": r.pick_matches,
        })
        print(f"autotune_{r.kind}_m{m},{r.measured_us:.1f},"
              f"best={dict(r.params)};model_pick={dict(r.model_pick)};"
              f"model_err={r.model_error:.3f}")
    print(f"autotune_calibration,0,err_before={result.error_before:.3f};"
          f"err_after={result.error_after:.3f}")
    return {
        "shapes": [list(s) for s in shapes],
        "table": table.to_json(),
        "model_error": model_error,
        "calibration": {
            "error_before": result.error_before,
            "error_after": result.error_after,
            "fitted": {
                "step_overhead": result.spec.step_overhead,
                "dma_latency": result.spec.dma_latency,
                "vmem_usable": result.spec.vmem_usable,
            },
        },
    }


def build_report(section_results, autotune=None, dispatch_sanity=None):
    """Assemble the machine-readable report from
    ``{title: ("ok"|"error", rows)}``. Pure function (tested); the
    ``autotune`` / ``dispatch_sanity`` payloads are computed by main."""
    import jax

    from repro.core import perf_model, tsmm

    pol = tsmm.current_policy()
    tbl = pol.tuning_table
    report = {
        "schema": "repro-tsm2x-bench/1",
        "backend": jax.default_backend(),
        "policy": {
            "mode": pol.mode,
            "spec": pol.spec.name,
            "interpret": pol.interpret,
            "shard_map": pol.shard_map,
            "reduce": pol.reduce,
            "split": pol.split,
            "dp_axes": list(pol.dp_axes) if pol.dp_axes else None,
            "tuning_table_records": len(tbl.records) if tbl is not None else 0,
        },
        "sections": {},
        "classification": [],
        "autotune": autotune,
        "dispatch_sanity": dispatch_sanity,
    }
    for title, (status, rows) in section_results.items():
        report["sections"][title] = {
            "status": status,
            "rows": [
                {"name": str(r[0]),
                 "us_per_call": _num(r[1]),
                 "derived": str(r[2]) if len(r) > 2 else ""}
                for r in rows
            ],
        }
    for m, k, n in CANONICAL_SHAPES:
        report["classification"].append({
            "m": m, "k": k, "n": n,
            "kind": tsmm.classify_gemm(m, k, n),
            "kind_t": tsmm.classify_gemm_t(m, k, n),
            "bound": perf_model.classify(m, k, n),
            "policy_mode": pol.mode,
        })
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", dest="json_out", metavar="OUT.json",
                    help="also write a machine-readable BENCH_*.json report")
    ap.add_argument("--sections", metavar="SUBSTR",
                    help="only run sections whose title contains SUBSTR")
    ap.add_argument("--autotune", action="store_true",
                    help="run the measured-time autotuner + model calibration "
                         "and emit the TuningTable into the report")
    ap.add_argument("--autotune-shapes", metavar="SPEC",
                    help="override autotune shapes: kind:m,k,n;kind:m,k,n")
    args = ap.parse_args(argv)

    from benchmarks import (bench_ab, bench_abft, bench_ablation,
                            bench_collectives, bench_e2e, bench_params,
                            bench_qr, bench_rect, bench_tsm2l, bench_tsm2r)
    sections = [
        ("Fig6/7+10/11: TSM2R speedup + utilization", bench_tsm2r.run),
        ("Fig5+13/14: TSM2L tcf sweep + speedup", bench_tsm2l.run),
        ("Fig12: non-square input", bench_rect.run),
        ("Table3/4: kernel parameters + bound classes", bench_params.run),
        ("Fig6 ladder: V0->V3 ablation", bench_ablation.run),
        ("A/B: policy arms, jit-cache isolated", bench_ab.run),
        ("int8_vs_f32: quantized kernel arms vs f32 oracle", bench_ab.run_int8),
        ("collectives: psum vs psum_scatter tsmm_t arms", bench_collectives.run),
        ("qr: tsqr vs dense-oracle vs gram-schmidt", bench_qr.run),
        ("abft_overhead: online checksum arms vs abft=none", bench_abft.run),
        ("e2e: train/decode step throughput", bench_e2e.run),
    ]
    if args.sections:
        sections = [(t, fn) for t, fn in sections if args.sections in t]

    failures = 0
    results = {}
    for title, fn in sections:
        print(f"\n# === {title} ===")
        try:
            results[title] = ("ok", fn() or [])
        except Exception:
            failures += 1
            results[title] = ("error", [])
            traceback.print_exc()

    autotune_payload = None
    if args.autotune:
        print("\n# === autotune: measured-time parameter search ===")
        shapes = (parse_autotune_shapes(args.autotune_shapes)
                  if args.autotune_shapes else AUTOTUNE_SHAPES)
        try:
            autotune_payload = run_autotune(shapes)
        except Exception:
            failures += 1
            traceback.print_exc()

    if args.json_out:
        from benchmarks import common
        report = build_report(results, autotune=autotune_payload,
                              dispatch_sanity=common.dispatch_sanity())
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"\nwrote {args.json_out}")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
