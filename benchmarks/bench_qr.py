"""Tall-skinny QR arms: CholeskyQR2 on the kernels vs the dense oracles.

Three arms per shape, all jit-cache isolated and dispatch-asserted via
``timeit_arm``:

* ``qr_tsqr`` -- ``repro.linalg.tsqr``; the arm FAILS unless every GEMM
  stage (Gram + apply, every pass) dispatched on the kernel executor --
  this is the executor assertion the acceptance bar asks for, in timing
  form (the committed-baseline form lives in ``dispatch_sanity``'s
  ``qr_stages`` arm).
* ``qr_oracle`` -- ``jnp.linalg.qr`` (Householder on stock XLA); must not
  touch the dispatcher at all.
* ``qr_gram_schmidt`` -- PowerSGD's unrolled Gram-Schmidt, the
  orthogonalization ``orth="tsqr"`` replaces; also dispatcher-free.

On this CPU container the kernels run in interpret mode, so the tsqr wall
times are mechanism-only (see common.py's measurement policy); relative
oracle/GS times are meaningful.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, rand, timeit_arm
from repro import linalg
from repro.core import tsmm
from repro.optim import powersgd

# (m, r): the PowerSGD P-factor shape and a taller sketching-basis shape.
SHAPES = [(8192, 16), (65536, 32)]


def run():
    rows = []
    for m, r in SHAPES:
        a = rand(0, (m, r))
        us, log = timeit_arm(lambda a_: linalg.tsqr(a_)[0], a,
                             policy=tsmm.GemmPolicy(),
                             expect_executors={"pallas-tpu"})
        kinds = "+".join(sorted({e.kind for e in log}))
        rows.append((f"qr_tsqr_m{m}_r{r}", f"{us:.1f}",
                     f"cholqr2;kinds={kinds};stages={len(log)}"))
        us, _ = timeit_arm(lambda a_: jnp.linalg.qr(a_)[0], a,
                           expect_executors=set())
        rows.append((f"qr_oracle_m{m}_r{r}", f"{us:.1f}", "householder-xla"))
        us, _ = timeit_arm(powersgd._orthonormalize, a,
                           expect_executors=set())
        rows.append((f"qr_gram_schmidt_m{m}_r{r}", f"{us:.1f}",
                     "unrolled-gs"))
    return emit(rows)


if __name__ == "__main__":
    run()
