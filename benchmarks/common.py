"""Shared benchmark utilities.

Measurement policy on this CPU container (documented in EXPERIMENTS.md):
* jnp/XLA paths (dot baseline, V0/V1 ladder) are WALL-CLOCK timed -- they
  compile natively, so relative CPU timings are meaningful proxies.
* Pallas kernels run in interpret mode here (Python), so their wall time
  is meaningless; the kernel numbers reported are the *modeled v5e* terms
  from core/perf_model.py (the paper's own Fig.7/11 metric -- bandwidth
  fraction), plus numerics validation against the oracle.

A/B arms and policy scopes: the dispatch policy is captured at *trace*
time, so two arms that share one jitted callable silently reuse the first
arm's baked-in policy -- the timing-leakage bug. ``timeit_arm`` gives each
arm a fresh ``jax.jit`` wrapper traced inside its own policy scope (via
``core.autotune.jit_isolated``, the same harness the autotuner uses) and
asserts through ``record_dispatches`` that the arm actually hit its
intended executor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tsmm
from repro.core.autotune import jit_isolated, time_call  # noqa: F401


def timeit(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of jitted fn (one timing loop repo-wide:
    ``core.autotune.time_call``)."""
    return time_call(fn, *args, reps=reps, warmup=warmup) * 1e6


def timeit_arm(fn, *args, policy=None, expect_executors=None, reps: int = 5,
               warmup: int = 1):
    """Time one A/B arm with jit-cache isolation + dispatch sanity.

    ``fn`` is wrapped in a *fresh* ``jax.jit`` and traced under ``policy``
    (a GemmPolicy, or None for the current scope), so the arm owns its
    cache entry. ``expect_executors``: exact set of executor names the
    trace must have dispatched to (raises AssertionError otherwise); None
    skips the check. Returns ``(us_per_call, dispatch_log)``.
    """
    f, log = jit_isolated(fn, *args, policy=policy)
    if expect_executors is not None:
        seen = {e.executor for e in log}
        if seen != set(expect_executors):
            raise AssertionError(
                f"arm hit executors {sorted(seen)}, expected "
                f"{sorted(expect_executors)}; dispatch log: {log}")
    return timeit(f, *args, reps=reps, warmup=warmup), log


def dispatch_sanity(m: int = 4096, k: int = 512, n: int = 8):
    """One row per canonical policy arm: did a fresh jit under that policy
    hit the executor the policy intends? Emitted into the --json report so
    CI can fail on silent dispatch regressions (benchmarks/
    check_regression.py gates on these rows vs the committed baseline).

    Split-reduction arms: ``tsmm_t`` under ``split=4`` vs ``split="never"``
    must both stay on the kernel executor AND the dispatch events must
    carry the scope's split knob (``DispatchEvent.split``) -- a policy that
    silently stops threading the knob fails the arm even though the
    executor looks right.

    The ``quant_int8`` arm asserts the int8 operand path the same way:
    kernel executor, with ``DispatchEvent.quant == "int8"`` on every
    event.

    On a >1-device backend mesh arms join: ``tsmm_t`` under a DP mesh
    must land on ``shard_map`` (reduce="psum", replicated output) and on
    ``shard_map-scatter`` (reduce="psum_scatter", sharded output); the
    ``mesh_psum_split`` arm asserts that a split scope does not disturb
    the collective contract (same executors, split knob on every event
    down to the per-shard re-dispatch)."""
    a, b = rand(0, (m, k)), rand(1, (k, n))
    arms = [
        ("dense", tsmm.GemmPolicy(mode="dense"), "dense-xla"),
        ("auto", tsmm.GemmPolicy(), "pallas-tpu"),
        ("interpret", tsmm.GemmPolicy(interpret=True), "interpret"),
    ]
    out = []
    for name, pol, expect in arms:
        _, log = jit_isolated(lambda a_, b_: tsmm.tsmm(a_, b_), a, b,
                              policy=pol)
        observed = sorted({e.executor for e in log})
        out.append({"arm": name, "shape": [m, k, n], "expected": expect,
                    "observed": observed, "ok": observed == [expect]})
    # Split-vs-sequential arms on the headline TSMT (PowerSGD/ABFT) shape.
    x_t, y_t = rand(4, (m, 64)), rand(5, (m, n))
    split_arms = [
        ("tsmt_split4", tsmm.GemmPolicy(split=4), 4),
        ("tsmt_sequential", tsmm.GemmPolicy(split="never"), "never"),
    ]
    for name, pol, knob in split_arms:
        _, log = jit_isolated(lambda x_, y_: tsmm.tsmm_t(x_, y_), x_t, y_t,
                              policy=pol)
        observed = sorted({e.executor for e in log})
        splits_seen = sorted({str(e.split) for e in log})
        out.append({"arm": name, "shape": [m, 64, n],
                    "expected": "pallas-tpu", "observed": observed,
                    "split": splits_seen,
                    "ok": (observed == ["pallas-tpu"]
                           and splits_seen == [str(knob)])})
    # Quantized arm: the int8 operand path must stay on the kernel
    # executor AND every dispatch event must carry the quant knob
    # (``DispatchEvent.quant``) -- a policy that silently stops threading
    # quant="int8" through dispatch fails the arm even though the
    # executor looks right.
    _, log = jit_isolated(lambda a_, b_: tsmm.tsmm(a_, b_), a, b,
                          policy=tsmm.GemmPolicy(quant="int8"))
    observed = sorted({e.executor for e in log})
    quants_seen = sorted({str(e.quant) for e in log})
    out.append({"arm": "quant_int8", "shape": [m, k, n],
                "expected": "pallas-tpu", "observed": observed,
                "quant": quants_seen,
                "ok": (observed == ["pallas-tpu"]
                       and quants_seen == ["int8"])})
    # Online-ABFT arms. abft="none" is the zero-overhead contract: exactly
    # ONE dispatch, no checksum GEMMs in the trace. The guarded modes must
    # dispatch exactly four GEMMs (protected + the three checksum stages of
    # ``contracts.abft_stage_shapes``) with the mode stamped on exactly one
    # event (``DispatchEvent.abft``) -- a wrap that guards the checksum
    # GEMMs recursively, or stops stamping, fails the arm even though the
    # executors look right.
    _, log = jit_isolated(lambda a_, b_: tsmm.tsmm(a_, b_), a, b,
                          policy=tsmm.GemmPolicy(abft="none"))
    observed = sorted({e.executor for e in log})
    out.append({"arm": "abft_none", "shape": [m, k, n],
                "expected": "pallas-tpu", "observed": observed,
                "events": len(log),
                "ok": observed == ["pallas-tpu"] and len(log) == 1})
    for mode in ("verify", "correct"):
        _, log = jit_isolated(lambda a_, b_: tsmm.tsmm(a_, b_), a, b,
                              policy=tsmm.GemmPolicy(abft=mode))
        observed = sorted({e.executor for e in log})
        flagged = [e for e in log if e.abft == mode]
        out.append({"arm": f"abft_{mode}", "shape": [m, k, n],
                    "expected": sorted({"dense-xla", "pallas-tpu"}),
                    "observed": observed, "events": len(log),
                    "abft": sorted({e.abft for e in log}),
                    "ok": (observed == ["dense-xla", "pallas-tpu"]
                           and len(log) == 4 and len(flagged) == 1)})
    # QR stages: both GEMMs of the CholeskyQR2 factorization (Gram and
    # R^-1 apply, every pass) must land on the tall-skinny kernels -- the
    # Gram as tsmt, the apply as tsm2l, and nothing on dense-xla. The
    # kind set is asserted alongside the executor set: a classifier drift
    # that silently sent the Gram to tsm2r would keep the executor green.
    from repro import linalg
    a_qr = rand(6, (m, 16))
    _, log = jit_isolated(lambda a_: linalg.tsqr(a_)[0], a_qr,
                          policy=tsmm.GemmPolicy())
    observed = sorted({e.executor for e in log})
    kinds = sorted({e.kind for e in log})
    out.append({"arm": "qr_stages", "shape": [m, 16, 16],
                "expected": "pallas-tpu", "observed": observed,
                "kinds": kinds,
                "ok": (observed == ["pallas-tpu"]
                       and kinds == ["tsm2l", "tsmt"])})
    devs = jax.devices()
    # The mesh arms need a per-shard shape that still classifies tsmt and
    # a scatter dim that divides the shard count: scale the tall dim with
    # the device count and skip when 64 rows can't tile the shards (odd
    # or >64-device backends) rather than emit guaranteed-false rows.
    if len(devs) > 1 and 64 % len(devs) == 0:
        from jax.sharding import Mesh
        import numpy as np
        mesh = Mesh(np.array(devs), ("data",))
        m_mesh = 2048 * len(devs)
        x, y = rand(2, (m_mesh, 64)), rand(3, (m_mesh, n))
        mesh_arms = [
            ("mesh_psum", tsmm.GemmPolicy(reduce="psum"), "shard_map",
             "auto"),
            ("mesh_psum_scatter", tsmm.GemmPolicy(reduce="psum_scatter"),
             "shard_map-scatter", "auto"),
            # Split partials must not change the psum contract: same
            # executor pair, the split knob visible on every event.
            ("mesh_psum_split", tsmm.GemmPolicy(reduce="psum", split=2),
             "shard_map", 2),
        ]
        for name, pol, expect, knob in mesh_arms:
            with mesh:
                _, log = jit_isolated(lambda x_, y_: tsmm.tsmm_t(x_, y_),
                                      x, y, policy=pol)
            observed = sorted({e.executor for e in log})
            splits_seen = sorted({str(e.split) for e in log})
            # Exact set, like the base arms: the outer executor plus the
            # per-shard kernel re-dispatch and NOTHING else -- an extra
            # dense-xla sneaking into the trace is a dispatch regression.
            expected = sorted({expect, "pallas-tpu"})
            out.append({"arm": name, "shape": [m_mesh, 64, n],
                        "expected": expected, "observed": observed,
                        "split": splits_seen,
                        "ok": (observed == expected
                               and splits_seen == [str(knob)])})
    return out


def rand(key, shape, dtype=jnp.float32):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32,
                              -1, 1).astype(dtype)


def emit(rows, header=("name", "us_per_call", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
