"""Shared benchmark utilities.

Measurement policy on this CPU container (documented in EXPERIMENTS.md):
* jnp/XLA paths (dot baseline, V0/V1 ladder) are WALL-CLOCK timed -- they
  compile natively, so relative CPU timings are meaningful proxies.
* Pallas kernels run in interpret mode here (Python), so their wall time
  is meaningless; the kernel numbers reported are the *modeled v5e* terms
  from core/perf_model.py (the paper's own Fig.7/11 metric -- bandwidth
  fraction), plus numerics validation against the oracle.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def timeit(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of jitted fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def rand(key, shape, dtype=jnp.float32):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32,
                              -1, 1).astype(dtype)


def emit(rows, header=("name", "us_per_call", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
