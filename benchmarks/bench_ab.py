"""A/B policy arms with per-arm jit-cache isolation.

Regression section for the A/B timing-leakage bug: dispatch policy is a
trace-time constant, so arms that shared one jitted callable across
``tsmm.policy`` scopes silently re-timed the first arm's baked-in policy.
Every arm here goes through ``benchmarks.common.timeit_arm`` (fresh jit
wrapper per arm) and ``record_dispatches`` asserts the arm actually hit
its intended executor -- a wrong route aborts the section instead of
publishing a bogus ratio.

``run_int8`` is the low-precision A/B: per kind, the f32 kernel arm vs
the ``GemmPolicy(quant="int8")`` arm (both executor-asserted; the quant
arm additionally asserts ``DispatchEvent.quant``), plus the quantized
output's max-normalized error against the f32 oracle, gated at the
documented 5% tolerance (README "Low-precision TSM2X").
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, rand, timeit_arm
from repro.core import tsmm

# Quantized output must stay within this of the f32 oracle (max-norm,
# relative to the oracle's absmax). Measured ~0.006 on these shapes; the
# README documents the 5% envelope.
INT8_REL_TOL = 0.05

# One shape per kernel kind, all inside the auto-dispatch regime.
SHAPES = [
    ("tsm2r", (4096, 1024, 8)),
    ("tsm2l", (8192, 16, 16)),
    ("tsmt", (4096, 64, 8)),
]


def run():
    rows = []
    for kind, (m, d1, d2) in SHAPES:
        if kind == "tsmt":
            x, y = rand(m + d1, (m, d1)), rand(m + d2, (m, d2))
            fn, args = (lambda x_, y_: tsmm.tsmm_t(x_, y_)), (x, y)
        else:
            a, b = rand(m + d1, (m, d1)), rand(m + d2, (d1, d2))
            fn, args = (lambda a_, b_: tsmm.tsmm(a_, b_)), (a, b)
        arms = [
            ("dense", tsmm.GemmPolicy(mode="dense"), {"dense-xla"}),
            ("auto", tsmm.GemmPolicy(), {"pallas-tpu"}),
            ("interpret", tsmm.GemmPolicy(interpret=True), {"interpret"}),
        ]
        if kind in ("tsm2r", "tsmt"):
            # Split-reduction A/B: the split-K kernel + tree-reduce
            # epilogue vs the sequential kernel the scope pins. tsm2l has
            # no reduction grid axis, hence no split arm.
            arms += [
                ("split4", tsmm.GemmPolicy(split=4), {"pallas-tpu"}),
                ("sequential", tsmm.GemmPolicy(split="never"),
                 {"pallas-tpu"}),
            ]
        times = {}
        for arm, pol, expect in arms:
            us, log = timeit_arm(fn, *args, policy=pol,
                                 expect_executors=expect, reps=3, warmup=1)
            times[arm] = us
            kinds = sorted({e.kind for e in log})
            splits = sorted({str(e.split) for e in log})
            rows.append((f"ab_{kind}_m{m}_{arm}", round(us, 1),
                         f"executors={'+'.join(sorted({e.executor for e in log}))};"
                         f"kinds={'+'.join(kinds)};split={'+'.join(splits)};"
                         f"dispatch_ok=1"))
        rows.append((f"ab_{kind}_m{m}_ratio", 0,
                     f"dense_over_auto={times['dense'] / times['auto']:.3f}"))
        if "split4" in times:
            rows.append((f"ab_{kind}_m{m}_split_ratio", 0,
                         f"sequential_over_split4="
                         f"{times['sequential'] / times['split4']:.3f}"))
    return emit(rows)


def run_int8():
    """int8_vs_f32: quantized-operand arms vs the f32 kernels per kind."""
    rows = []
    for kind, (m, d1, d2) in SHAPES:
        if kind == "tsmt":
            x, y = rand(m + d1, (m, d1)), rand(m + d2, (m, d2))
            fn, args = (lambda x_, y_: tsmm.tsmm_t(x_, y_)), (x, y)
            oracle = x.T @ y
        else:
            a, b = rand(m + d1, (m, d1)), rand(m + d2, (d1, d2))
            fn, args = (lambda a_, b_: tsmm.tsmm(a_, b_)), (a, b)
            oracle = a @ b
        times = {}
        for arm, pol in [("f32", tsmm.GemmPolicy()),
                         ("int8", tsmm.GemmPolicy(quant="int8"))]:
            us, log = timeit_arm(fn, *args, policy=pol,
                                 expect_executors={"pallas-tpu"},
                                 reps=3, warmup=1)
            times[arm] = us
            quants = sorted({str(e.quant) for e in log})
            if arm == "int8" and quants != ["int8"]:
                raise AssertionError(
                    f"int8 arm dispatched with quant knobs {quants}; "
                    f"dispatch log: {log}")
            rows.append((f"int8_vs_f32_{kind}_m{m}_{arm}", round(us, 1),
                         f"executors="
                         f"{'+'.join(sorted({e.executor for e in log}))};"
                         f"quant={'+'.join(quants)};dispatch_ok=1"))
        rows.append((f"int8_vs_f32_{kind}_m{m}_ratio", 0,
                     f"f32_over_int8={times['f32'] / times['int8']:.3f}"))
        with tsmm.policy(tsmm.GemmPolicy(quant="int8")):
            qout = fn(*args)
        rel = float(jnp.max(jnp.abs(qout - oracle))
                    / jnp.max(jnp.abs(oracle)))
        if rel > INT8_REL_TOL:
            raise AssertionError(
                f"{kind} int8 output off by {rel:.4f} rel (max-norm), "
                f"tolerance {INT8_REL_TOL}")
        rows.append((f"int8_vs_f32_{kind}_m{m}_err", 0,
                     f"rel_err_maxnorm={rel:.5f};tol={INT8_REL_TOL};ok=1"))
    return emit(rows)


if __name__ == "__main__":
    run()
    run_int8()
