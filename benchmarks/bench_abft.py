"""abft_overhead: online checksum guard arms vs the unguarded baseline.

Per protected shape, three jit-cache-isolated arms (``timeit_arm``):

* ``abft_none`` -- ``GemmPolicy(abft="none")``; the arm FAILS unless the
  trace contains exactly ONE dispatch (zero structural overhead: no
  checksum GEMMs, no guard math in the jaxpr).
* ``abft_verify`` / ``abft_correct`` -- the guarded arms; each must
  dispatch exactly four GEMMs (protected + u + c_ref + c_out per
  ``contracts.abft_stage_shapes``) with the guard mode stamped on
  exactly one event.

The checksum passes are bandwidth-bound at these shapes (skinny s=2
operands), so on real hardware the verify overhead is a small multiple
of the protected GEMM's own HBM traffic; on this CPU container the wall
times are interpret-mode mechanism numbers (see common.py's measurement
policy) and the gated signal is the dispatch structure, mirrored into
``common.dispatch_sanity`` for the committed-baseline gate.
"""

from __future__ import annotations

from benchmarks.common import emit, rand, timeit_arm
from repro.core import tsmm

# Protected shapes: the canonical tsm2r bench shape and a tsmt
# (PowerSGD/ABFT-encode style) shape.
MM_SHAPE = (4096, 512, 8)
MMT_SHAPE = (65536, 16, 16)


def _arm(fn, args, mode, expect, want_events):
    us, log = timeit_arm(fn, *args, policy=tsmm.GemmPolicy(abft=mode),
                         expect_executors=expect)
    flagged = [e for e in log if e.abft == mode]
    if len(log) != want_events:
        raise AssertionError(
            f"abft={mode!r} arm dispatched {len(log)} GEMMs, expected "
            f"{want_events}; log: {log}")
    if mode != "none" and len(flagged) != 1:
        raise AssertionError(
            f"abft={mode!r} arm stamped {len(flagged)} guarded events, "
            f"expected exactly 1; log: {log}")
    return us, len(log)


def run():
    rows = []
    for name, shape, fn in (
        ("tsm2r", MM_SHAPE,
         lambda a_, b_: tsmm.tsmm(a_, b_)),
        ("tsmt", MMT_SHAPE,
         lambda x_, y_: tsmm.tsmm_t(x_, y_)),
    ):
        m, d1, d2 = shape
        if name == "tsmt":
            args = (rand(0, (m, d1)), rand(1, (m, d2)))
        else:
            args = (rand(0, (m, d1)), rand(1, (d1, d2)))
        base_us, n_base = _arm(fn, args, "none", {"pallas-tpu"}, 1)
        rows.append((f"abft_none_{name}", f"{base_us:.1f}",
                     f"events={n_base};zero-overhead"))
        for mode in ("verify", "correct"):
            us, n_ev = _arm(fn, args, mode,
                            {"pallas-tpu", "dense-xla"}, 4)
            rows.append((f"abft_{mode}_{name}", f"{us:.1f}",
                         f"events={n_ev};x{us / max(base_us, 1e-9):.2f}"
                         " vs none"))
    return emit(rows)


if __name__ == "__main__":
    run()
