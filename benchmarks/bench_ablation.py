"""Paper Fig. 6 optimization ladder V0 -> V3 on one fixed shape.

V0 inner-product and V1 outer-product are CPU-timed jnp restatements;
V2 (VMEM staging) and V3 (+pipelined prefetch) exist inside the Pallas
kernel, so their deltas are reported from the v5e model: V2 = V3 without
pipelining overlap (memory and compute serialize); V3 = the shipped
kernel."""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, rand, timeit_arm
from repro.core import perf_model
from repro.kernels import ref


def run():
    m = k = 4096
    n = 8
    a, b = rand(1, (m, k)), rand(2, (k, n))
    rows = []
    t0, _ = timeit_arm(ref.tsm2r_v0_inner, a, b)
    t1, _ = timeit_arm(ref.tsm2r_v1_outer, a, b)
    t_dot, _ = timeit_arm(ref.tsm2r_ref, a, b)
    rows.append(("ablation_v0_inner_cpu", round(t0, 1), f"speedup_vs_v0=1.00"))
    rows.append(("ablation_v1_outer_cpu", round(t1, 1),
                 f"speedup_vs_v0={t0 / t1:.2f}"))
    rows.append(("ablation_xla_dot_cpu", round(t_dot, 1),
                 f"speedup_vs_v0={t0 / t_dot:.2f}"))
    bm, bk, _ = perf_model.choose_params_tsm2r(m, k, n)
    spec = perf_model.V5E
    bpe = perf_model.bytes_per_elem(jnp.bfloat16)
    gm, gk = m // bm, -(-k // bk)
    bytes_total = (m * k + k * 128 * gm + m * 128) * bpe
    t_mem = bytes_total / spec.hbm_bw
    t_comp = 2 * m * k * n / (spec.peak_flops_bf16 * n / 128)
    v2 = t_mem + t_comp + spec.dma_latency * gm * gk   # no overlap, no prefetch
    v3 = perf_model.tsm2r_model_time(m, k, n, bm, bk)  # pipelined (shipped)
    rows.append(("ablation_v2_staged_v5e_model", round(v2 * 1e6, 1),
                 "VMEM staging, serialized DMA/compute"))
    rows.append(("ablation_v3_pipelined_v5e_model", round(v3 * 1e6, 1),
                 f"speedup_v3_over_v2={v2 / v3:.2f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
