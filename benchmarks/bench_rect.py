"""Paper Fig. 12: non-square regular matrix (m != k) has ~no effect on
bandwidth utilization -- the kernel streams A row-tiles either way."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import perf_model


def run():
    rows = []
    m, n = 15360, 16
    for div in (1, 2, 4, 8):
        k = m // div
        bm, bk, s = perf_model.choose_params_tsm2r(m, k, n)
        t = perf_model.tsm2r_model_time(m, k, n, bm, bk, splits=s)
        util = perf_model.modeled_bandwidth_utilization(m, k, n, bm, bk,
                                                        splits=s)
        rows.append((f"tsm2r_rect_m{m}_k{k}", round(t * 1e6, 1),
                     f"bw_util={util:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
