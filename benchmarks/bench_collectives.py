"""psum vs psum_scatter arms for the sharded-consumer ``tsmm_t`` path.

Three jit-cache-isolated arms per shape under a data-parallel mesh over
every local device (``timeit_arm`` asserts each arm's executor via the
dispatch spy, so a silent dispatch regression fails the run rather than
timing the wrong thing):

* ``psum``          -- the replicated-output default (``shard_map``),
* ``psum_scatter``  -- the sharded-output executor (``shard_map-scatter``),
* ``dense``         -- stock XLA under GSPMD, the no-kernel control,
* ``psum_split``    -- psum with per-shard split reduction (``split=2``):
  split partials are summed inside each shard's kernel epilogue, so the
  executor pair and the collective contract must match the plain psum arm
  exactly -- this arm exists to catch a split path leaking partials across
  the shard boundary.

On this CPU container the per-shard kernels run in interpret mode, so the
absolute times exercise the mechanism only (see benchmarks/common.py's
measurement policy); the interesting CI signal is the executor assertions
plus the relative psum/psum_scatter trend, which is collective-structure,
not kernel, time. On a single-device backend the section emits one
"skipped" row instead of rows that would time nothing (CI runs it with
``XLA_FLAGS=--xla_force_host_platform_device_count=2``).

This file is in the ruff-format ratchet set (see ci.yml) -- keep edits
formatter-clean.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from benchmarks.common import emit, rand, timeit_arm
from repro.core import tsmm

# (per_shard_m, a_dim, b_dim): the tall dim is PER SHARD and scales with
# the device count at run time, so the local shape classifies tsmt (and
# the scatter dim divides) on any power-of-two mesh size up to a_dim --
# fixed global shapes would drop out of the per-shard regime at >4
# devices and fail the executor assertions.
SHAPES = [
    (4096, 256, 8),
    (8192, 512, 16),
]

SKIP_NOTE = "single-device backend: psum vs psum_scatter needs a >=2-device mesh"

# Per-shard re-dispatch logs the inner kernel executor alongside the outer
# shard_map one; the dense control must stay pure dense-xla.
EXPECT_PSUM = {"shard_map", "pallas-tpu"}
EXPECT_SCATTER = {"shard_map-scatter", "pallas-tpu"}
EXPECT_DENSE = {"dense-xla"}


def _mmt(x, y):
    return tsmm.tsmm_t(x, y)


def run():
    rows = []
    devs = jax.devices()
    if len(devs) < 2:
        rows.append(("collectives_skipped", 0, SKIP_NOTE))
        return emit(rows)
    mesh = Mesh(np.array(devs), ("data",))
    psum_pol = tsmm.GemmPolicy(reduce="psum")
    scatter_pol = tsmm.GemmPolicy(reduce="psum_scatter")
    dense_pol = tsmm.GemmPolicy(mode="dense")
    split_pol = tsmm.GemmPolicy(reduce="psum", split=2)
    for shard_m, a_dim, b_dim in SHAPES:
        m = shard_m * len(devs)
        x, y = rand(0, (m, a_dim)), rand(1, (m, b_dim))
        with mesh:
            us_p, _ = timeit_arm(
                _mmt, x, y, policy=psum_pol, expect_executors=EXPECT_PSUM
            )
            us_s, _ = timeit_arm(
                _mmt, x, y, policy=scatter_pol, expect_executors=EXPECT_SCATTER
            )
            us_d, _ = timeit_arm(
                _mmt, x, y, policy=dense_pol, expect_executors=EXPECT_DENSE
            )
            us_k, split_log = timeit_arm(
                _mmt, x, y, policy=split_pol, expect_executors=EXPECT_PSUM
            )
        assert {e.split for e in split_log} == {2}, split_log
        tag = f"m{m}_a{a_dim}_b{b_dim}"
        note_p = f"replicated out, {len(devs)} shards"
        note_s = f"sharded out; psum/scatter={us_p / us_s:.2f}"
        note_k = f"per-shard split=2; psum/psum_split={us_p / us_k:.2f}"
        rows.append((f"tsmmt_psum_{tag}", f"{us_p:.1f}", note_p))
        rows.append((f"tsmmt_psum_scatter_{tag}", f"{us_s:.1f}", note_s))
        rows.append((f"tsmmt_dense_{tag}", f"{us_d:.1f}", "dense-xla control"))
        rows.append((f"tsmmt_psum_split_{tag}", f"{us_k:.1f}", note_k))
    return emit(rows)


if __name__ == "__main__":
    run()
