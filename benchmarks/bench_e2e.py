"""End-to-end micro-benchmarks: train-step and decode-step throughput on
the reduced configs (CPU wall clock -- relative regressions tracking).

Each policy arm (tsmm dispatch vs forced-dense) is timed through
``timeit_arm``: a fresh jit wrapper traced inside its own policy scope,
with the dispatch spy asserting the dense arm really stayed on dense-xla.
Sharing one jitted step across arms would re-time the first arm's policy
(trace-time capture) -- the A/B leakage this harness exists to prevent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit_arm
from repro.configs import registry
from repro.core import tsmm
from repro.data import pipeline
from repro.models import model
from repro.optim import adamw
from repro.train import train_step as ts


def run():
    rows = []
    for arch in ("llama3.2-3b", "mixtral-8x7b", "rwkv6-1.6b"):
        cfg = registry.get_config(arch, smoke=True)
        dcfg = pipeline.DataConfig(seed=0, seq_len=64, global_batch=4,
                                   vocab_size=cfg.vocab_size)
        opt = adamw.AdamWConfig(lr=1e-3)
        state = ts.init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step_fn = ts.make_train_step(cfg, opt)
        batch = jax.tree.map(jnp.asarray, pipeline.batch_for_step(dcfg, 0))
        toks = 4 * 64
        arms = [("tsmm", None, None),
                ("dense", tsmm.GemmPolicy(mode="dense"), {"dense-xla"})]
        times = {}
        for arm, pol, expect in arms:
            us, log = timeit_arm(lambda s, b: step_fn(s, b)[0], state, batch,
                                 policy=pol, expect_executors=expect,
                                 reps=3, warmup=0)
            times[arm] = us
            execs = "+".join(sorted({e.executor for e in log})) or "none"
            rows.append((f"train_step_{arch}_smoke_{arm}", round(us, 0),
                         f"tokens_per_s={toks / (us / 1e6):.0f};"
                         f"executors={execs}"))
        rows.append((f"train_step_{arch}_smoke_ab", 0,
                     f"dense_over_tsmm={times['dense'] / times['tsmm']:.3f}"))

        params = model.init(jax.random.PRNGKey(0), cfg)
        cache = model.init_cache(cfg, 2, 64)
        tok = jnp.zeros((2, 1), jnp.int32)
        us, _ = timeit_arm(
            lambda p, t, c: model.decode_step(p, cfg, t, 5, c),
            params, tok, cache, reps=3, warmup=0)
        rows.append((f"decode_step_{arch}_smoke", round(us, 0),
                     f"tokens_per_s={2 / (us / 1e6):.0f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
