"""End-to-end micro-benchmarks: train-step and decode-step throughput on
the reduced configs (CPU wall clock -- relative regressions tracking)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs import registry
from repro.data import pipeline
from repro.models import model
from repro.optim import adamw
from repro.train import train_step as ts


def run():
    rows = []
    for arch in ("llama3.2-3b", "mixtral-8x7b", "rwkv6-1.6b"):
        cfg = registry.get_config(arch, smoke=True)
        dcfg = pipeline.DataConfig(seed=0, seq_len=64, global_batch=4,
                                   vocab_size=cfg.vocab_size)
        opt = adamw.AdamWConfig(lr=1e-3)
        state = ts.init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step = jax.jit(ts.make_train_step(cfg, opt))
        batch = jax.tree.map(jnp.asarray, pipeline.batch_for_step(dcfg, 0))
        us = timeit(lambda s, b: step(s, b)[0], state, batch, reps=3, warmup=1)
        toks = 4 * 64
        rows.append((f"train_step_{arch}_smoke", round(us, 0),
                     f"tokens_per_s={toks / (us / 1e6):.0f}"))

        params = model.init(jax.random.PRNGKey(0), cfg)
        cache = model.init_cache(cfg, 2, 64)
        dec = jax.jit(lambda p, t, pos, c: model.decode_step(p, cfg, t, pos, c))
        tok = jnp.zeros((2, 1), jnp.int32)
        us = timeit(lambda p, t, c: dec(p, t, 5, c), params, tok, cache,
                    reps=3, warmup=1)
        rows.append((f"decode_step_{arch}_smoke", round(us, 0),
                     f"tokens_per_s={2 / (us / 1e6):.0f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
