"""Bench-regression gate: compare a fresh BENCH_*.json against the
committed baseline.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline benchmarks/BENCH_baseline.json BENCH_<rev>.json

``--update-baseline`` rewrites the baseline file from the current report
instead of gating against it (refuses when the current report itself has
failing dispatch-sanity arms -- a broken run must not become the bar).
Use it after intentionally changing the arm set or the model, and commit
the diff.

Two classes of regression fail the gate (exit 1):

* dispatch sanity -- every policy arm that hit its intended executor in
  the baseline must still hit it. Arms new in the current report only
  need to pass themselves; an arm dropped from the report entirely is a
  failure (a silently deleted assertion is a regression too).
* autotune model error -- per (kind, shape) row, the model-vs-measured
  gap may not worsen by more than ``--tolerance`` (default 25%) relative
  to baseline. The gap is measured as ``|ln(model_us / measured_us)``|
  (the same log-scale objective ``autotune.calibrate`` minimizes), NOT
  the report's ``model_error`` ratio -- that ratio saturates at 1.0 when
  the model under-predicts (always the case in interpret mode, where
  measured Python-loop times dwarf the modeled v5e times), so a bound on
  it could never fire in the realistic direction. The log gap is
  unbounded both ways. Interpret-mode timings on shared CI runners are
  noisy, so rows only fail when they are ALSO more than ``--abs-floor``
  (default 0.25 nats) above baseline; rows lacking the ``*_us`` fields
  fall back to the ratio. Rows missing from the current report fail; new
  rows are informational.

Wall-clock section times are deliberately NOT gated -- on shared runners
they swing far more than any real regression, and the autotuner's model
error already tracks the kernel-level signal the paper cares about.

This file is in the ruff-format ratchet set (see ci.yml) -- keep edits
formatter-clean.
"""

from __future__ import annotations

import argparse
import json
import math
import sys


def _sanity_index(report):
    return {row["arm"]: row for row in (report.get("dispatch_sanity") or [])}


def _model_error_index(report):
    rows = (report.get("autotune") or {}).get("model_error") or []
    return {(r["kind"], r["m"], r["d1"], r["d2"]): r for r in rows}


def _check_sanity(current, baseline, failures):
    base_sanity = _sanity_index(baseline)
    cur_sanity = _sanity_index(current)
    for arm, base_row in base_sanity.items():
        cur_row = cur_sanity.get(arm)
        if cur_row is None:
            failures.append(f"dispatch_sanity arm {arm!r} missing vs baseline")
        elif base_row.get("ok") and not cur_row.get("ok"):
            expected = cur_row.get("expected")
            observed = cur_row.get("observed")
            failures.append(
                f"dispatch_sanity arm {arm!r} regressed: "
                f"expected {expected}, observed {observed}"
            )
    for arm, cur_row in cur_sanity.items():
        if arm not in base_sanity and not cur_row.get("ok"):
            expected = cur_row.get("expected")
            observed = cur_row.get("observed")
            failures.append(
                f"dispatch_sanity arm {arm!r} (new) failed: "
                f"expected {expected}, observed {observed}"
            )


def _row_gap(row):
    """Log-scale model gap for one row: |ln(model/measured)|, unbounded in
    both directions; falls back to the saturating model_error ratio when a
    report predates the ``*_us`` fields. None when neither is usable."""
    model_us = row.get("model_us")
    measured_us = row.get("measured_us")
    if model_us and measured_us and model_us > 0 and measured_us > 0:
        return abs(math.log(model_us / measured_us))
    return row.get("model_error")


def _check_model_error(current, baseline, tolerance, abs_floor, failures):
    base_err = _model_error_index(baseline)
    cur_err = _model_error_index(current)
    for key, base_row in base_err.items():
        cur_row = cur_err.get(key)
        name = "autotune model gap {}@({}, {}, {})".format(*key)
        if cur_row is None:
            failures.append(f"{name} missing from the current report")
            continue
        base_e = _row_gap(base_row)
        cur_e = _row_gap(cur_row)
        if base_e is None or cur_e is None:
            failures.append(f"{name} lacks model/measured fields")
            continue
        if cur_e > base_e * (1 + tolerance) and cur_e > base_e + abs_floor:
            failures.append(
                f"{name} worsened {base_e:.3f} -> {cur_e:.3f} nats "
                f"(> {tolerance:.0%} over baseline and > +{abs_floor} absolute)"
            )


def check(current, baseline, tolerance=0.25, abs_floor=0.25):
    """Return a list of human-readable failures (empty = gate passes)."""
    failures = []
    _check_sanity(current, baseline, failures)
    _check_model_error(current, baseline, tolerance, abs_floor, failures)
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH_*.json to gate")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="relative model-error worsening allowed (0.25 = 25%%)",
    )
    ap.add_argument(
        "--abs-floor",
        type=float,
        default=0.25,
        help="absolute log-gap slack in nats (noise floor for CI runners)",
    )
    ap.add_argument(
        "--update-baseline",
        action="store_true",
        help="rewrite the baseline file from the current report "
        "(refused when the current report has failing sanity arms)",
    )
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)

    if args.update_baseline:
        bad = [a for a, row in _sanity_index(current).items() if not row.get("ok")]
        if bad:
            print(
                "refusing --update-baseline: current report has failing "
                f"dispatch_sanity arms: {sorted(bad)}"
            )
            sys.exit(1)
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2)
            f.write("\n")
        n_arms = len(_sanity_index(current))
        n_rows = len(_model_error_index(current))
        print(
            f"baseline updated: {args.baseline} <- {args.current} "
            f"({n_arms} dispatch arms, {n_rows} model-error rows)"
        )
        return

    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = check(
        current, baseline, tolerance=args.tolerance, abs_floor=args.abs_floor
    )
    if failures:
        print(f"bench-regression gate: {len(failures)} failure(s) vs {args.baseline}:")
        for msg in failures:
            print(f"  - {msg}")
        sys.exit(1)
    n_arms = len(_sanity_index(current))
    n_rows = len(_model_error_index(current))
    print(
        f"bench-regression gate: OK ({n_arms} dispatch arms, {n_rows} "
        f"model-error rows within {args.tolerance:.0%} of {args.baseline})"
    )


if __name__ == "__main__":
    main()
