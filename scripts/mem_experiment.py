import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.distributed import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import model
from repro.optim import adamw
from repro.train import train_step as ts

arch = sys.argv[1] if len(sys.argv) > 1 else "llama3.2-3b"
base = registry.get_config(arch)
shape = SHAPES["train_4k"]
mesh = make_production_mesh()

variants = {
    "A_nomicro_rematlayer": dataclasses.replace(base, microbatch=0, remat_group=1),
    "B_nomicro_rematgrp4": dataclasses.replace(base, microbatch=0, remat_group=4),
    "C_micro4_rematlayer": dataclasses.replace(base, microbatch=4, remat_group=1),
    "D_micro4_rematgrp4": dataclasses.replace(base, microbatch=4, remat_group=4),
    "E_nomicro_noremat": dataclasses.replace(base, microbatch=0, remat=False),
}

key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
for name, cfg in variants.items():
    try:
        params_shape = jax.eval_shape(lambda k: model.init(k, cfg), key_s)
        p_specs = sharding.make_param_specs(cfg, params_shape, mesh)
        p_named = sharding.named(mesh, p_specs)
        opt_cfg = adamw.AdamWConfig(lr=3e-4)
        state_shape = jax.eval_shape(lambda k: ts.init_train_state(k, cfg, opt_cfg), key_s)
        state_specs = {"params": p_specs, "opt": sharding.make_opt_specs(p_specs)}
        state_named = sharding.named(mesh, state_specs)
        batch_shape = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
                       "targets": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
        b_named = sharding.named(mesh, sharding.batch_specs(cfg, mesh, batch_shape))
        step_fn = ts.make_train_step(cfg, opt_cfg, n_micro=cfg.microbatch,
                                     acc_shardings=p_named)
        with mesh:
            comp = jax.jit(step_fn, in_shardings=(state_named, b_named),
                           out_shardings=(state_named, None),
                           donate_argnums=(0,)).lower(state_shape, batch_shape).compile()
        ma = comp.memory_analysis()
        print(f"{name}: temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"arg={ma.argument_size_in_bytes/2**30:.2f} "
              f"alias={ma.alias_size_in_bytes/2**30:.2f}", flush=True)
    except Exception as e:
        print(f"{name}: FAILED {type(e).__name__}: {e}", flush=True)
