#!/usr/bin/env python
"""CI entry for the static-analysis layer: contract audit + repo linter.

Runs ``repro.analysis.audit --strict`` (kernel-launch contracts over the
full configuration space, the kernel-dataflow verifier, committed tuning
table, bench dispatch arms) and ``repro.analysis.lint`` (repo invariant
linter) in one process; exits non-zero if either finds a violation.
Pass-through flags go to the auditor, so ``scripts/check_contracts.py
--json report.json`` artifacts the machine-readable report.

``--dataflow-json PATH`` additionally extracts the ``kernel-dataflow``
section (grid-race / bounds / guard verification, including which grids
were corner-sampled -- see ``repro.analysis.kernel_verify``) into its own
artifact, so a dataflow failure is inspectable without digging through
the full report.

Equivalent to::

    PYTHONPATH=src python -m repro.analysis.audit --strict [flags]
    PYTHONPATH=src python -m repro.analysis.lint
"""

from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.analysis import audit, lint  # noqa: E402


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    dataflow_path = None
    if "--dataflow-json" in argv:
        i = argv.index("--dataflow-json")
        dataflow_path = pathlib.Path(argv[i + 1])
        del argv[i:i + 2]
        if "--json" not in argv:
            argv += ["--json", "audit-report.json"]
    if "--strict" not in argv:
        argv.append("--strict")
    audit_rc = audit.main(argv)
    if dataflow_path is not None:
        report_path = pathlib.Path(argv[argv.index("--json") + 1])
        report = json.loads(report_path.read_text())
        section = report["sections"]["kernel-dataflow"]
        dataflow_path.write_text(json.dumps(
            {"schema": report["schema"], "section": "kernel-dataflow",
             **section}, indent=2, sort_keys=True) + "\n")
    lint_rc = lint.main([])
    return audit_rc or lint_rc


if __name__ == "__main__":
    sys.exit(main())
