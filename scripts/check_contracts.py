#!/usr/bin/env python
"""CI entry for the static-analysis layer: contract audit + repo linter.

Runs ``repro.analysis.audit --strict`` (kernel-launch contracts over the
full configuration space, committed tuning table, bench dispatch arms)
and ``repro.analysis.lint`` (repo invariant linter) in one process; exits
non-zero if either finds a violation. Pass-through flags go to the
auditor, so ``scripts/check_contracts.py --json report.json`` artifacts
the machine-readable report.

Equivalent to::

    PYTHONPATH=src python -m repro.analysis.audit --strict [flags]
    PYTHONPATH=src python -m repro.analysis.lint
"""

from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.analysis import audit, lint  # noqa: E402


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--strict" not in argv:
        argv.append("--strict")
    audit_rc = audit.main(argv)
    lint_rc = lint.main([])
    return audit_rc or lint_rc


if __name__ == "__main__":
    sys.exit(main())
