"""Generate EXPERIMENTS.md from dry-run artifacts + the perf iteration log."""
import glob
import json
import os

ART = "/root/repo/artifacts/dryrun_v2"
HILL = "/root/repo/artifacts/hillclimb"


def load(d):
    out = {}
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        j = json.load(open(f))
        if j.get("status") != "ok":
            continue
        key = (j["arch"], j["shape"], j["mesh"], j.get("strategy", "tp"),
               j.get("variant") or "-")
        out[key] = j
    return out


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f} s"
    if x >= 1e-3:
        return f"{x*1e3:.1f} ms"
    return f"{x*1e6:.0f} us"


cells = load(ART)
hill = load(HILL)

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["zamba2-1.2b", "chatglm3-6b", "llama3.2-3b", "mistral-nemo-12b",
              "qwen2-72b", "deepseek-v3-671b", "mixtral-8x7b", "rwkv6-1.6b",
              "llama-3.2-vision-11b", "hubert-xlarge"]
SKIPS = {
    ("hubert-xlarge", "decode_32k"): "encoder-only: no decode",
    ("hubert-xlarge", "long_500k"): "encoder-only: no decode",
    ("chatglm3-6b", "long_500k"): "full attention",
    ("llama3.2-3b", "long_500k"): "full attention",
    ("mistral-nemo-12b", "long_500k"): "full attention",
    ("qwen2-72b", "long_500k"): "full attention",
    ("deepseek-v3-671b", "long_500k"): "full (MLA latent) attention",
    ("llama-3.2-vision-11b", "long_500k"): "full attention",
}

lines = []
A = lines.append


def dryrun_section():
    A("## §Dry-run — 16x16 (256 chips) and 2x16x16 (512 chips), all cells\n")
    A("Every supported (arch x shape) cell `.lower().compile()`s on BOTH "
      "production meshes — 64/64 compiles, zero sharding failures. "
      "`mem/dev` is `compiled.memory_analysis()` totals (args+temp+out-alias) "
      "per device on the dry-run backend; see the XLA:CPU-artifact caveat "
      "in §Perf. Skipped cells per the shape spec are listed explicitly.\n")
    A("| arch | shape | 16x16 compile | 16x16 mem/dev | fits 16G | "
      "2x16x16 compile | 2x16x16 mem/dev |")
    A("|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            if (arch, shape) in SKIPS:
                A(f"| {arch} | {shape} | — skipped: {SKIPS[(arch, shape)]} "
                  f"| | | | |")
                continue
            s = cells.get((arch, shape, "16x16", "tp", "-"))
            m = cells.get((arch, shape, "2x16x16", "tp", "-"))
            def mem(c):
                if not c or "total_bytes" not in c.get("memory", {}):
                    return "n/a"
                return f"{c['memory']['total_bytes']/2**30:.1f} GiB"
            fits = (s and s["memory"].get("fits_16gb_hbm"))
            A(f"| {arch} | {shape} | {s['compile_s'] if s else '?'} s "
              f"| {mem(s)} | {'yes' if fits else 'no'} "
              f"| {m['compile_s'] if m else '?'} s | {mem(m)} |")
    A("")
    A("Collective schedule sanity (per step, parsed from partitioned HLO): "
      "see per-cell JSON `collective_counts` / `collective_by_kind` under "
      "`artifacts/dryrun_v2/`.\n")


def roofline_section():
    A("## §Roofline — single pod (256 chips), per supported cell\n")
    A("Terms per the spec: `compute = HLO_FLOPs/(chips*197e12)`, "
      "`memory = HLO_bytes/(chips*819e9)`, `collective = wire_bytes/"
      "(chips-local 4 links * 50 GB/s)`. FLOPs/bytes come from the "
      "loop-aware HLO cost pass (`repro.roofline.analyze.hlo_cost`): "
      "`compiled.cost_analysis()` counts while-loop bodies once, which "
      "under-reports scanned-layer models by up to 432x (qwen2 train, "
      "measured) — validated against hand-counted programs in "
      "`tests/test_roofline.py`. Collective wire bytes use ring-algorithm "
      "formulas x loop trip counts. `6ND/HLO` = model FLOPs (6ND train / "
      "2ND inference, N=active params) over HLO FLOPs: the useful-compute "
      "fraction (<1 means remat/attention/dispatch overhead; decode cells "
      "<<1 are expected — decode work is bytes, not FLOPs).\n")
    A("| arch | shape | compute | memory | collective | dominant | 6ND/HLO |")
    A("|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            c = cells.get((arch, shape, "16x16", "tp", "-"))
            if not c:
                continue
            r = c["roofline"]
            A(f"| {arch} | {shape} | {fmt_s(r['compute_s'])} "
              f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
              f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} |")
    A("")
    # bottleneck commentary
    A("Per-cell bottleneck notes (what moves the dominant term):\n")
    notes = {
        "train_4k": "memory-dominant across archs: remat recompute + "
            "bf16 activation traffic; next lever = fewer saves (offload) "
            "or bf16 grads (measured below).",
        "prefill_32k": "memory-dominant; chunked-attention full-mask "
            "compute is ~2x the causal minimum — block-skipping is the "
            "next compute lever.",
        "decode_32k": "pure HBM streaming of params+cache (the paper's "
            "memory-bound regime); lever = cache layout/quantization.",
        "long_500k": "state/window-bounded decode: dominated by param "
            "reads at batch<=128; lever = multi-token speculation.",
    }
    for k, v in notes.items():
        A(f"* **{k}** — {v}")
    A("")


def perf_section():
    A("## §Perf — iteration log (hypothesis -> change -> measure -> verdict)\n")
    A("Hardware note: the dry-run backend is XLA:CPU with 512 placeholder "
      "devices; FLOPs/bytes/collective terms transfer to TPU, but some "
      "`memory_analysis` temps are CPU-fusion artifacts (full-tensor f32 "
      "round-trips around stack saves) that the TPU partitioner does not "
      "emit — flagged below where observed.\n")
    A("### Iteration log (llama3.2-3b x train_4k, single pod, the most "
      "instrumented cell)\n")
    A("| # | hypothesis | change | before -> after | verdict |")
    A("|---|---|---|---|---|")
    rows = [
        ("0", "vocab-gather in the loss forces (B,S,V) f32 logits "
              "all-gather (~34 GiB)",
         "iota-compare gold extraction + sequence-chunked loss "
         "(`losses.chunked_lm_loss`)",
         "HLO flops 1.29e13 -> 4.43e12/chip; mem 42.5 GiB unchanged",
         "partially confirmed: fixed 3x flops waste; memory had a second cause"),
        ("1", "temp memory is per-layer activation saves; microbatching "
              "should divide it by 4",
         "grad-accumulation scan (n_micro=4)",
         "42.5 -> 44.6 GiB; collective 3.3e11 -> 1.3e12",
         "refuted: batch-independent 38 GiB floor found -> bisect"),
        ("2", "attention backward saves all f32 score tiles (S^2)",
         "jax.checkpoint on the kv-step of chunked attention",
         "no-remat variant 428 -> 172 GiB; remat variant ~unchanged",
         "confirmed for the no-remat path; remat path dominated elsewhere"),
        ("3", "loss scan saves stacked f32 logits",
         "jax.checkpoint on the loss-chunk body",
         "38.3 -> 33.9 GiB",
         "confirmed (-4.4 GiB)"),
        ("4", "backward grad accumulator (scan carry) is replicated",
         "with_sharding_constraint on params (transpose pins cotangents) "
         "+ pinned f32 micro accumulator",
         "no memory change; buffer dump shows full-batch backward bodies",
         "refuted -> deeper bisect"),
        ("5", "**microbatch reshape mis-sharded**: GSPMD splits the data "
              "axis across micro AND batch dims, so each micro step runs "
              "4x the intended tokens",
         "pin reshaped batch to P(None, dp, ...)",
         "36.6 -> **12.1 GiB (fits!)**; collective 1.3e12 -> 3.3e11; "
         "6ND/HLO 16.5 -> 0.63",
         "confirmed — the dominant bug; also fixed the roofline accounting "
         "story for every train cell"),
        ("6", "global argsort in MoE dispatch gathers the world "
              "(deepseek prefill)",
         "group-local dispatch (one group per DP shard) + index-based "
         "scatter (no (T*k, d) data tensor)",
         "deepseek prefill 487 -> 40.6 GiB",
         "confirmed (12x)"),
        ("7", "FSDP-vs-batch axis conflict unshards the MoE group dim",
         "activation-side sharding pins through the expert einsums "
         "(fixed `maybe_wsc` to read the physical mesh — the abstract "
         "mesh is empty under `with mesh:`)",
         "40.6 -> 25.0 GiB",
         "confirmed"),
        ("8", "vision train's 400x-out-of-family memory term (8741 s) is a "
              "degenerate attention chunking: vision_seq=1601 is PRIME, so "
              "the divisor-shrink fallback ran kv_chunk=1 (a 1601-step scan "
              "per cross-attn layer)",
         "pad sequences to chunk multiples + mask, instead of shrinking "
         "the chunk",
         "bytes/chip 7.2e15 -> 1.87e13 (385x); mem 146.6 -> 17.9 GiB",
         "confirmed — found BY the roofline table, the methodology "
         "working as intended"),
        ("9", "mixtral train's 74 GiB is activation-dominated; doubling "
              "microbatching (mb4 -> mb8) and bf16 param grads should halve it",
         "--variant mb8 (+ REPRO_BF16_PARAM_GRADS=1)",
         "73.7 -> 63.4 GiB (mb8); bf16 grads: no change",
         "partially refuted: ~55 GiB batch-independent floor remains in the "
         "EP-TP hybrid backward (per-layer dispatch/scatter temps) -- "
         "open item; mixtral training is sized for >=2 pods meanwhile "
         "(63.4 -> 63.4/2-pod column)"),
    ]
    for r in rows:
        A("| " + " | ".join(r) + " |")
    A("")
    A("### Hillclimb cell 1 — llama3.2-3b x train_4k "
      "(most collective-bound family)\n")
    b = cells.get(("llama3.2-3b", "train_4k", "16x16", "tp", "-"))
    d = hill.get(("llama3.2-3b", "train_4k", "16x16", "dp", "-"))
    if b and d:
        A("| variant | memory/dev | HLO bytes/chip | collective bytes/chip "
          "| collective term | dominant |")
        A("|---|---|---|---|---|---|")
        for name, c in (("TP baseline (paper-faithful default: shard "
                         "weights over 'model')", b),
                        ("**beyond-paper: pure-DP + ZeRO-1** (batch over "
                         "all 256 ways, replicated weights, mesh-sharded "
                         "optimizer)", d)):
            r = c["roofline"]
            A(f"| {name} | {c['memory']['total_bytes']/2**30:.1f} GiB "
              f"| {c['cost_bytes']:.2e} | {r['collective_bytes']:.2e} "
              f"| {fmt_s(r['collective_s'])} | {r['dominant']} |")
        A("")
        A(f"DP cuts collective wire bytes {b['roofline']['collective_bytes']/d['roofline']['collective_bytes']:.0f}x "
          f"and HBM traffic {b['cost_bytes']/d['cost_bytes']:.1f}x for a 3B model "
          "on 256 chips — 16-way TP pays ~2 activation all-reduces/layer "
          "this model never needed. Its memory column regresses on the "
          "dry-run backend because XLA:CPU materializes full f32 converts "
          "of replicated params before slicing (verified in the buffer "
          "assignment; the pinned f32 update math is present and sharded). "
          "Production config: DP+ZeRO-1 for <=13B archs, TP(+FSDP) above.")
    A("")
    A("### Hillclimb cell 2 — deepseek-v3-671b x decode_32k "
      "(most representative of the paper: memory-bound skinny GEMMs)\n")
    b = cells.get(("deepseek-v3-671b", "decode_32k", "16x16", "tp", "-"))
    n = hill.get(("deepseek-v3-671b", "decode_32k", "16x16", "tp", "noabsorb"))
    if b and n:
        A("| variant | memory/dev | HLO bytes/chip | memory term | dominant |")
        A("|---|---|---|---|---|")
        A(f"| non-absorbed MLA decode (re-expand latent cache to per-head "
          f"K/V each step) | {n['memory']['total_bytes']/2**30:.1f} GiB "
          f"| {n['cost_bytes']:.2e} | {fmt_s(n['roofline']['memory_s'])} "
          f"| {n['roofline']['dominant']} |")
        A(f"| **absorbed MLA decode** (fold W_uk into Q, W_uv into out; "
          f"attention runs in the 512-d latent space) "
          f"| {b['memory']['total_bytes']/2**30:.1f} GiB "
          f"| {b['cost_bytes']:.2e} | {fmt_s(b['roofline']['memory_s'])} "
          f"| {b['roofline']['dominant']} |")
        A("")
        A(f"The absorbed form moves {n['cost_bytes']/b['cost_bytes']:.2f}x "
          "fewer bytes per decode step — on a memory-bound cell that is "
          "the step-time ratio. The projections involved (7168->512 "
          "latent, 512->128-per-head) are exactly the tall-and-skinny "
          "shapes the paper's kernels own; at batch 128 the activation "
          "side routes through the TSM2X dispatcher.")
    A("")
    A("### Hillclimb cell 3 — hubert-xlarge x train_4k "
      "(worst roofline fraction among train cells)\n")
    b = cells.get(("hubert-xlarge", "train_4k", "16x16", "tp", "-"))
    d = hill.get(("hubert-xlarge", "train_4k", "16x16", "dp", "-"))
    if b and d:
        A("| variant | memory/dev | HLO bytes/chip | collective bytes/chip "
          "| dominant |")
        A("|---|---|---|---|---|")
        for name, c in (("TP baseline", b), ("**pure-DP + ZeRO-1**", d)):
            r = c["roofline"]
            A(f"| {name} | {c['memory']['total_bytes']/2**30:.1f} GiB "
              f"| {c['cost_bytes']:.2e} | {r['collective_bytes']:.2e} "
              f"| {r['dominant']} |")
        A("")
        A(f"Collective bytes drop {b['roofline']['collective_bytes']/d['roofline']['collective_bytes']:.0f}x "
          f"(3.0e11 -> 3.8e9: just the ZeRO-1 grad reduce-scatter + param "
          f"all-gather), HBM traffic {b['cost_bytes']/d['cost_bytes']:.2f}x. "
          "A 1B encoder on 256 chips wants zero TP; both roofline terms "
          "improve and memory stays comfortably inside HBM (9.0 GiB).")
    A("")
    A("### Kernel-level (paper reproduction + beyond)\n")
    A("Paper-faithful ladder (bench_ablation / bench_tsm2r, modeled on the "
      "v5e terms the way the paper models Fig. 6/7 on GPU specs):\n")
    A("* V0 inner-product (the paper's cuBLAS-workaround strawman) -> V1 "
      "outer-product: CPU-measured, V1 touches A once.")
    A("* V2 VMEM staging (B pinned on-chip) -> V3 + pipelined prefetch "
      "(Mosaic double buffering): modeled 1.50x — the paper reports "
      "1.3–3.5x for the same step on GPUs (Fig. 6).")
    A("* TSM2R modeled bandwidth utilization at paper shapes "
      "(20480^2 x n<=16): **93–96% of 819 GB/s** (paper: up to ~55% on "
      "V100 for TSM2L, ~90%+ for TSM2R on V100 Fig. 11); modeled speedup "
      "vs the 128-lane-padded generic GEMM: ~8x at n=2, ~2x at n=16 "
      "(paper Fig. 10: 1.1–3.2x vs cuBLAS).")
    A("* Beyond paper: TSMT kernel (the TSMTTSM case the paper cites as "
      "uncovered) powers PowerSGD (399x wire compression measured at "
      "rank 4 in examples/powersgd_abft.py) and ABFT checksums "
      "(single-bit corruption detected, tests/test_ft.py).")
    A("* Numerics: every kernel sweeps shapes/dtypes vs the jnp oracle in "
      "interpret mode (tests/test_kernels.py, 46 cases + hypothesis "
      "properties).")
    A("")
    A("Stopping criterion: three consecutive <5% iterations were reached "
      "on the memory term of cell 1 (iterations 2/3/4 before the "
      "microbatch-sharding discovery reset the landscape); post-fix, the "
      "remaining deltas on the dry-run backend are CPU-artifact bound.")


A("# EXPERIMENTS — TSM2X-on-TPU framework\n")
A("Paper: *TSM2X: High-Performance Tall-and-Skinny Matrix-Matrix "
  "Multiplication on GPUs* (Rivera, Chen, et al., JPDC 2020/2021). "
  "Reproduction claims validated: the bound classifier places every paper "
  "shape (n<=32) in the memory-bound regime on v5e "
  "(t2_threshold=481 elems), the optimization ladder reproduces the "
  "paper's ordering (V0 worst, data-prefetch best), and modeled bandwidth "
  "utilization at paper shapes reaches 93–96% of HBM peak — the paper's "
  "own success metric (Figs. 7/11). Kernel numerics validated against "
  "oracles in all cases. Hardware adaptation notes: DESIGN.md §2.\n")
dryrun_section()
roofline_section()
perf_section()

with open("/root/repo/EXPERIMENTS.md", "w") as f:
    f.write("\n".join(lines) + "\n")
print(f"wrote EXPERIMENTS.md: {len(lines)} lines")
