#!/usr/bin/env bash
# Tier-1 CI entry: exactly the command ROADMAP.md pins.
# Optional dev deps (see requirements-dev.txt) are installed best-effort;
# the suite is self-sufficient without them (tests/conftest.py provides a
# hypothesis fallback).
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${REPRO_CI_INSTALL:-0}" == "1" ]] \
        && ! python -c "import hypothesis" 2>/dev/null; then
    pip install -r requirements-dev.txt \
        || echo "ci.sh: install failed, using the in-repo hypothesis fallback"
fi

# REPRO_PYTEST_XDIST=auto (or an int) parallelizes the run via
# pytest-xdist when it is installed -- CI's latest-jax leg sets it to keep
# wall-clock flat as the suite grows; the oldest-pin leg stays serial as
# the deterministic reference. -x is dropped under xdist (fail-fast and
# worker scheduling don't compose; failures still fail the run).
XDIST="${REPRO_PYTEST_XDIST:-}"
if [[ -n "$XDIST" ]] && python -c "import xdist" 2>/dev/null; then
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m pytest -q -n "$XDIST" "$@"
else
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
fi
