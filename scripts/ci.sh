#!/usr/bin/env bash
# Tier-1 CI entry: exactly the command ROADMAP.md pins.
# Optional dev deps (see requirements-dev.txt) are installed best-effort;
# the suite is self-sufficient without them (tests/conftest.py provides a
# hypothesis fallback).
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${REPRO_CI_INSTALL:-0}" == "1" ]] \
        && ! python -c "import hypothesis" 2>/dev/null; then
    pip install -r requirements-dev.txt \
        || echo "ci.sh: install failed, using the in-repo hypothesis fallback"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
